"""Render the §Roofline markdown table from the dry-run JSONs.
Usage: PYTHONPATH=src python -m benchmarks.make_table"""
from __future__ import annotations

from benchmarks.roofline import load_all


def main() -> None:
    data = load_all()
    pod = {(a, s): r for (a, s, m), r in data.items() if m == "16x16"}
    multi = {(a, s) for (a, s, m) in data if m == "2x16x16"}
    print("| arch | shape | t_compute | t_memory | t_collective | "
          "bottleneck | useful | temp GB | fits | 2-pod |")
    print("|---|---|---|---|---|---|---|---|---|---|")

    def fmt(sec):
        if sec >= 1:
            return f"{sec:.2f} s"
        if sec >= 1e-3:
            return f"{sec*1e3:.1f} ms"
        return f"{sec*1e6:.0f} us"

    for (a, s), r in sorted(pod.items()):
        temp = (r["memory"].get("temp_bytes") or 0) / 1e9
        fits = temp + r["param_bytes_per_device"] / 1e9 <= 16.0
        uf = r.get("useful_flops_ratio")
        print(f"| {a} | {s} | {fmt(r['t_compute_s'])} | "
              f"{fmt(r['t_memory_s'])} | {fmt(r['t_collective_s'])} | "
              f"{r['bottleneck'].split('_')[1]} | "
              f"{uf:.2f} | {temp:.1f} | {'Y' if fits else 'N'} | "
              f"{'Y' if (a, s) in multi else 'N'} |")


if __name__ == "__main__":
    main()
