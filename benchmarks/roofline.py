"""Roofline table: reads the dry-run result JSONs (produced by
``python -m repro.launch.dryrun``) and emits the three per-chip roofline
terms per (arch x shape) on the single-pod mesh, plus the multi-pod
lowering check. See EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os
from typing import List

from benchmarks.common import Row

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load_all() -> dict:
    out = {}
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as fh:
            r = json.load(fh)
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def run() -> List[Row]:
    rows: List[Row] = []
    data = load_all()
    if not data:
        rows.append(Row("roofline", "NO_DRYRUN_RESULTS_RUN_dryrun_first",
                        0.0))
        return rows
    pod = {(a, s): r for (a, s, m), r in data.items() if m == "16x16"}
    multi = {(a, s): r for (a, s, m), r in data.items() if m == "2x16x16"}
    for (arch, shape), r in sorted(pod.items()):
        tag = f"{arch}.{shape}"
        rows.append(Row("roofline", f"{tag}.t_compute_us",
                        r["t_compute_s"] * 1e6, "us"))
        rows.append(Row("roofline", f"{tag}.t_memory_us",
                        r["t_memory_s"] * 1e6, "us"))
        rows.append(Row("roofline", f"{tag}.t_collective_us",
                        r["t_collective_s"] * 1e6, "us"))
        rows.append(Row("roofline", f"{tag}.bottleneck",
                        {"t_compute_s": 0, "t_memory_s": 1,
                         "t_collective_s": 2}[r["bottleneck"]], "0=c,1=m,2=x"))
        if r.get("useful_flops_ratio"):
            rows.append(Row("roofline", f"{tag}.useful_flops_ratio",
                            r["useful_flops_ratio"], ""))
        rows.append(Row("roofline", f"{tag}.multipod_lowered",
                        1.0 if (arch, shape) in multi else 0.0, "bool",
                        paper=1.0))
    return rows
