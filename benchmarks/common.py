"""Shared benchmark plumbing: records + CSV output + anchor comparison."""
from __future__ import annotations

import dataclasses
import json
import os
from typing import List, Optional

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


@dataclasses.dataclass
class Row:
    bench: str
    name: str
    value: float
    unit: str = ""
    paper: Optional[float] = None

    @property
    def rel_err(self) -> Optional[float]:
        if self.paper in (None, 0):
            return None
        return abs(self.value - self.paper) / abs(self.paper)

    def csv(self) -> str:
        p = "" if self.paper is None else f"{self.paper:.6g}"
        e = "" if self.rel_err is None else f"{self.rel_err:.3f}"
        return f"{self.bench},{self.name},{self.value:.6g},{self.unit},{p},{e}"


HEADER = "bench,name,value,unit,paper_anchor,rel_err"


def emit(rows: List[Row], *, save_as: Optional[str] = None) -> None:
    for r in rows:
        print(r.csv())
    if save_as:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        with open(os.path.join(RESULTS_DIR, save_as), "w") as fh:
            json.dump([dataclasses.asdict(r) for r in rows], fh, indent=1)
