"""Shared benchmark plumbing: records + CSV output + anchor comparison."""
from __future__ import annotations

import dataclasses
import json
import os
from typing import List, Optional

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


@dataclasses.dataclass
class Row:
    bench: str
    name: str
    value: float
    unit: str = ""
    paper: Optional[float] = None

    @property
    def rel_err(self) -> Optional[float]:
        if self.paper in (None, 0):
            return None
        return abs(self.value - self.paper) / abs(self.paper)

    def csv(self) -> str:
        p = "" if self.paper is None else f"{self.paper:.6g}"
        e = "" if self.rel_err is None else f"{self.rel_err:.3f}"
        return f"{self.bench},{self.name},{self.value:.6g},{self.unit},{p},{e}"


HEADER = "bench,name,value,unit,paper_anchor,rel_err"


def emit(rows: List[Row], *, save_as: Optional[str] = None,
         out_path: Optional[str] = None) -> None:
    """Print rows as CSV; optionally dump JSON to ``RESULTS_DIR/save_as``
    (the benchmarks.run registry path) or to an explicit ``out_path``
    (standalone CLIs / CI artifacts)."""
    for r in rows:
        print(r.csv())
    paths = []
    if save_as:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        paths.append(os.path.join(RESULTS_DIR, save_as))
    if out_path:
        parent = os.path.dirname(out_path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        paths.append(out_path)
    for p in paths:
        with open(p, "w") as fh:
            json.dump([dataclasses.asdict(r) for r in rows], fh, indent=1)
