"""Fig 4: vehicle-classification endpoint inference time on N2-i7 at every
partition point, Ethernet + WiFi, vs the paper's anchors."""
from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.core import Explorer, paper_platform
from repro.core import calibration as cal
from repro.models.cnn import vehicle_graph


def run() -> List[Row]:
    g = vehicle_graph()
    rows: List[Row] = []
    for link in ("ethernet", "wifi"):
        res = Explorer(g, paper_platform("N2", link)).evaluate_modeled()
        for rec in res.records:
            rows.append(Row("fig4", f"n2_{link}_pp{rec.pp}",
                            rec.endpoint_time_s * 1e3, "ms"))
        best = res.best(privacy=True)
        rows.append(Row("fig4", f"n2_{link}_best_pp", best.pp, "pp",
                        paper=3))
        rows.append(Row(
            "fig4", f"n2_{link}_best_ms", best.endpoint_time_s * 1e3, "ms",
            paper=cal.PAPER_ANCHORS[f"vehicle_n2_pp3_{link}"] * 1e3))
    eth = Explorer(g, paper_platform("N2", "ethernet")).evaluate_modeled()
    rows.append(Row("fig4", "n2_full_endpoint_ms",
                    eth.full_endpoint().endpoint_time_s * 1e3, "ms",
                    paper=cal.PAPER_ANCHORS["vehicle_n2_full_endpoint"] * 1e3))
    rows.append(Row("fig4", "n2_raw_offload_ethernet_ms",
                    eth.records[0].endpoint_time_s * 1e3, "ms",
                    paper=cal.PAPER_ANCHORS["vehicle_n2_pp1_ethernet"] * 1e3))
    return rows
