"""Fig 5: vehicle classification on N270-i7 (single-core Atom endpoint)."""
from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.core import Explorer, paper_platform
from repro.core import calibration as cal
from repro.models.cnn import vehicle_graph


def run() -> List[Row]:
    g = vehicle_graph()
    rows: List[Row] = []
    for link in ("ethernet", "wifi"):
        res = Explorer(g, paper_platform("N270", link)).evaluate_modeled()
        for rec in res.records:
            rows.append(Row("fig5", f"n270_{link}_pp{rec.pp}",
                            rec.endpoint_time_s * 1e3, "ms"))
        best = res.best(privacy=True)
        rows.append(Row("fig5", f"n270_{link}_best_pp", best.pp, "pp",
                        paper=2))
        rows.append(Row(
            "fig5", f"n270_{link}_best_ms", best.endpoint_time_s * 1e3, "ms",
            paper=cal.PAPER_ANCHORS[f"vehicle_n270_pp2_{link}"] * 1e3))
    eth = Explorer(g, paper_platform("N270", "ethernet")).evaluate_modeled()
    rows.append(Row(
        "fig5", "n270_full_endpoint_ms",
        eth.full_endpoint().endpoint_time_s * 1e3, "ms",
        paper=cal.PAPER_ANCHORS["vehicle_n270_full_endpoint"] * 1e3))
    return rows
