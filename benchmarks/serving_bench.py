"""Serving micro-benchmark on this CPU: prefill + decode throughput of a
small dense model through the ServeEngine, plus the Edge-PRUNE partitioned
path (actor graph split across two simulated units) — demonstrating the
paper's technique applied to an LLM on real (CPU) wall-clock."""
from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from benchmarks.common import Row
from repro.core import Explorer, Mapping, tpu_pod_platform
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.serving import PartitionedServeEngine, Request, ServeEngine


def _cfg():
    return ModelConfig(
        name="bench-120m", arch_type="dense", n_layers=4, d_model=256,
        n_heads=8, n_kv_heads=4, d_ff=1024, vocab_size=2048,
        dtype="float32", param_dtype="float32", attn_chunk=64, remat=False)


def run() -> List[Row]:
    cfg = _cfg()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=160)
    prompts = [np.random.RandomState(i).randint(0, cfg.vocab_size, 64)
               .astype(np.int32) for i in range(8)]
    reqs = [Request(i, p, max_new_tokens=32) for i, p in enumerate(prompts)]
    eng.generate(reqs[:1])      # warmup/compile
    t0 = time.perf_counter()
    outs = eng.generate(reqs)
    wall = time.perf_counter() - t0
    new_tokens = sum(len(o.tokens) for o in outs)
    rows = [
        Row("serving", "decode_tokens_per_s", new_tokens / wall, "tok/s"),
        Row("serving", "prefill_s", float(np.mean([o.prefill_s for o in outs])),
            "s"),
    ]

    # Edge-PRUNE partitioned inference: actor graph split across 2 units
    g = T.to_actor_graph(cfg, params, batch=1, seq=64)
    assignment = {a: ("endpoint" if i < len(g.actors) // 2 else "server")
                  for i, a in enumerate(g.actors)}
    pse = PartitionedServeEngine(cfg, params, Mapping("half", assignment),
                                 batch=1, seq=64)
    toks = prompts[0][None, :]
    out = pse.infer(toks)                      # warmup
    t0 = time.perf_counter()
    for _ in range(5):
        out = jax.block_until_ready(pse.infer(toks))
    wall = (time.perf_counter() - t0) / 5
    rows.append(Row("serving", "partitioned_infer_ms", wall * 1e3, "ms"))
    rows.append(Row("serving", "partitioned_comm_bytes",
                    pse.comm_bytes(), "B"))

    # explorer over the LLM actor graph on the TPU pod platform model:
    # the paper's partition-point methodology applied to pod boundaries
    res = Explorer(T.to_actor_graph(cfg, batch=1, seq=64),
                   tpu_pod_platform(2)).evaluate_modeled()
    rows.append(Row("serving", "pod_explorer_best_pp",
                    res.best(privacy=True).pp, "pp"))
    return rows
