"""Serving benchmark: static-bucket vs continuous vs continuous+pipelined.

Workload: Poisson request arrivals with mixed prompt lengths (the
open-loop serving regime). All engine configurations are the same
policy-based ``Engine`` under different ``EngineConfig``s:

* ``batch``        — the seed static-bucket executor: per-(batch,
  prompt_len) bucket compiles, each bucket decoded to completion
  serially;
* ``fifo``         — the slot-based continuous-batching scheduler: one
  decode compile, per-step admission/eviction into a shared batch;
* ``priority``     — same scheduler, priority admission: measured on the
  same Poisson trace with a contended slot budget, asserting that
  high-priority requests beat their FIFO TTFT p99 (they jump the queue);
* ``disaggregated`` — the multi-unit execution core: the same
  closed-loop trace through a single-unit, a 2-unit prefill/decode
  split, and a 3-unit pipelined-decode topology on modeled per-unit
  clocks, asserting bit-identical tokens and a >= 1.3x modeled-makespan
  improvement for the 2-unit split;
* ``continuous+pipelined`` — the Edge-PRUNE angle: prefill partitioned
  across two processing units via a StagedProgram, frames streamed
  through the stage pipeline with modeled per-unit clocks (paper
  platform, Sec III.B), reported as modeled makespan vs the sequential
  execution of the same stages.

``--paged`` additionally measures the paged-KV + chunked-prefill engine
against the slotted continuous baseline on the same Poisson trace:
pool/high-water KV bytes vs the dense slotted reservation, TTFT p50/p99
for both, and the growth-preemption count under the admission
``--watermark`` (0 = no headroom reserved).

``--prefix-cache`` measures paged prefix sharing on a *shared-prefix*
Poisson trace (every prompt opens with the same preamble — the
edge-serving pattern where many endpoint clients reuse one task header):
the same trace runs with sharing off and on, asserting identical greedy
tokens, >= 30% of prompt tokens skipping prefill, and a lower pool
high-water mark; reports prefill tokens saved, pool high-water and TTFT
p50/p99 for both.

``python benchmarks/serving_bench.py --tiny --out smoke.json`` is the CI
bench-smoke entrypoint (``--paged --prefix-cache --tiny`` is the paged
smoke; also runnable via ``python -m benchmarks.run --only serving`` for
the full size).
"""
from __future__ import annotations

import argparse
import time
from typing import List, Optional

import jax
import numpy as np

from benchmarks.common import HEADER, Row, emit
from repro.core import Explorer, Mapping, PlatformModel, paper_platform, \
    tpu_pod_platform
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.observability import pipeline_trace
from repro.runtime.serving import PartitionedServeEngine, Request

PROMPT_LENS = (32, 48, 64, 96)


def _cfg(tiny: bool = False) -> ModelConfig:
    if tiny:
        return ModelConfig(
            name="bench-tiny", arch_type="dense", n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
            dtype="float32", param_dtype="float32", attn_chunk=32,
            remat=False)
    return ModelConfig(
        name="bench-120m", arch_type="dense", n_layers=4, d_model=256,
        n_heads=8, n_kv_heads=4, d_ff=1024, vocab_size=2048,
        dtype="float32", param_dtype="float32", attn_chunk=64, remat=False)


def _requests(cfg: ModelConfig, n: int, max_new: int, *,
              lens=PROMPT_LENS, seed: int = 0) -> List[Request]:
    rng = np.random.RandomState(seed)
    return [Request(i, rng.randint(0, cfg.vocab_size,
                                   lens[i % len(lens)]).astype(np.int32),
                    max_new_tokens=max_new) for i in range(n)]


def _poisson_arrivals(n: int, rate_per_s: float, seed: int = 0) -> List[float]:
    rng = np.random.RandomState(seed)
    return list(np.cumsum(rng.exponential(1.0 / rate_per_s, size=n)))


def _measure(eng: Engine, reqs: List[Request],
             arrivals: Optional[List[float]]) -> dict:
    t0 = time.perf_counter()
    outs = eng.generate(reqs, arrivals=arrivals) \
        if not eng.batch_mode else eng.generate(reqs)
    wall = time.perf_counter() - t0
    toks = sum(len(o.tokens) for o in outs)
    lat = [o.latency_s for o in outs if o.finish_s > 0.0]
    return {
        "throughput": toks / wall,
        "wall_s": wall,
        "mean_latency_s": float(np.mean(lat)) if lat else wall,
        "p95_latency_s": float(np.percentile(lat, 95)) if lat else wall,
        "outs": outs,
    }


def _priority_rows(cfg, params, reqs, arrivals, *, max_len: int) -> List[Row]:
    """Priority admission vs FIFO on the same Poisson trace under a
    contended slot budget (2 slots): the last quarter of arrivals is
    marked high-priority, so under FIFO they queue behind everything
    already waiting while priority admission jumps them to the head.
    Asserts the headline property: priority scheduling improves the
    high-priority cohort's TTFT p99."""
    hi = max(1, len(reqs) // 4)
    hi_ids = {r.id for r in reqs[-hi:]}
    prio_reqs = [Request(r.id, r.prompt, max_new_tokens=r.max_new_tokens,
                         eos=r.eos, embeds=r.embeds,
                         priority=5 if r.id in hi_ids else 0) for r in reqs]
    ttft_p99 = {}
    for name in ("fifo", "priority"):
        eng = Engine(cfg, params, EngineConfig(
            max_len=max_len, max_slots=2, admission=name))
        eng.generate(prio_reqs)             # warmup (compiles), closed loop
        # best-of-2 damps wall-clock hiccups (the hi cohort is small, so
        # its p99 is ~a max — a single descheduling pause would dominate)
        p99s = []
        for _ in range(2):
            o = _measure(eng, prio_reqs, arrivals)
            ttfts = [x.ttft_s for x in o["outs"] if x.id in hi_ids]
            p99s.append(float(np.percentile(ttfts, 99)))
        ttft_p99[name] = min(p99s)
    # wall-clock comparative gate (the ISSUE-mandated assertion): the
    # structural gap under contention is ~3x, so a 15%-relative + 1ms
    # margin tolerates runner jitter while still failing if priority
    # scheduling stops helping the high-priority cohort at all
    assert ttft_p99["priority"] <= ttft_p99["fifo"] \
        + max(1e-3, 0.15 * ttft_p99["fifo"]), \
        (f"priority admission must not worsen high-priority TTFT p99: "
         f"{ttft_p99['priority']:.4f}s vs fifo {ttft_p99['fifo']:.4f}s")
    return [
        Row("serving", "fifo_hi_ttft_p99_ms", ttft_p99["fifo"] * 1e3, "ms"),
        Row("serving", "priority_hi_ttft_p99_ms",
            ttft_p99["priority"] * 1e3, "ms"),
    ]


def _paged_rows(cfg, params, reqs, arrivals, *, max_len: int, slots: int,
                watermark: int, slotted_outs) -> List[Row]:
    """Paged + chunked-prefill engine vs the slotted baseline on the same
    Poisson trace: KV memory (pool + high-water mark vs the dense slotted
    reservation), TTFT p50/p99, and growth preemptions under the
    admission watermark."""
    pag = Engine(cfg, params, EngineConfig(
        max_len=max_len, max_slots=slots, kv_layout="paged", block_size=16,
        prefill_chunk=16, watermark=watermark))
    pag.generate(reqs)                  # warmup (compiles)
    # the closed-loop warmup saturates the pool; report the high-water
    # mark of the measured Poisson run only
    pag.scheduler.alloc.reset_hwm()
    pre_warmup = pag.stats()["preemptions"]
    o = _measure(pag, reqs, arrivals)
    stats = pag.kv_stats()
    preemptions = pag.stats()["preemptions"] - pre_warmup
    ttft_p = [x.ttft_s for x in o["outs"]]
    ttft_s = [x.ttft_s for x in slotted_outs]
    return [
        Row("serving", "paged_tokens_per_s", o["throughput"], "tok/s"),
        Row("serving", "slotted_kv_reserved_bytes",
            stats["slotted_kv_reserved_bytes"], "B"),
        Row("serving", "paged_kv_pool_bytes", stats["paged_kv_pool_bytes"],
            "B"),
        Row("serving", "paged_kv_hwm_bytes", stats["paged_kv_hwm_bytes"],
            "B"),
        Row("serving", "paged_watermark_blocks", float(watermark), "blk"),
        Row("serving", "paged_poisson_preemptions", float(preemptions),
            "req"),
        Row("serving", "paged_poisson_ttft_p50_ms",
            float(np.percentile(ttft_p, 50)) * 1e3, "ms"),
        Row("serving", "paged_poisson_ttft_p99_ms",
            float(np.percentile(ttft_p, 99)) * 1e3, "ms"),
        Row("serving", "slotted_poisson_ttft_p50_ms",
            float(np.percentile(ttft_s, 50)) * 1e3, "ms"),
        Row("serving", "slotted_poisson_ttft_p99_ms",
            float(np.percentile(ttft_s, 99)) * 1e3, "ms"),
    ]


def _shared_prefix_requests(cfg: ModelConfig, n: int, max_new: int, *,
                            prefix_len: int, seed: int = 3) -> List[Request]:
    """The edge-serving traffic shape: every prompt opens with the same
    ``prefix_len``-token preamble (task instructions / few-shot header),
    followed by a per-request tail of varying length."""
    rng = np.random.RandomState(seed)
    shared = rng.randint(0, cfg.vocab_size, prefix_len).astype(np.int32)
    tails = (8, 16, 24, 32)
    return [Request(i, np.concatenate([
                shared, rng.randint(0, cfg.vocab_size,
                                    tails[i % len(tails)]).astype(np.int32)]),
                    max_new_tokens=max_new) for i in range(n)]


def _prefix_rows(cfg, params, *, max_len: int, slots: int, n: int,
                 max_new: int, rate: float, seed: int) -> List[Row]:
    """Prefix sharing on vs off over the same shared-prefix Poisson
    trace. Asserts the headline properties: identical greedy tokens,
    >= 30% of prompt tokens skipping prefill, and a lower paged-pool
    high-water mark (shared chains are resident once, not per slot)."""
    prefix_len = 48
    reqs = _shared_prefix_requests(cfg, n, max_new, prefix_len=prefix_len)
    # arrive fast relative to service so shared chains stay resident
    arrivals = _poisson_arrivals(n, rate_per_s=max(rate, 200.0), seed=seed)
    runs = {}
    for on in (False, True):
        eng = Engine(cfg, params, EngineConfig(
            max_len=max_len, max_slots=slots, kv_layout="paged",
            block_size=16, prefix_cache=on))
        eng.generate(reqs)              # warmup (compiles), closed loop
        eng.scheduler.alloc.reset_hwm()
        base = eng.stats()
        o = _measure(eng, reqs, arrivals)
        st = eng.stats()
        runs[on] = {
            "outs": o["outs"],
            "hwm": eng.kv_stats()["paged_kv_hwm_blocks"],
            "saved": st["prefill_tokens_saved"] - base["prefill_tokens_saved"],
            "total": st["prefill_tokens_total"] - base["prefill_tokens_total"],
            "hits": st["prefix_hits"] - base["prefix_hits"],
            "ttft": [x.ttft_s for x in o["outs"]],
        }
    assert [c.tokens for c in runs[True]["outs"]] == \
        [c.tokens for c in runs[False]["outs"]], \
        "prefix sharing changed greedy tokens"
    saved_frac = runs[True]["saved"] / max(runs[True]["total"], 1)
    assert saved_frac >= 0.30, \
        (f"shared-prefix trace must skip >= 30% of prefill tokens, got "
         f"{saved_frac:.1%} ({runs[True]['saved']}/{runs[True]['total']})")
    assert runs[True]["hwm"] < runs[False]["hwm"], \
        (f"prefix sharing must lower the pool high-water mark: "
         f"{runs[True]['hwm']:.0f} vs {runs[False]['hwm']:.0f} blocks")
    return [
        Row("serving", "prefix_shared_prompt_tokens", float(prefix_len),
            "tok"),
        Row("serving", "prefix_prefill_tokens_saved",
            float(runs[True]["saved"]), "tok"),
        Row("serving", "prefix_prefill_tokens_saved_frac", saved_frac, "x"),
        Row("serving", "prefix_hits", float(runs[True]["hits"]), "req"),
        Row("serving", "prefix_on_kv_hwm_blocks", runs[True]["hwm"], "blk"),
        Row("serving", "prefix_off_kv_hwm_blocks", runs[False]["hwm"], "blk"),
        Row("serving", "prefix_on_ttft_p50_ms",
            float(np.percentile(runs[True]["ttft"], 50)) * 1e3, "ms"),
        Row("serving", "prefix_on_ttft_p99_ms",
            float(np.percentile(runs[True]["ttft"], 99)) * 1e3, "ms"),
        Row("serving", "prefix_off_ttft_p50_ms",
            float(np.percentile(runs[False]["ttft"], 50)) * 1e3, "ms"),
        Row("serving", "prefix_off_ttft_p99_ms",
            float(np.percentile(runs[False]["ttft"], 99)) * 1e3, "ms"),
    ]


def _victim_rows(cfg, params, *, max_len: int, slots: int, n: int,
                 max_new: int, tenants: int) -> List[Row]:
    """The prefix-cache *service* section: a two-wave multi-tenant
    shared-prefix trace, victim cache on vs off. Wave 1 drains fully
    (every chain hits refcount 0); wave 2 re-sends the same per-tenant
    prompts as cold admissions. With the victim cache on those must
    resume from parked chains (``victim_hits`` counts exactly the
    cross-request hits — it is structurally zero with the cache off),
    and retention must never change the greedy tokens."""
    tenants = max(tenants, 1)
    prefix_len = 32
    rng = np.random.RandomState(5)
    names = [f"tenant{t}" for t in range(tenants)]
    preamble = {t: rng.randint(0, cfg.vocab_size, prefix_len)
                .astype(np.int32) for t in names}
    per = max(n // tenants, 2)
    prompts = [(t, np.concatenate(
        [preamble[t], rng.randint(0, cfg.vocab_size,
                                  8 + 4 * (i % 3)).astype(np.int32)]))
        for t in names for i in range(per)]

    def wave_reqs(base: int) -> List[Request]:
        return [Request(base + i, p.copy(), max_new_tokens=max_new,
                        tenant=t) for i, (t, p) in enumerate(prompts)]

    runs = {}
    for on in (False, True):
        eng = Engine(cfg, params, EngineConfig(
            max_len=max_len, max_slots=slots, kv_layout="paged",
            block_size=16, prefix_cache=True, victim_cache=on))
        w1 = eng.generate(wave_reqs(0))         # also the compile warmup
        base = eng.stats()
        w2 = eng.generate(wave_reqs(10_000))    # cold cross-drain replay
        st = eng.stats()
        runs[on] = {
            "w1": w1, "w2": w2,
            "victim_hits": st["victim_hits"] - base["victim_hits"],
            "saved": st["prefill_tokens_saved"]
            - base["prefill_tokens_saved"],
            "total": st["prefill_tokens_total"]
            - base["prefill_tokens_total"],
            "snap": eng.snapshot().get("prefix_cache", {}),
        }
    for w in ("w1", "w2"):
        assert [c.tokens for c in runs[True][w]] == \
            [c.tokens for c in runs[False][w]], \
            f"victim cache changed greedy tokens (wave {w})"
    assert runs[False]["victim_hits"] == 0, \
        "victim_hits must be structurally zero with the cache off"
    assert runs[True]["victim_hits"] > 0, \
        "cold replay never resumed from a parked chain"
    snap = runs[True]["snap"]
    assert len(snap.get("per_tenant_bytes", {})) == tenants, \
        "victim pool is missing a tenant's namespace"
    hit_rate = runs[True]["victim_hits"] / len(prompts)
    saved_frac = runs[True]["saved"] / max(runs[True]["total"], 1)
    return [
        Row("serving", "victim_tenants", float(tenants), "n"),
        Row("serving", "victim_cross_request_hit_rate", hit_rate, "x"),
        Row("serving", "victim_hits", float(runs[True]["victim_hits"]),
            "req"),
        Row("serving", "victim_prefill_tokens_saved_frac", saved_frac, "x"),
        Row("serving", "victim_bytes_saved",
            float(runs[True]["saved"] * T.kv_row_bytes(cfg)), "B"),
        Row("serving", "victim_pool_blocks",
            float(snap.get("victim_blocks", 0)), "blk"),
        Row("serving", "victim_evictions",
            float(snap.get("victim_evictions", 0)), "n"),
    ]


def _disagg_rows(cfg, params, *, tiny: bool) -> List[Row]:
    """Prefill/decode disaggregation on the multi-unit execution core:
    one closed-loop trace through three unit topologies — single unit
    (the degenerate case: modeled makespan == the sequential work sum),
    a 2-unit prefill/decode split, and a 3-unit split with 2 pipelined
    decode stages. Tokens must be bit-identical across all three (unit
    topologies move modeled time, never content); the headline gate is
    the 2-unit split beating single-unit modeled makespan by >= 1.3x.
    The workload balances prompt and decode work and keeps the slot
    batch small, so the dedicated prefill unit runs ahead on the next
    admissions while the decode unit drains the current batch."""
    n, plen, new = (6, 16, 16) if tiny else (8, 48, 48)
    rng = np.random.RandomState(5)
    reqs = [Request(i, rng.randint(0, cfg.vocab_size, plen).astype(np.int32),
                    max_new_tokens=new) for i in range(n)]
    topos = {
        "single": dict(),
        "disagg": dict(units=2, prefill_units=1),
        "disagg_pipelined": dict(units=3, prefill_units=1, decode_stages=2),
    }
    outs, summ = {}, {}
    for name, kw in topos.items():
        eng = Engine(cfg, params, EngineConfig(
            max_len=plen + new + 8, max_slots=2, **kw))
        outs[name] = eng.generate(reqs)
        summ[name] = eng.scheduler.core.summary()
    for name in ("disagg", "disagg_pipelined"):
        assert [c.tokens for c in outs[name]] == \
            [c.tokens for c in outs["single"]], \
            f"unit topology {name} changed greedy tokens"
        assert summ[name]["kv_handoffs"] == n
        # same requests -> same total modeled work on every topology
        assert abs(summ[name]["modeled_sequential_s"]
                   - summ["single"]["modeled_sequential_s"]) < 1e-9
    mk = {k: v["modeled_makespan_s"] for k, v in summ.items()}
    # single unit is the degenerate case: nothing overlaps
    assert abs(mk["single"] - summ["single"]["modeled_sequential_s"]) < 1e-9
    speedup = mk["single"] / mk["disagg"]
    assert speedup >= 1.3, \
        (f"2-unit prefill/decode split must improve modeled makespan "
         f">= 1.3x over single-unit, got {speedup:.2f}x "
         f"({mk['disagg']:.4f}s vs {mk['single']:.4f}s)")
    return [
        Row("serving", "single_unit_modeled_makespan_s", mk["single"], "s"),
        Row("serving", "disagg_modeled_makespan_s", mk["disagg"], "s"),
        Row("serving", "disagg_modeled_speedup", speedup, "x"),
        Row("serving", "disagg_pipelined_modeled_makespan_s",
            mk["disagg_pipelined"], "s"),
        Row("serving", "disagg_pipelined_modeled_speedup",
            mk["single"] / mk["disagg_pipelined"], "x"),
        Row("serving", "disagg_kv_handoffs",
            float(summ["disagg"]["kv_handoffs"]), "req"),
    ]


def _observability_rows(cfg, params, reqs, arrivals, *, max_len: int,
                        slots: int):
    """The same open-loop Poisson trace through an observability-enabled
    continuous engine: the engine's own histogram summaries (TTFT, queue
    wait, step duration) become bench rows; the engine's
    ``Observability`` rides back so ``run`` can append the pipelined
    section's modeled timeline before writing ``--trace-out``."""
    eng = Engine(cfg, params, EngineConfig(
        max_len=max_len, max_slots=slots, observability=True))
    eng.generate(reqs)                  # warmup (compiles), closed loop
    # scope the summaries to the measured window: the warmup's
    # compile-inflated TTFTs would otherwise dominate every percentile
    eng.obs.registry.reset_histograms()
    _measure(eng, reqs, arrivals)
    h = eng.snapshot()["metrics"]["histograms"]

    def p(name: str, q: str) -> float:
        return float(h.get(name, {}).get(q, 0.0)) * 1e3

    rows = [
        Row("serving", "obs_ttft_p50_ms", p("repro_ttft_seconds", "p50"),
            "ms"),
        Row("serving", "obs_ttft_p99_ms", p("repro_ttft_seconds", "p99"),
            "ms"),
        Row("serving", "obs_queue_wait_p50_ms",
            p("repro_queue_wait_seconds", "p50"), "ms"),
        Row("serving", "obs_step_duration_p50_ms",
            p("repro_step_duration_seconds", "p50"), "ms"),
        Row("serving", "obs_inter_token_p50_ms",
            p("repro_inter_token_seconds", "p50"), "ms"),
    ]
    return rows, eng.obs


def run(*, tiny: bool = False, n_requests: Optional[int] = None,
        max_new: Optional[int] = None, rate: float = 200.0,
        seed: int = 1, paged: bool = False, watermark: int = 0,
        prefix_cache: bool = False, victim_cache: bool = False,
        tenants: int = 0, trace_out: Optional[str] = None) -> List[Row]:
    cfg = _cfg(tiny)
    n = n_requests or (8 if tiny else 16)
    new = max_new or (8 if tiny else 32)
    max_len = max(PROMPT_LENS) + new + 8
    slots = min(n, 8)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    reqs = _requests(cfg, n, new)
    arrivals = _poisson_arrivals(n, rate_per_s=rate, seed=seed)

    static = Engine(cfg, params, EngineConfig(max_len=max_len,
                                              admission="batch"))
    cont = Engine(cfg, params, EngineConfig(max_len=max_len,
                                            max_slots=slots))
    # warmup both paths so compile time doesn't pollute the comparison
    static.generate(reqs)
    cont.generate(reqs)

    # Closed-loop throughput: both modes get every request at t=0, so the
    # comparison isolates scheduling (shared decode batch + single compile
    # vs per-bucket loops), not arrival waiting. Best-of-2 damps CI noise.
    s = max((_measure(static, reqs, None) for _ in range(2)),
            key=lambda m: m["throughput"])
    c = max((_measure(cont, reqs, None) for _ in range(2)),
            key=lambda m: m["throughput"])
    # Open-loop latency under Poisson arrivals (continuous only: the
    # static engine has no admission queue to feed mid-flight).
    o = _measure(cont, reqs, arrivals)
    rows = [
        Row("serving", "static_bucket_tokens_per_s", s["throughput"], "tok/s"),
        Row("serving", "continuous_tokens_per_s", c["throughput"], "tok/s"),
        Row("serving", "continuous_vs_static_speedup",
            c["throughput"] / s["throughput"], "x"),
        Row("serving", "poisson_mean_latency_ms",
            o["mean_latency_s"] * 1e3, "ms"),
        Row("serving", "poisson_p95_latency_ms",
            o["p95_latency_s"] * 1e3, "ms"),
        Row("serving", "poisson_mean_ttft_ms",
            float(np.mean([x.ttft_s for x in o["outs"]])) * 1e3, "ms"),
    ]
    rows += _priority_rows(cfg, params, reqs, arrivals, max_len=max_len)
    obs_rows, obs = _observability_rows(cfg, params, reqs, arrivals,
                                        max_len=max_len, slots=slots)
    rows += obs_rows
    if paged:
        rows += _paged_rows(cfg, params, reqs, arrivals, max_len=max_len,
                            slots=slots, watermark=watermark,
                            slotted_outs=o["outs"])
    if prefix_cache:
        rows += _prefix_rows(cfg, params, max_len=max_len, slots=slots,
                             n=n, max_new=new, rate=rate, seed=seed)
    if victim_cache:
        rows += _victim_rows(cfg, params, max_len=max_len, slots=slots,
                             n=n, max_new=new, tenants=tenants or 4)
    rows += _disagg_rows(cfg, params, tiny=tiny)

    # continuous+pipelined: prefill stream through a 2-unit StagedProgram
    # on the paper's N2/i7 WiFi platform (overlapping link), modeled clocks.
    seq_len = PROMPT_LENS[0]
    g = T.to_actor_graph(cfg, params, batch=1, seq=seq_len, group_size=2)
    names = list(g.actors)
    mapping = Mapping("half", {nm: ("endpoint" if i < len(names) // 2
                                    else "server")
                               for i, nm in enumerate(names)})
    pse = PartitionedServeEngine(cfg, params, mapping, batch=1, seq=seq_len,
                                 group_size=2)
    pm = PlatformModel(paper_platform("N2", "wifi"))
    rng = np.random.RandomState(2)
    frames = [rng.randint(0, cfg.vocab_size, (1, seq_len)).astype(np.int32)
              for _ in range(n)]
    _, sched = pse.infer_pipelined(frames, platform=pm)
    rows += [
        Row("serving", "pipelined_modeled_makespan_s", sched.makespan_s, "s"),
        Row("serving", "pipelined_modeled_sequential_s", sched.sequential_s,
            "s"),
        Row("serving", "pipelined_modeled_speedup", sched.speedup, "x"),
        Row("serving", "partitioned_comm_bytes", pse.comm_bytes(), "B"),
    ]
    assert sched.makespan_s < sched.sequential_s, \
        "pipelined execution must beat sequential stage execution"
    if trace_out:
        # wall-clock engine tracks + the pipelined section's modeled
        # unit tracks in one file (separate processes, separate clocks)
        pipeline_trace(obs.tracer, sched)
        n_ev = obs.write_trace(trace_out)
        print(f"wrote {trace_out} ({n_ev} trace events)")

    if not tiny:
        # explorer over the LLM actor graph on the TPU pod platform model:
        # the paper's partition-point methodology applied to pod boundaries
        res = Explorer(T.to_actor_graph(cfg, batch=1, seq=64),
                       tpu_pod_platform(2)).evaluate_modeled()
        rows.append(Row("serving", "pod_explorer_best_pp",
                        res.best(privacy=True).pp, "pp"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    # shared engine-policy flags (same registration as launch/serve.py,
    # load_bench.py and runtime/server.py — no per-entry-point drift);
    # this bench reads --paged / --watermark / --prefix-cache as "also
    # measure that engine configuration on the same trace"
    EngineConfig.add_cli_args(ap)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke config (small model, few requests)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--max-new", type=int, default=None)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="Poisson arrival rate (requests/s) for the "
                         "open-loop workload")
    ap.add_argument("--seed", type=int, default=1,
                    help="arrival-process RNG seed (reproducible sweeps)")
    ap.add_argument("--tenants", type=int, default=0,
                    help="tenant count for the victim-cache section "
                         "(with --victim-cache; 0 = default of 4)")
    ap.add_argument("--out", default=None,
                    help="write rows as JSON to this path")
    ap.add_argument("--trace-out", default=None,
                    help="write the observability run's Chrome trace "
                         "here (load into Perfetto / chrome://tracing)")
    args = ap.parse_args()
    rows = run(tiny=args.tiny, n_requests=args.requests,
               max_new=args.max_new, rate=args.rate, seed=args.seed,
               paged=args.paged, watermark=args.watermark,
               prefix_cache=args.prefix_cache,
               victim_cache=getattr(args, "victim_cache", False),
               tenants=args.tenants, trace_out=args.trace_out)
    print(HEADER)
    emit(rows, out_path=args.out)


if __name__ == "__main__":
    main()
