"""Sec IV.D: single-image end-to-end latency with the feedback socket —
31.2 ms total, split 57% endpoint / 23% network / 20% server."""
from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.core import PlatformModel, paper_platform
from repro.core import calibration as cal
from repro.models.cnn import vehicle_graph


def run() -> List[Row]:
    g = vehicle_graph()
    model = PlatformModel(paper_platform("N2", "ethernet"))
    order = g.topo_order()
    ep_actors, sv_actors = order[:3], order[3:]
    cold = cal.N2_COLD_START_FACTOR     # single-frame runs cache-cold
    ep = sum(model.actor_time_s("endpoint", a) for a in ep_actors) * cold
    tx = model.transfer_time_s("endpoint", "server", 73728)
    sv = sum(model.actor_time_s("server", a) for a in sv_actors)
    total = ep + tx + sv
    a = cal.PAPER_ANCHORS
    return [
        Row("latency", "e2e_ms", total * 1e3, "ms", paper=a["latency_e2e"] * 1e3),
        Row("latency", "endpoint_frac", ep / total, "",
            paper=a["latency_split"][0]),
        Row("latency", "network_frac", tx / total, "",
            paper=a["latency_split"][1]),
        Row("latency", "server_frac", sv / total, "",
            paper=a["latency_split"][2]),
    ]
