"""Benchmark entrypoint: one module per paper table/figure + the roofline
table + a CPU serving microbench. ``python -m benchmarks.run [--only X]``.

CSV schema: bench,name,value,unit,paper_anchor,rel_err
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (dual_input, fig4_vehicle_n2, fig5_vehicle_n270,
                        fig6_ssd_n2, latency_breakdown, roofline,
                        serving_bench)
from benchmarks.common import HEADER, emit

BENCHES = {
    "fig4": fig4_vehicle_n2,
    "fig5": fig5_vehicle_n270,
    "fig6": fig6_ssd_n2,
    "dual_input": dual_input,
    "latency": latency_breakdown,
    "roofline": roofline,
    "serving": serving_bench,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(BENCHES))
    args = ap.parse_args()
    names = [args.only] if args.only else list(BENCHES)
    print(HEADER)
    bad = 0
    for name in names:
        t0 = time.time()
        rows = BENCHES[name].run()
        emit(rows, save_as=f"{name}.json")
        for r in rows:
            if r.rel_err is not None and r.rel_err > 0.25:
                bad += 1
                print(f"WARN,{name},{r.name},rel_err={r.rel_err:.3f}",
                      file=sys.stderr)
        print(f"# {name}: {len(rows)} rows in {time.time() - t0:.1f}s",
              file=sys.stderr)
    if bad:
        print(f"# {bad} rows deviate >25% from paper anchors",
              file=sys.stderr)


if __name__ == "__main__":
    main()
