"""Fig 6: SSD-Mobilenet object tracking on N2-i7 — the paper's headline
5.8x collaborative-inference speedup."""
from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.core import Explorer, paper_platform
from repro.core import calibration as cal
from repro.models.cnn import partition_point_after, ssd_mobilenet_graph


def run() -> List[Row]:
    g = ssd_mobilenet_graph()
    rows: List[Row] = []
    res_by_link = {}
    for link in ("ethernet", "wifi"):
        res = Explorer(g, paper_platform("N2", link,
                                         workload="ssd")).evaluate_modeled()
        res_by_link[link] = res
        for rec in res.records:
            rows.append(Row("fig6", f"ssd_{link}_pp{rec.pp}",
                            rec.endpoint_time_s * 1e3, "ms"))
    eth = res_by_link["ethernet"]
    full = eth.full_endpoint().endpoint_time_s
    rows.append(Row("fig6", "ssd_full_endpoint_ms", full * 1e3, "ms",
                    paper=cal.PAPER_ANCHORS["ssd_n2_full_endpoint"] * 1e3))
    # the paper's reported cut: Input..DWCL9 on the endpoint
    pp_paper = partition_point_after(g, "DWCL9")
    at_cut = eth.records[pp_paper - 1]
    rows.append(Row("fig6", "ssd_at_paper_cut_ms",
                    at_cut.endpoint_time_s * 1e3, "ms",
                    paper=cal.PAPER_ANCHORS["ssd_n2_best_ethernet"] * 1e3))
    rows.append(Row("fig6", "ssd_speedup_at_paper_cut",
                    full / at_cut.endpoint_time_s, "x",
                    paper=cal.PAPER_ANCHORS["ssd_speedup"]))
    # our explorer's own optimum lies earlier on the same 739328-B token
    # plateau (DWCL6..DWCL9 are within the model's resolution) — reported
    # without an anchor as a model finding, see EXPERIMENTS.md.
    best = eth.best(privacy=True)
    rows.append(Row("fig6", "ssd_model_best_ms",
                    best.endpoint_time_s * 1e3, "ms"))
    rows.append(Row("fig6", "ssd_model_best_boundary_bytes",
                    best.boundary_bytes, "B", paper=739328))
    wifi = res_by_link["wifi"]
    at_cut_w = wifi.records[partition_point_after(g, "DWCL9") - 1]
    rows.append(Row("fig6", "ssd_wifi_at_paper_region_ms",
                    at_cut_w.endpoint_time_s * 1e3, "ms",
                    paper=cal.PAPER_ANCHORS["ssd_n2_best_wifi"] * 1e3))
    return rows
