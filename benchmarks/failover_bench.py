"""Failover benchmark: collaborative inference under injected failures.

Reproduces the scenario shape of *Fault-Tolerant Collaborative Inference
through the Edge-PRUNE Framework* (arXiv 2206.08152): an LLM actor graph
served endpoint+server on the paper's N2/i7 WiFi platform, with the
server killed mid-stream. Because the application graph never changes —
only the mapping does — recovery is a mapping switch: the failover
controller detects the loss via heartbeat timeout, re-synthesizes the
staged program on the surviving unit from its precomputed ranked fallback
list, and replays the unacknowledged frames from its checkpoint buffer.

Three layers are measured:

* **controller** — recovery latency (detection + re-synthesis), frames
  replayed, degraded vs nominal modeled throughput, and a bit-identity
  check: every served frame's logits must equal the failure-free run's.
* **scheduler** — continuous-batching slot loss mid-decode: affected
  requests are re-queued (not dropped) and every request's greedy tokens
  stay bit-identical to the failure-free run.
* **simulator** — token-accurate kill/revive of the server: lost frames
  re-fired from the last consistent frame boundary.

``python benchmarks/failover_bench.py --tiny --out smoke.json`` is the CI
bench-smoke entrypoint.
"""
from __future__ import annotations

import argparse
from typing import List, Optional

import jax
import numpy as np

from benchmarks.common import HEADER, Row, emit
from repro.core import Explorer, Mapping, PlatformModel, Simulator, \
    paper_platform
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.observability import Observability, simulator_trace
from repro.runtime.resilience import (FailoverController, FailureTrace,
                                      HeartbeatConfig)
from repro.runtime.scheduler import (ContinuousScheduler, SchedulerConfig,
                                     SlotFailure)
from repro.runtime.serving import Request

SEQ_LEN = 32


def _cfg(tiny: bool = False) -> ModelConfig:
    if tiny:
        return ModelConfig(
            name="failover-tiny", arch_type="dense", n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
            dtype="float32", param_dtype="float32", attn_chunk=32,
            remat=False)
    return ModelConfig(
        name="failover-120m", arch_type="dense", n_layers=4, d_model=256,
        n_heads=8, n_kv_heads=4, d_ff=1024, vocab_size=2048,
        dtype="float32", param_dtype="float32", attn_chunk=64, remat=False)


def _controller_rows(cfg, params, *, n_frames: int, fail_frac: float,
                     seed: int,
                     obs: Optional[Observability] = None) -> List[Row]:
    # The companion paper's scenario needs collaboration to *win*
    # nominally so that losing the server genuinely degrades service:
    # the N270 endpoint is far too weak for full on-device inference
    # (paper Fig. 5), hence endpoint+server is the best collaborative
    # mapping and the post-failure all-endpoint fallback is the degraded
    # mode.
    g = T.to_actor_graph(cfg, params, batch=1, seq=SEQ_LEN, group_size=2)
    pg = paper_platform("N270", "ethernet")
    pm = PlatformModel(pg)
    # Precomputed ranked fallback list (the deployment-time artifact):
    # every partition point plus the single-unit recovery mappings. The
    # controller walks it for the first mapping viable on the survivors.
    ranked = Explorer(g, pg).rank_fallbacks()
    primary = next(m for m in ranked if len(m.units_used()) == 2)
    fallbacks = ranked
    rng = np.random.RandomState(seed)
    frames = [{"Input": jax.numpy.asarray(
        rng.randint(0, cfg.vocab_size, (1, SEQ_LEN)).astype(np.int32))}
        for _ in range(n_frames)]

    def controller(hb=None, obs_=None):
        return FailoverController(g, primary, fallbacks, platform=pm,
                                  heartbeat=hb,
                                  checkpoint_frames=max(2, n_frames // 3),
                                  obs=obs_)

    nominal, nom_rep = controller().serve(frames)
    assert nom_rep.num_failovers == 0
    frame_gap = nom_rep.makespan_s / n_frames
    hb = HeartbeatConfig(interval_s=frame_gap / 2, timeout_s=frame_gap)

    t_fail = fail_frac * nom_rep.makespan_s
    trace = FailureTrace().kill_unit("server", at=t_fail)
    ctl = controller(hb, obs)
    outs, rep = ctl.serve(frames, failures=trace)

    assert rep.num_failovers >= 1 and not rep.exhausted, \
        "mid-stream server loss must recover via a fallback mapping"
    assert rep.mapping_history[-1] != primary.name
    assert "server" not in ctl.mapping.units_used(), \
        "recovery mapping must avoid the dead server"
    served = sum(o is not None for o in outs)
    assert served == n_frames and not rep.frames_unserved, \
        "every frame must be served after failover"
    for f, (a, b) in enumerate(zip(nominal, outs)):
        assert np.array_equal(np.asarray(a["Head"]), np.asarray(b["Head"])), \
            f"frame {f} diverged after failover — bit-identity broken"

    ev = rep.events[0]
    nominal_fps = n_frames / nom_rep.makespan_s
    degraded_fps = served / rep.makespan_s
    return [
        Row("failover", "nominal_modeled_makespan_s", nom_rep.makespan_s, "s"),
        Row("failover", "degraded_modeled_makespan_s", rep.makespan_s, "s"),
        Row("failover", "nominal_throughput_fps", nominal_fps, "frame/s"),
        Row("failover", "degraded_throughput_fps", degraded_fps, "frame/s"),
        Row("failover", "degraded_vs_nominal", degraded_fps / nominal_fps,
            "x"),
        Row("failover", "recovery_latency_ms", rep.recovery_latency_s * 1e3,
            "ms"),
        Row("failover", "detection_ms", (ev.t_detect_s - ev.t_fail_s) * 1e3,
            "ms"),
        Row("failover", "resynthesis_ms", ev.resynth_s * 1e3, "ms"),
        Row("failover", "frames_replayed", float(len(rep.frames_replayed)),
            "frames"),
        Row("failover", "frames_lost", float(len(rep.frames_unserved)),
            "frames"),
        Row("failover", "failovers", float(rep.num_failovers), ""),
        Row("failover", "bit_identical", 1.0, "bool"),
    ]


def _scheduler_rows(cfg, params, *, n_requests: int, seed: int) -> List[Row]:
    def reqs():
        rng = np.random.RandomState(seed)
        lens = (8, 12, 16, 10)
        return [Request(i, rng.randint(0, cfg.vocab_size,
                                       lens[i % len(lens)]).astype(np.int32),
                        max_new_tokens=4 + i % 5)
                for i in range(n_requests)]

    def drain(failures=None):
        sch = ContinuousScheduler(
            cfg, params, SchedulerConfig(max_slots=max(2, n_requests // 2),
                                         max_len=64),
            failures=failures)
        for r in reqs():
            sch.submit(r)
        return sch, sch.run()

    _, ref = drain()
    sch, out = drain([SlotFailure(step=2, slots=None)])  # whole-unit loss
    fails = [e for e in sch.events if e.kind == "fail"]
    assert fails, "slot failure was not applied"
    identical = all(a.id == b.id and a.tokens == b.tokens
                    for a, b in zip(ref, out))
    assert identical, "re-queued requests must decode bit-identically"
    return [
        Row("failover", "sched_requeued_requests", float(len(fails)), "req"),
        Row("failover", "sched_bit_identical", 1.0, "bool"),
    ]


def _simulator_rows(cfg, params, *, n_frames: int, seed: int,
                    obs: Optional[Observability] = None) -> List[Row]:
    g = T.to_actor_graph(cfg, params, batch=1, seq=SEQ_LEN, group_size=2)
    pg = paper_platform("N270", "ethernet")
    pm = PlatformModel(pg)
    names = list(g.actors)
    mapping = Mapping("half", {nm: ("endpoint" if i < len(names) // 2
                                    else "server")
                               for i, nm in enumerate(names)}, pg)
    rng = np.random.RandomState(seed)
    feed = [jax.numpy.asarray(
        rng.randint(0, cfg.vocab_size, (1, SEQ_LEN)).astype(np.int32))
        for _ in range(n_frames)]
    nom = Simulator(g, mapping=mapping, platform=pm).run(
        n_frames, source_inputs={"Input": feed})
    # Kill the server in the middle of its nominal activity window so
    # in-flight tokens are genuinely lost; revive it at the window's end
    # so the lost frames can replay onto the same mapping.
    sv = [f for f in nom.firings if f.unit == "server"]
    t_kill = (sv[0].start_s + sv[-1].finish_s) / 2
    trace = FailureTrace().kill_unit("server", at=t_kill) \
        .revive_unit("server", at=sv[-1].finish_s)
    res = Simulator(g, mapping=mapping, platform=pm).run(
        n_frames, source_inputs={"Input": feed}, failures=trace)
    if obs is not None and obs.enabled:
        # modeled-clock unit tracks: every firing of the failure run as
        # a complete slice, so the kill/replay gap is visible next to
        # the controller's detection/resynthesis spans
        simulator_trace(obs.tracer, res)
    assert res.frames_replayed, \
        "a mid-activity server kill must lose (and replay) frames"
    assert not res.frames_lost, "revived server must allow full replay"
    for nm_ in nom.outputs:
        assert len(res.outputs[nm_]) == len(nom.outputs[nm_])
        for a, b in zip(nom.outputs[nm_], res.outputs[nm_]):
            assert np.array_equal(np.asarray(a), np.asarray(b))
    return [
        Row("failover", "sim_frames_replayed", float(len(res.frames_replayed)),
            "frames"),
        Row("failover", "sim_downtime_overhead",
            res.modeled_makespan_s / nom.modeled_makespan_s, "x"),
    ]


def run(*, tiny: bool = False, n_frames: Optional[int] = None,
        fail_frac: float = 0.4, seed: int = 0,
        trace_out: Optional[str] = None) -> List[Row]:
    if not 0.0 < fail_frac < 1.0:
        raise ValueError(f"--fail-frac must be in (0, 1), got {fail_frac}")
    cfg = _cfg(tiny)
    n = n_frames or (6 if tiny else 16)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    obs = Observability(enabled=True)
    rows = _controller_rows(cfg, params, n_frames=n, fail_frac=fail_frac,
                            seed=seed, obs=obs)
    rows += _scheduler_rows(cfg, params, n_requests=min(n, 8), seed=seed)
    rows += _simulator_rows(cfg, params, n_frames=min(n, 6), seed=seed,
                            obs=obs)
    # the controller's observability view of the same run: detection /
    # recovery latency histogram summaries as rows (modeled seconds)
    snap = obs.registry.snapshot()
    det = snap["histograms"].get("repro_failover_detection_seconds", {})
    rec = snap["histograms"].get("repro_failover_recovery_seconds", {})
    rows += [
        Row("failover", "obs_failovers_total",
            float(snap["counters"].get("repro_failovers_total", 0)), ""),
        Row("failover", "obs_detection_p50_ms",
            det.get("p50", 0.0) * 1e3, "ms"),
        Row("failover", "obs_recovery_p50_ms",
            rec.get("p50", 0.0) * 1e3, "ms"),
    ]
    if trace_out:
        n_ev = obs.write_trace(trace_out)
        print(f"wrote {trace_out} ({n_ev} trace events, modeled clocks)")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke config (small model, few frames)")
    ap.add_argument("--frames", type=int, default=None)
    ap.add_argument("--fail-frac", type=float, default=0.4,
                    help="inject the server kill at this fraction of the "
                         "nominal modeled makespan")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="write rows as JSON to this path")
    ap.add_argument("--trace-out", default=None,
                    help="write a modeled-clock Chrome trace (simulator "
                         "unit tracks + failover detection/resynthesis "
                         "spans) here")
    args = ap.parse_args()
    rows = run(tiny=args.tiny, n_frames=args.frames,
               fail_frac=args.fail_frac, seed=args.seed,
               trace_out=args.trace_out)
    print(HEADER)
    emit(rows, out_path=args.out)


if __name__ == "__main__":
    main()
