"""Concurrent wall-clock load generator for the HTTP serving front end.

Where ``serving_bench.py`` measures the engine in-process on modeled
arrival clocks, this bench drives the *whole serving stack* — HTTP
parse, bounded admission, background drain thread, chunked streaming —
from real concurrent connections at Poisson arrival rates, and records
the numbers that matter to a serving operator:

* **TTFT p50/p99** — wall-clock time from sending the request to the
  first streamed token line arriving on the socket;
* **inter-token p50/p99** — steady-state gaps between successive token
  lines. The token1->token2 gap is reported separately (``first_gap_s``)
  because it absorbs stream-setup stalls that say nothing about decode
  cadence. The remaining tail is *real*: under continuous batching a
  mid-stream admission's prefill chunks stall decode for everyone in
  the batch — load the ``--trace-out`` file into Perfetto and the p99
  gaps line up with ``prefill chunk`` slices on the neighbouring slot;
* **throughput** — generated tokens per wall-clock second across the
  whole run;
* **shed rate** — the fraction of requests the server refused (429
  backpressure) or expired (``finish_reason="timeout"`` under
  ``--enforce-deadlines``) instead of serving late.

``--sweep R1,R2,...`` additionally re-drives the same trace at each
Poisson rate against the same live server (deadline-free) and persists
the per-rate (rate, TTFT p99, throughput) points plus the saturation
knee — the highest rate whose TTFT p99 stays within 3x of the sweep's
floor — under ``rate_sweep`` in the result JSON.

The result is persisted as JSON (``BENCH_serving.json``) so the serving
perf trajectory is recorded in-repo and regression-gated: ``--baseline``
compares TTFT p99 against a committed run and exits non-zero past
``--max-regression``, and — when both runs carry a ``rate_sweep`` — the
saturation-knee *rate* against ``--max-knee-regression`` (the capacity
gate next to the latency gate; both run in CI nightly). ``--tenants N``
adds the prefix-cache service section: a two-wave multi-tenant
shared-prefix trace whose cross-request hit-rate and replay bytes-saved
land under ``prefix_cache`` and are gated by
``--max-prefix-regression`` when both runs carry the section.

By default the bench self-hosts an ``EngineServer`` on a tiny model and
an ephemeral port (so it runs anywhere, CI included); ``--url`` points
it at an external live server instead.

``python benchmarks/load_bench.py --tiny --out BENCH_serving.json`` is
the CI entrypoint. A fraction of the tiny trace carries tight deadlines
on purpose: the recorded run demonstrates timeout shedding under
contention, while every *non-shed* request must complete cleanly (the
bench exits non-zero otherwise).
"""
from __future__ import annotations

import argparse
import http.client
import json
import sys
import threading
import time
from typing import Any, Dict, List, Optional
from urllib.parse import urlparse

import numpy as np


def _percentiles(xs: List[float]) -> Dict[str, float]:
    if not xs:
        return {"p50": 0.0, "p99": 0.0}
    return {"p50": float(np.percentile(xs, 50)),
            "p99": float(np.percentile(xs, 99))}


class _Result:
    __slots__ = ("id", "status", "ttft_s", "first_gap_s", "gaps_s",
                 "n_tokens", "finish_reason", "error")

    def __init__(self, id):
        self.id = id
        self.status = 0
        self.ttft_s = None
        self.first_gap_s = None
        self.gaps_s: List[float] = []
        self.n_tokens = 0
        self.finish_reason = None
        self.error = None


def _run_one(host: str, port: int, body: Dict[str, Any],
             res: _Result) -> None:
    """One streamed /generate over a fresh connection; fills ``res``
    with per-line wall-clock timings (HTTPResponse decodes the chunked
    framing transparently, so readline() returns one NDJSON line per
    token the moment its chunk lands)."""
    conn = http.client.HTTPConnection(host, port, timeout=120)
    try:
        t_send = time.perf_counter()
        conn.request("POST", "/generate", json.dumps(body),
                     {"Content-Type": "application/json"})
        r = conn.getresponse()
        res.status = r.status
        if r.status != 200:
            r.read()
            return
        prev = None
        while True:
            line = r.readline()
            if not line:
                break
            line = line.strip()
            if not line:
                continue
            now = time.perf_counter()
            obj = json.loads(line)
            if "token" in obj:
                if res.ttft_s is None:
                    res.ttft_s = now - t_send
                elif res.first_gap_s is None:
                    # token1->token2 absorbs stream-setup / chunked-
                    # prefill stalls; keep it out of the steady-state
                    # inter-token series
                    res.first_gap_s = now - prev
                else:
                    res.gaps_s.append(now - prev)
                prev = now
                res.n_tokens += 1
            if obj.get("done"):
                res.finish_reason = obj["finish_reason"]
    except Exception as e:               # noqa: BLE001 — recorded, not fatal
        res.error = f"{type(e).__name__}: {e}"
    finally:
        conn.close()


def _worker(host: str, port: int, jobs: List[tuple], t0: float,
            results: List[_Result]) -> None:
    """Serve this worker's slice of the global Poisson schedule: sleep
    until each arrival instant, fire, stream to completion. A worker
    that falls behind fires late (open-loop degradation under overload —
    exactly what the deadline shed path is for)."""
    for at_s, rid, body in jobs:
        delay = t0 + at_s - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        res = _Result(rid)
        _run_one(host, port, body, res)
        results.append(res)


def _prefix_cache_trace(host: str, port: int, *, tenants: int,
                        max_new: int, vocab: int,
                        seed: int) -> Dict[str, Any]:
    """Prefix-cache service section: a two-wave multi-tenant
    shared-prefix HTTP trace. Wave 1 warms the pool and drains; wave 2
    replays the same per-tenant prompts as cold admissions, so its
    ``victim_hits`` delta (scraped from /status) is exactly the
    cross-request hit count. Returns {} when the server runs without
    the prefix cache."""
    rng = np.random.RandomState(seed)
    names = [f"tenant{t}" for t in range(max(tenants, 1))]
    bodies = []
    for t in names:
        pre = [int(x) for x in rng.randint(1, vocab, 24)]
        for i in range(2):
            tail = [int(x) for x in rng.randint(1, vocab, 6 + 4 * i)]
            bodies.append({"prompt": pre + tail, "max_new_tokens": max_new,
                           "stream": True, "tenant": t})

    def scrape() -> Dict[str, Any]:
        status, raw = _http_get(host, port, "/status")
        if status != 200:
            return {}
        return json.loads(raw).get("prefix_cache") or {}

    prev = scrape()
    if not prev.get("enabled"):
        return {}
    waves = []
    for wave in range(2):
        for i, body in enumerate(bodies):
            res = _Result(f"pc-{wave}-{i}")
            _run_one(host, port, dict(body), res)
            if res.status != 200 or res.error:
                raise RuntimeError(
                    f"prefix-cache trace request failed: status="
                    f"{res.status} error={res.error}")
        cur = scrape()
        waves.append({k: cur.get(k, 0) - prev.get(k, 0)
                      for k in ("victim_hits", "prefix_hits",
                                "prefill_tokens_saved", "bytes_saved")})
        waves[-1]["requests"] = len(bodies)
        prev = cur
    replay = waves[1]
    return {
        "tenants": len(names),
        "victim_cache": bool(prev.get("victim_cache")),
        "waves": waves,
        "cross_request_hit_rate":
            replay["victim_hits"] / max(replay["requests"], 1),
        "replay_bytes_saved": replay["bytes_saved"],
        "per_tenant_bytes": prev.get("per_tenant_bytes", {}),
        "pool": {k: prev.get(k, 0) for k in
                 ("victim_blocks", "victim_bytes", "victim_evictions")},
    }


def _http_get(host: str, port: int, path: str) -> tuple:
    conn = http.client.HTTPConnection(host, port, timeout=30)
    try:
        conn.request("GET", path)
        r = conn.getresponse()
        return r.status, r.read()
    finally:
        conn.close()


def _sweep_knee(points: List[Dict[str, Any]]) -> Dict[str, Any]:
    """The TTFT-p99-vs-throughput knee of a rate sweep: the highest
    offered rate whose TTFT p99 stays within 3x of the best (lowest)
    p99 observed across the sweep — past that point queueing delay
    dominates and p99 departs the service-time floor. Falls back to the
    lowest-rate point when every rate is already past saturation."""
    pts = sorted(points, key=lambda p: p["rate_per_s"])
    floor = min(p["ttft_p99_s"] for p in pts) or 1e-9
    ok = [p for p in pts if p["ttft_p99_s"] <= 3.0 * floor]
    return dict(ok[-1] if ok else pts[0],
                criterion="highest rate with ttft_p99 <= 3x sweep floor")


def _run_sweep(host: str, port: int, rates: List[float], *, n: int,
               max_new: int, workers: int, vocab: int,
               seed: int) -> Dict[str, Any]:
    """Drive the same trace at each Poisson rate against the same live
    server (deadline-free: the sweep charts the pure latency/throughput
    curve, not the shed path) and locate the saturation knee."""
    points = []
    for rate in rates:
        o = run_load(host, port, n=n, rate=rate, max_new=max_new,
                     workers=workers, deadline_s=0.0, deadline_every=0,
                     vocab=vocab, seed=seed)
        pt = {"rate_per_s": rate,
              "ttft_p50_s": o["ttft_s"]["p50"],
              "ttft_p99_s": o["ttft_s"]["p99"],
              "throughput_tok_per_s": o["throughput_tok_per_s"],
              "completed": o["completed"],
              "failed": o["failed"]}
        points.append(pt)
        print(f"sweep @ {rate:g}/s: ttft p99 {pt['ttft_p99_s'] * 1e3:.1f} ms"
              f", {pt['throughput_tok_per_s']:.0f} tok/s", flush=True)
    return {"points": points, "knee": _sweep_knee(points)}


def _poisson_schedule(n: int, rate_per_s: float, seed: int) -> List[float]:
    rng = np.random.RandomState(seed)
    return list(np.cumsum(rng.exponential(1.0 / rate_per_s, size=n)))


def _make_bodies(n: int, *, vocab: int, max_new: int, deadline_s: float,
                 deadline_every: int, seed: int) -> List[Dict[str, Any]]:
    """Mixed-length prompts; every ``deadline_every``-th request carries
    a tight deadline so a contended trace sheds visibly."""
    rng = np.random.RandomState(seed)
    lens = (12, 16, 24, 32)
    bodies = []
    for i in range(n):
        b = {"prompt": [int(t) for t in
                        rng.randint(1, vocab, lens[i % len(lens)])],
             "max_new_tokens": max_new, "stream": True}
        if deadline_every and i % deadline_every == deadline_every - 1:
            b["deadline_s"] = deadline_s
        bodies.append(b)
    return bodies


def run_load(host: str, port: int, *, n: int, rate: float, max_new: int,
             workers: int, deadline_s: float, deadline_every: int,
             vocab: int, seed: int) -> Dict[str, Any]:
    bodies = _make_bodies(n, vocab=vocab, max_new=max_new,
                          deadline_s=deadline_s,
                          deadline_every=deadline_every, seed=seed)
    schedule = _poisson_schedule(n, rate, seed)
    # round-robin the global schedule across workers: each worker's
    # sub-schedule is increasing, so per-worker sequential dispatch
    # preserves every arrival instant
    slices: List[List[tuple]] = [[] for _ in range(workers)]
    for i, (at, body) in enumerate(zip(schedule, bodies)):
        slices[i % workers].append((at, i, body))
    results: List[_Result] = []
    t0 = time.perf_counter()
    threads = [threading.Thread(target=_worker,
                                args=(host, port, jobs, t0, results),
                                daemon=True)
               for jobs in slices if jobs]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0

    ok = [r for r in results if r.status == 200
          and r.finish_reason in ("eos", "length")]
    timeouts = [r for r in results if r.finish_reason == "timeout"]
    rejected = [r for r in results if r.status == 429]
    failed = [r for r in results
              if r not in ok and r not in timeouts and r not in rejected]
    ttfts = [r.ttft_s for r in ok if r.ttft_s is not None]
    first_gaps = [r.first_gap_s for r in ok if r.first_gap_s is not None]
    gaps = [g for r in ok for g in r.gaps_s]
    toks = sum(r.n_tokens for r in results)
    return {
        "requests": n,
        "rate_per_s": rate,
        "max_new_tokens": max_new,
        "workers": workers,
        "wall_s": wall,
        "completed": len(ok),
        "shed_timeout": len(timeouts),
        "rejected_429": len(rejected),
        "failed": len(failed),
        "failed_detail": [
            {"id": r.id, "status": r.status, "finish_reason": r.finish_reason,
             "error": r.error} for r in failed],
        "shed_rate": (len(timeouts) + len(rejected)) / max(n, 1),
        "throughput_tok_per_s": toks / wall,
        "ttft_s": _percentiles(ttfts),
        "first_gap_s": _percentiles(first_gaps),
        "inter_token_s": _percentiles(gaps),
    }


# ---------------------------------------------------------------------------
# self-hosted server (default) / external --url
# ---------------------------------------------------------------------------


def _self_hosted(args):
    """Build the tiny EngineServer this bench drives when no --url is
    given. Deadline enforcement is always on here — the recorded
    trajectory is supposed to show the shed path working — and so is
    observability, so the recorded run carries server-side histogram
    summaries next to the client-side percentiles."""
    import jax

    from repro.models import transformer as T
    from repro.models.config import ModelConfig
    from repro.runtime.engine import Engine, EngineConfig
    from repro.runtime.server import EngineServer, ServerConfig

    cfg = ModelConfig(
        name="load-tiny", arch_type="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256, dtype="float32",
        param_dtype="float32", attn_chunk=16, remat=False)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    ec = EngineConfig.from_args(
        args, max_len=args.max_len,
        admission=args.policy or "edf", enforce_deadlines=True,
        max_slots=args.slots if args.slots != 8 else 2,
        observability=True)
    engine = Engine(cfg, params, ec)
    return EngineServer(engine, ServerConfig(
        port=0, max_inflight=args.max_inflight, max_new_cap=args.max_new))


def main(argv=None) -> int:
    from repro.runtime.engine import EngineConfig

    ap = argparse.ArgumentParser(description=__doc__)
    EngineConfig.add_cli_args(ap)
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized run: small trace against the built-in "
                         "tiny self-hosted server")
    ap.add_argument("--url", default=None,
                    help="drive an external live server instead of "
                         "self-hosting (http://host:port)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--rate", type=float, default=None,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--max-new", type=int, default=None)
    ap.add_argument("--workers", type=int, default=None,
                    help="concurrent client connections")
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-inflight", type=int, default=16,
                    help="self-hosted server admission bound (429 past it)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="deadline carried by every N-th request (see "
                         "--deadline-every); tight by default so the "
                         "contended trace sheds visibly")
    ap.add_argument("--deadline-every", type=int, default=4,
                    help="every N-th request carries --deadline-s "
                         "(0 = no deadlines)")
    ap.add_argument("--sweep", default=None, metavar="R1,R2,...",
                    help="comma-separated Poisson rates (req/s): after "
                         "the main run, drive the same trace at each "
                         "rate against the same server and persist the "
                         "per-rate (rate, TTFT p99, throughput) points "
                         "plus the saturation knee under 'rate_sweep' "
                         "in the result JSON")
    ap.add_argument("--tenants", type=int, default=0,
                    help="run the prefix-cache service section: a "
                         "two-wave multi-tenant shared-prefix trace "
                         "whose cross-request hit-rate and bytes-saved "
                         "land under 'prefix_cache' in the result JSON "
                         "(needs a server with --prefix-cache; pair "
                         "with --victim-cache for cross-drain hits)")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--out", default="BENCH_serving.json",
                    help="write the result JSON here")
    ap.add_argument("--trace-out", default=None,
                    help="fetch the server's Chrome trace (GET /trace) "
                         "after the run, validate it, and write it here "
                         "(load into Perfetto / chrome://tracing)")
    ap.add_argument("--baseline", default=None,
                    help="committed BENCH_serving.json to regression-gate "
                         "TTFT p99 against")
    ap.add_argument("--max-regression", type=float, default=0.20,
                    help="fail if TTFT p99 exceeds baseline by more than "
                         "this fraction")
    ap.add_argument("--max-knee-regression", type=float, default=0.25,
                    help="with --baseline and --sweep: fail if the "
                         "saturation-knee rate drops below the baseline "
                         "knee by more than this fraction (the capacity "
                         "gate next to the latency gate)")
    ap.add_argument("--max-prefix-regression", type=float, default=0.25,
                    help="with --baseline and --tenants: fail if the "
                         "prefix-cache cross-request hit-rate or replay "
                         "bytes-saved drop below the baseline by more "
                         "than this fraction")
    args = ap.parse_args(argv)

    n = args.requests or (24 if args.tiny else 200)
    rate = args.rate or (30.0 if args.tiny else 50.0)
    max_new = args.max_new or (8 if args.tiny else 32)
    workers = args.workers or min(n, 12 if args.tiny else 64)
    deadline_s = args.deadline_s if args.deadline_s is not None \
        else (0.15 if args.tiny else 0.5)

    if args.url:
        u = urlparse(args.url)
        host, port = u.hostname, u.port
        srv = None
    else:
        srv = _self_hosted(args)
        srv.start()
        host, port = srv.config.host, srv.port

    try:
        print(f"load_bench: {n} requests @ {rate}/s, {workers} workers, "
              f"max_new={max_new}, deadline={deadline_s}s every "
              f"{args.deadline_every} -> {host}:{port}", flush=True)
        out = run_load(host, port, n=n, rate=rate, max_new=max_new,
                       workers=workers, deadline_s=deadline_s,
                       deadline_every=args.deadline_every,
                       vocab=256, seed=args.seed)
        if srv is not None:
            out["server_status"] = srv.status()
        # server-side view of the same run: scrape /metrics while the
        # server is still up and keep the histogram summaries next to
        # the client-side percentiles (TTFT should agree to within the
        # HTTP/streaming overhead)
        from repro.serving import parse_prometheus, validate_chrome_trace
        m_status, m_body = _http_get(host, port, "/metrics")
        if m_status == 200:
            parsed = parse_prometheus(m_body.decode())
            out["server_metrics"] = {
                "counters": parsed["counters"],
                "histograms": {
                    name: {"count": h["count"], "sum": h["sum"]}
                    for name, h in parsed["histograms"].items()},
            }
            hists = out.get("server_status", {}).get(
                "metrics", {}).get("histograms", {})
            ttft = hists.get("repro_ttft_seconds")
            if ttft and ttft.get("count"):
                out["server_metrics"]["ttft_s"] = {
                    "p50": ttft["p50"], "p99": ttft["p99"]}
                print(f"server-side TTFT p50={ttft['p50'] * 1e3:.1f} ms "
                      f"p99={ttft['p99'] * 1e3:.1f} ms "
                      f"(client-side p50="
                      f"{out['ttft_s']['p50'] * 1e3:.1f} ms "
                      f"p99={out['ttft_s']['p99'] * 1e3:.1f} ms)")
        if args.trace_out:
            t_status, t_body = _http_get(host, port, "/trace")
            if t_status != 200:
                print(f"FAIL: GET /trace -> {t_status}", file=sys.stderr)
                return 1
            trace = json.loads(t_body)
            n_ev = validate_chrome_trace(trace)
            with open(args.trace_out, "w") as f:
                json.dump(trace, f)
                f.write("\n")
            print(f"wrote {args.trace_out} ({n_ev} trace events)")
        if args.sweep:
            rates = [float(r) for r in args.sweep.split(",") if r.strip()]
            out["rate_sweep"] = _run_sweep(
                host, port, rates, n=n, max_new=max_new, workers=workers,
                vocab=256, seed=args.seed)
            k = out["rate_sweep"]["knee"]
            print(f"sweep knee: {k['rate_per_s']:g}/s "
                  f"(ttft p99 {k['ttft_p99_s'] * 1e3:.1f} ms, "
                  f"{k['throughput_tok_per_s']:.0f} tok/s)")
        if args.tenants:
            pc = _prefix_cache_trace(host, port, tenants=args.tenants,
                                     max_new=max_new, vocab=256,
                                     seed=args.seed + 1)
            if pc:
                out["prefix_cache"] = pc
                print(f"prefix cache: {pc['tenants']} tenants, "
                      f"cross-request hit-rate "
                      f"{pc['cross_request_hit_rate']:.2f}, replay saved "
                      f"{pc['replay_bytes_saved']} B "
                      f"(victim={'on' if pc['victim_cache'] else 'off'})")
            else:
                print("prefix cache: server runs without the prefix "
                      "cache; section skipped", file=sys.stderr)
    finally:
        if srv is not None:
            srv.close()

    print(json.dumps({k: v for k, v in out.items()
                      if k not in ("failed_detail", "server_status")},
                     indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.out}")

    rc = 0
    if out["failed"]:
        print(f"FAIL: {out['failed']} non-shed requests failed: "
              f"{out['failed_detail']}", file=sys.stderr)
        rc = 1
    if args.baseline:
        with open(args.baseline) as f:
            base = json.load(f)
        base_p99 = base["ttft_s"]["p99"]
        cur_p99 = out["ttft_s"]["p99"]
        limit = base_p99 * (1.0 + args.max_regression)
        print(f"TTFT p99: {cur_p99 * 1e3:.1f} ms vs baseline "
              f"{base_p99 * 1e3:.1f} ms (limit {limit * 1e3:.1f} ms)")
        if cur_p99 > limit:
            print(f"FAIL: TTFT p99 regressed past "
                  f"{args.max_regression:.0%}", file=sys.stderr)
            rc = 1
        # capacity gate: the saturation knee (highest rate the server
        # absorbs before TTFT p99 departs the service-time floor) must
        # not slide down vs. the committed run
        base_knee = base.get("rate_sweep", {}).get("knee")
        cur_knee = out.get("rate_sweep", {}).get("knee")
        if base_knee and cur_knee:
            floor = base_knee["rate_per_s"] * (1.0 - args.max_knee_regression)
            print(f"sweep knee: {cur_knee['rate_per_s']:g}/s vs baseline "
                  f"{base_knee['rate_per_s']:g}/s (floor {floor:g}/s)")
            if cur_knee["rate_per_s"] < floor:
                print(f"FAIL: saturation knee regressed past "
                      f"{args.max_knee_regression:.0%} "
                      f"({cur_knee['rate_per_s']:g}/s < {floor:g}/s)",
                      file=sys.stderr)
                rc = 1
        elif base_knee and not cur_knee:
            print("FAIL: baseline has a rate_sweep knee but this run "
                  "was not driven with --sweep", file=sys.stderr)
            rc = 1
        # cache-effectiveness gate: the prefix-cache service's
        # cross-request hit-rate and replay bytes-saved must not slide
        # down vs. the committed run
        base_pc = base.get("prefix_cache")
        cur_pc = out.get("prefix_cache")
        if base_pc and cur_pc:
            for key in ("cross_request_hit_rate", "replay_bytes_saved"):
                floor = base_pc[key] * (1.0 - args.max_prefix_regression)
                print(f"prefix {key}: {cur_pc[key]:g} vs baseline "
                      f"{base_pc[key]:g} (floor {floor:g})")
                if cur_pc[key] < floor:
                    print(f"FAIL: prefix-cache {key} regressed past "
                          f"{args.max_prefix_regression:.0%} "
                          f"({cur_pc[key]:g} < {floor:g})",
                          file=sys.stderr)
                    rc = 1
        elif base_pc and not cur_pc:
            print("FAIL: baseline has a prefix_cache section but this "
                  "run was not driven with --tenants against a "
                  "prefix-cache server", file=sys.stderr)
            rc = 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
