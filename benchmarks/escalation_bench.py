"""Hierarchical-serving benchmark: endpoint-alone vs always-offload vs
confidence-gated escalation, plus link-cut recovery.

The paper's collaborative-inference claim, measured on the serving
path: a small endpoint engine (1 decode slot — the low-resource device)
fronts a bigger server engine (8 slots) through ``runtime.escalation``,
and the same Poisson trace is driven through three configurations.
Both tiers share one host, so the endpoint's slower silicon is modeled
with a per-step wall-clock handicap (``--endpoint-step-delay-ms`` ->
``EngineConfig.step_delay_s``); token content is bit-identical, only
the endpoint's real elapsed time stretches — without it a tiny model
on one CPU gives the 8-slot tier no true capacity advantage and every
routing mode converges to the same wall latency.

* ``local-only`` — the ``never`` policy: the endpoint answers
  everything itself (the paper's endpoint-alone baseline, and the
  privacy-maximal configuration);
* ``always-escalate`` — every request ships to the server tier
  (the always-offload baseline);
* ``confidence-gated`` — ``confidence`` + ``overload``: the endpoint
  keeps what it is sure about and its queue can absorb, escalates the
  hard residue.

Reported per mode: answered-within-deadline rate (the serving-side
quality metric), mean/percentile latency, and the **escalated
fraction** — how much traffic ever left the device, the privacy metric
of the partitioning papers. The bench asserts the acceptance criteria:
confidence-gated must beat local-only on answered-within-deadline rate
while escalating strictly less than 100% of traffic.

The second phase cuts the endpoint<->server link with an injected
``resilience.FailureTrace`` while deadline-free requests are in flight:
they wait durably in the on-disk escalation journal, and on revival the
journal replays in order with **zero lost requests**; the bench measures
``recovery_s`` (revival -> journal drained) and asserts it lands within
the recovery window, and that the fail-back was counted.

``--tiny`` is the CI fast-lane configuration; ``--out`` writes the
result JSON, and ``--merge-bench BENCH_serving.json`` folds it under
that file's ``"escalation"`` key (a new top-level key — the nightly
load_bench gate reads ``ttft_s``/``rate_sweep`` and is unaffected).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Any, Dict, List

import numpy as np


def _build(args):
    import jax

    from repro.models import transformer as T
    from repro.models.config import ModelConfig

    cfg = ModelConfig(
        name="esc-tiny", arch_type="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256, dtype="float32",
        param_dtype="float32", attn_chunk=16, remat=False)
    # one set of params for both tiers: escalated completions stay
    # bit-identical to local ones, so the quality axis is isolated to
    # *where* requests run (capacity), which is what this bench measures
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


def _trace(n: int, *, vocab: int, max_new: int, deadline_s: float,
           seed: int, rate: float):
    rng = np.random.RandomState(seed)
    lens = (6, 8, 10, 12)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
    reqs = []
    for i in range(n):
        reqs.append({"prompt": rng.randint(1, vocab, lens[i % len(lens)])
                     .astype(np.int32),
                     "max_new_tokens": max_new, "deadline_s": deadline_s})
    return list(zip(arrivals, reqs))


def _drive(tiered, trace, *, deadline_s: float) -> Dict[str, Any]:
    """Submit the trace open-loop on its arrival schedule, wait for
    everything, and score answered-within-deadline on wall latency."""
    from repro.serving import Request

    t0 = time.perf_counter()
    handles = []
    for at_s, spec in trace:
        delay = t0 + at_s - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        h = tiered.submit(Request(
            id=len(handles), prompt=spec["prompt"],
            max_new_tokens=spec["max_new_tokens"],
            deadline_s=spec["deadline_s"]))
        handles.append((time.perf_counter(), h))
    lat, in_deadline, escalated, fallbacks = [], 0, 0, 0
    for sent_at, h in handles:
        c = h.result(120)
        wall = time.perf_counter() - sent_at if c.finish_reason != "timeout" \
            else float("inf")
        lat.append(min(wall, 1e9))
        answered = c.finish_reason in ("eos", "length", "local_fallback")
        if answered and wall <= deadline_s:
            in_deadline += 1
        if h.tier not in (None, tiered.config.tier):
            escalated += 1
        if c.finish_reason == "local_fallback":
            fallbacks += 1
    finite = [x for x in lat if x != float("inf")]
    n = len(handles)
    return {
        "requests": n,
        "answered_within_deadline": in_deadline,
        "answered_within_deadline_rate": in_deadline / n,
        "escalated": escalated,
        "escalated_fraction": escalated / n,
        "local_fallbacks": fallbacks,
        "latency_mean_s": float(np.mean(finite)) if finite else 0.0,
        "latency_p99_s": float(np.percentile(finite, 99)) if finite else 0.0,
    }


def _make_tiered(cfg, params, *, policies, journal_dir, transport_wrap=None,
                 endpoint_slots: int, server_slots: int, max_len: int,
                 endpoint_step_delay_s: float = 0.0):
    from repro.runtime.escalation import (InProcessTransport, TieredConfig,
                                          TieredEngine)
    from repro.serving import Engine, EngineConfig

    # the endpoint is the paper's low-resource device; both tiers share
    # one host here, so its slower silicon is emulated with a per-step
    # wall-clock handicap (content-neutral — tokens stay bit-identical)
    local = Engine(cfg, params, EngineConfig(
        max_slots=endpoint_slots, max_len=max_len, observability=True,
        step_delay_s=endpoint_step_delay_s))
    server = Engine(cfg, params, EngineConfig(
        max_slots=server_slots, max_len=max_len)).start()
    transport = InProcessTransport(server)
    if transport_wrap is not None:
        transport = transport_wrap(transport)
    # replay window = server slots: a send blocks until its completion,
    # so the window is the server tier's effective concurrency — leave
    # it below the slot count and the bench throttles the big tier's
    # batching advantage to the window
    tiered = TieredEngine(local, transport, TieredConfig(
        policies=policies, journal_dir=journal_dir,
        replay_window=server_slots,
        max_sends_per_pump=2 * server_slots)).start()
    return tiered, server


def _calibrate_threshold(cfg, params, trace, *, max_len: int) -> float:
    """Median next-token confidence over the trace's prompts: the
    operating point where roughly half the traffic is 'hard residue'.
    A real deployment tunes this against a quality target; the bench
    just needs a gate that splits the traffic, whatever the model."""
    import jax

    from repro.models import transformer as T

    @jax.jit
    def probe(tokens):
        logits, _, _ = T.prefill(params, cfg, {"tokens": tokens},
                                 max_len=max_len)
        return jax.numpy.max(jax.nn.softmax(logits[0]))

    confs = sorted(float(probe(spec["prompt"][None, :]))
                   for _, spec in trace)
    return confs[len(confs) // 2]


def _mode(name, cfg, params, trace, *, policies, deadline_s, root,
          endpoint_slots, server_slots, max_len,
          endpoint_step_delay_s) -> Dict[str, Any]:
    tiered, server = _make_tiered(
        cfg, params, policies=policies, journal_dir=f"{root}/{name}",
        endpoint_slots=endpoint_slots, server_slots=server_slots,
        max_len=max_len, endpoint_step_delay_s=endpoint_step_delay_s)
    try:
        # warm BOTH tiers (+ the confidence probe) outside the timed
        # window, submitting to each engine DIRECTLY — warming through
        # tiered.submit() routes by policy, and a confidence gate can
        # escalate every warmup prompt, leaving the local tier to pay
        # its JIT compiles mid-run: latency differences must come from
        # capacity, not from who paid the compile
        from repro.serving import Request
        for L in {len(spec["prompt"]) for _, spec in trace}:
            tiered.local.submit(Request(
                id=-L, prompt=np.ones(L, np.int32),
                max_new_tokens=2)).result(120)
            server.submit(Request(id=-1000 - L, prompt=np.ones(L, np.int32),
                                  max_new_tokens=2)).result(120)
            tiered._confidence(Request(id=-2000 - L,
                                       prompt=np.ones(L, np.int32),
                                       max_new_tokens=2))
        tiered.local.obs.registry.reset_histograms()
        out = _drive(tiered, trace, deadline_s=deadline_s)
        out["policies"] = [getattr(p, "name", str(p))
                           for p in tiered.policies]
        out["escalation_stats"] = tiered.escalation_stats()
        from repro.serving import parse_prometheus
        m = parse_prometheus(tiered.metrics_text())
        out["metrics"] = {
            "escalated_total": m["counters"]["repro_escalated_total"],
            "local_fallback_total":
                m["counters"]["repro_local_fallback_total"],
            "failback_total": m["counters"]["repro_failback_total"],
            "escalation_queue_depth":
                m["gauges"]["repro_escalation_queue_depth"],
        }
        return out
    finally:
        tiered.shutdown()
        server.shutdown()


def _link_cut_phase(cfg, params, *, root, n: int, cut_after_s: float,
                    down_s: float, recovery_window_s: float,
                    endpoint_slots, server_slots, max_len) -> Dict[str, Any]:
    """Escalate deadline-free requests straight into a link cut; measure
    journal drain after revival."""
    from repro.runtime.escalation import FlakyTransport
    from repro.runtime.resilience import FailureTrace
    from repro.serving import Request

    cut = FailureTrace()                # scheduled after warmup, below
    tiered, server = _make_tiered(
        cfg, params, policies=("always",), journal_dir=f"{root}/linkcut",
        transport_wrap=lambda t: FlakyTransport(t, cut),
        endpoint_slots=endpoint_slots, server_slots=server_slots,
        max_len=max_len)
    try:
        # warm the server tier before the cut so post-revival replay
        # measures protocol recovery, not JIT compile time
        tiered.submit(Request(id=-1, prompt=np.ones(6, np.int32),
                              max_new_tokens=2)).result(120)
        # schedule the cut relative to the warmed clock (compile time
        # varies run to run; the trace is absolute)
        kill_at = tiered.now() + cut_after_s
        revive_at = kill_at + down_s
        cut.kill_link("endpoint", "server", at=kill_at) \
           .revive_link("endpoint", "server", at=revive_at)
        while tiered.now() < kill_at:
            time.sleep(0.005)
        # the link is now down: these journal durably (no deadlines)
        rng = np.random.RandomState(3)
        handles = [tiered.submit(Request(
            id=i, prompt=rng.randint(1, 256, 6).astype(np.int32),
            max_new_tokens=4)) for i in range(n)]
        stranded = tiered.journal.depth
        while tiered.now() < revive_at:
            time.sleep(0.005)
        results = [h.result(60 + recovery_window_s) for h in handles]
        drained_at = tiered.now()
        stats = tiered.escalation_stats()
        lost = [h.request.id for h, c in zip(handles, results)
                if c.finish_reason not in ("eos", "length")]
        return {
            "requests": n,
            "stranded_in_journal": stranded,
            "lost": lost,
            "recovery_s": max(drained_at - revive_at, 0.0),
            "recovery_window_s": recovery_window_s,
            "failback_total": stats["failback"],
            "queue_depth_after": stats["queue_depth"],
        }
    finally:
        tiered.shutdown()
        server.shutdown()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized run (fast lane)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--rate", type=float, default=None,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--max-new", type=int, default=None)
    ap.add_argument("--deadline-s", type=float, default=None)
    ap.add_argument("--endpoint-slots", type=int, default=1)
    ap.add_argument("--server-slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--endpoint-step-delay-ms", type=float, default=None,
                    help="per-step wall-clock handicap on the endpoint "
                         "engine — models the slow edge device when both "
                         "tiers share one host (default: 15ms tiny, "
                         "8ms full)")
    ap.add_argument("--confidence-threshold", type=float, default=None,
                    help="override the confidence gate (default: policy "
                         "default)")
    ap.add_argument("--recovery-window-s", type=float, default=10.0,
                    help="link-cut phase must drain the journal within "
                         "this many seconds of revival")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--out", default="BENCH_escalation.json")
    ap.add_argument("--merge-bench", default=None, metavar="BENCH.json",
                    help="also fold the result under this JSON's "
                         "'escalation' key (top-level keys the nightly "
                         "gate reads are untouched)")
    args = ap.parse_args(argv)

    n = args.requests or (18 if args.tiny else 96)
    rate = args.rate or (24.0 if args.tiny else 40.0)
    max_new = args.max_new or (6 if args.tiny else 16)
    deadline_s = args.deadline_s or (0.6 if args.tiny else 1.5)
    delay_ms = args.endpoint_step_delay_ms
    if delay_ms is None:
        delay_ms = 15.0 if args.tiny else 8.0
    endpoint_step_delay_s = delay_ms / 1e3

    import tempfile
    root = tempfile.mkdtemp(prefix="esc-bench-")
    cfg, params = _build(args)
    trace = _trace(n, vocab=256, max_new=max_new, deadline_s=deadline_s,
                   seed=args.seed, rate=rate)

    from repro.runtime.policies import (ConfidenceEscalation,
                                        LocalOverloadEscalation)
    threshold = args.confidence_threshold
    if threshold is None:
        threshold = _calibrate_threshold(cfg, params, trace,
                                         max_len=args.max_len)
        print(f"calibrated confidence threshold: {threshold:.4f} "
              f"(trace median)", flush=True)
    gate = [ConfidenceEscalation(threshold),
            LocalOverloadEscalation(max_queue_depth=1)]

    print(f"escalation_bench: {n} requests @ {rate}/s, max_new={max_new}, "
          f"deadline={deadline_s}s, endpoint={args.endpoint_slots} slot(s) "
          f"@ +{delay_ms:.0f}ms/step vs server={args.server_slots}",
          flush=True)
    modes = {}
    for name, policies in (("local_only", ("never",)),
                           ("always_escalate", ("always",)),
                           ("confidence_gated", gate)):
        modes[name] = _mode(
            name, cfg, params, trace, policies=policies,
            deadline_s=deadline_s, root=root,
            endpoint_slots=args.endpoint_slots,
            server_slots=args.server_slots, max_len=args.max_len,
            endpoint_step_delay_s=endpoint_step_delay_s)
        m = modes[name]
        print(f"  {name:18s}: answered-in-deadline "
              f"{m['answered_within_deadline']}/{n} "
              f"({m['answered_within_deadline_rate']:.0%}), escalated "
              f"{m['escalated_fraction']:.0%}, mean latency "
              f"{m['latency_mean_s'] * 1e3:.0f} ms", flush=True)

    linkcut = _link_cut_phase(
        cfg, params, root=root, n=min(n, 8), cut_after_s=0.3, down_s=1.0,
        recovery_window_s=args.recovery_window_s,
        endpoint_slots=args.endpoint_slots,
        server_slots=args.server_slots, max_len=args.max_len)
    print(f"  link cut: {linkcut['stranded_in_journal']} stranded, "
          f"{len(linkcut['lost'])} lost, recovery "
          f"{linkcut['recovery_s']:.2f}s "
          f"(window {linkcut['recovery_window_s']:.0f}s), failbacks "
          f"{linkcut['failback_total']}", flush=True)

    local, gated = modes["local_only"], modes["confidence_gated"]
    speedup = (local["latency_mean_s"] / gated["latency_mean_s"]
               if gated["latency_mean_s"] else 0.0)
    out = {
        "requests": n, "rate_per_s": rate, "max_new_tokens": max_new,
        "deadline_s": deadline_s,
        "endpoint_slots": args.endpoint_slots,
        "server_slots": args.server_slots,
        "endpoint_step_delay_ms": delay_ms,
        "modes": modes,
        "endpoint_speedup_vs_local_only": speedup,
        "privacy_fraction_local": 1.0 - gated["escalated_fraction"],
        "link_cut": linkcut,
    }
    print(f"  gated vs local-only: {speedup:.2f}x mean-latency speedup, "
          f"{out['privacy_fraction_local']:.0%} of traffic stayed "
          f"on-device", flush=True)

    rc = 0
    # acceptance: gated beats local-only on answered-within-deadline
    # while escalating strictly less than everything
    if gated["answered_within_deadline"] \
            <= local["answered_within_deadline"] \
            and gated["answered_within_deadline"] < n:
        print("FAIL: confidence-gated did not beat local-only on "
              "answered-within-deadline "
              f"({gated['answered_within_deadline']} vs "
              f"{local['answered_within_deadline']})", file=sys.stderr)
        rc = 1
    if not gated["escalated_fraction"] < 1.0:
        print("FAIL: confidence-gated escalated 100% of traffic "
              "(no privacy benefit over always-escalate)", file=sys.stderr)
        rc = 1
    if local["escalated"] != 0:
        print("FAIL: local-only escalated traffic", file=sys.stderr)
        rc = 1
    # acceptance: zero lost across the link cut, bounded recovery,
    # fail-back observed
    if linkcut["lost"]:
        print(f"FAIL: requests lost across the link cut: "
              f"{linkcut['lost']}", file=sys.stderr)
        rc = 1
    if linkcut["recovery_s"] > linkcut["recovery_window_s"]:
        print(f"FAIL: journal recovery took {linkcut['recovery_s']:.2f}s "
              f"> window {linkcut['recovery_window_s']:.0f}s",
              file=sys.stderr)
        rc = 1
    if linkcut["failback_total"] < 1:
        print("FAIL: no fail-back counted after link revival",
              file=sys.stderr)
        rc = 1

    if args.out:
        with open(args.out, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.out}")
    if args.merge_bench:
        with open(args.merge_bench) as f:
            bench = json.load(f)
        bench["escalation"] = out
        with open(args.merge_bench, "w") as f:
            json.dump(bench, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"merged under 'escalation' in {args.merge_bench}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
