"""Sec IV.C: dual-input vehicle classification across three devices
(N2 + N270 endpoints, i7 server). Paper: 49 ms on N270, 154 ms on N2,
157 ms on the server (pipelined steady-state per-frame busy times)."""
from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.core import Mapping, PlatformModel, paper_platform, synthesize
from repro.core import calibration as cal
from repro.models.cnn import dual_input_vehicle_graph


def run() -> List[Row]:
    g = dual_input_vehicle_graph()
    # build the paper's three-device platform: N2 + N270 + i7
    pg = paper_platform("N2", "ethernet")
    n2 = pg.units["endpoint"]
    pg270 = paper_platform("N270", "ethernet")
    from repro.core.mapping import Link, PlatformGraph, ProcessingUnit
    plat = PlatformGraph("dual")
    plat.add_unit(ProcessingUnit("n2", n2.kind, n2.flops, n2.mem_bandwidth,
                                 n2.firing_overhead_s))
    n270 = pg270.units["endpoint"]
    plat.add_unit(ProcessingUnit("n270", n270.kind, n270.flops,
                                 n270.mem_bandwidth, n270.firing_overhead_s))
    srv = pg.units["server"]
    plat.add_unit(ProcessingUnit("server", srv.kind, srv.flops,
                                 srv.mem_bandwidth, srv.firing_overhead_s))
    eth = pg.links[frozenset(("endpoint", "server"))]
    plat.add_link(Link("n2", "server", eth.bandwidth, eth.latency_s))
    plat.add_link(Link("n270", "server", eth.bandwidth, eth.latency_s))

    assignment = {"Input.1": "n2", "L1.1": "n2", "L2.1": "n2", "L3.1": "n2",
                  "Input.2": "n270", "L1.2": "server", "L2.2": "server",
                  "L3.2": "server", "L4L5": "server"}
    prog = synthesize(g, Mapping("dual", assignment, plat))
    model = PlatformModel(plat)

    def unit_busy(unit: str) -> float:
        compute = sum(model.actor_time_s(unit, a)
                      for a in g.actors.values()
                      if assignment[a.name] == unit)
        tx = sum(model.transfer_bw_time_s(c.src_unit, c.dst_unit,
                                          c.token_bytes)
                 for c in prog.channels if c.src_unit == unit)
        return compute + tx

    # Structural validation is exact (2 boundary channels, 3 stages, the
    # fan-in join). The paper's absolute per-device times (49/154/157 ms)
    # are NOT derivable from its published device constants: they exceed
    # the single-input N2 pipeline time (19 ms) by ~8x, implying
    # synchronization / frame-sync stalls Sec IV.C does not specify. We
    # therefore report the modeled busy times without anchors and record
    # one derivable consistency check: the paper's N2 and server times are
    # nearly equal (154 vs 157), and so are our modeled busy-time shares
    # once both instances run in lockstep. See EXPERIMENTS.md §Dual-input.
    rows = [
        Row("dual_input", "n2_busy_ms", unit_busy("n2") * 1e3, "ms"),
        Row("dual_input", "server_busy_ms", unit_busy("server") * 1e3, "ms"),
        Row("dual_input", "n270_busy_ms", unit_busy("n270") * 1e3, "ms"),
        Row("dual_input", "n2_vs_server_busy_ratio",
            unit_busy("n2") / (unit_busy("server") + unit_busy("n270")), "",
            paper=154.0 / 157.0),
        Row("dual_input", "channels", len(prog.channels), "n", paper=2),
        Row("dual_input", "stages", len(prog.stages), "n", paper=3),
    ]
    return rows
