"""Stable public serving surface.

``repro.serving`` is the supported import path for everything a serving
caller needs — the policy-configured ``Engine`` facade, its config, the
request/handle/completion lifecycle types, the HTTP front end, and the
closed set of ``finish_reason`` values:

    from repro.serving import Engine, EngineConfig, Request

    eng = Engine(cfg, params, EngineConfig(admission="fifo")).start()
    handle = eng.submit(Request(id=0, prompt=prompt, max_new_tokens=32))
    for tok in handle.stream():
        ...

Hierarchical serving (``runtime.escalation``) is re-exported here too:
``TieredEngine`` fronts a local ``Engine`` plus a remote tier behind a
transport, with a durable on-disk escalation journal.

Deep imports (``repro.runtime.engine``, ``repro.runtime.scheduler``)
keep working — this package only re-exports — but docs and examples use
this path so internal module reshuffles never break callers. The legacy
``ServeEngine`` kwarg shim stays importable from ``repro.runtime.serving``
with a ``DeprecationWarning``.
"""
from repro.runtime.engine import Engine, EngineConfig, RequestHandle
from repro.runtime.escalation import (EscalationJournal, FlakyTransport,
                                      HttpTransport, InProcessTransport,
                                      JournalReplayer, LinkDown, TieredConfig,
                                      TieredEngine, TieredHandle)
from repro.runtime.observability import (MetricsRegistry, Observability,
                                         Tracer, parse_prometheus,
                                         validate_chrome_trace)
from repro.runtime.scheduler import (FINISH_REASONS, Completion, Request,
                                     SlotFailure)
from repro.runtime.server import EngineServer, ServerConfig

__all__ = [
    "Engine",
    "EngineConfig",
    "Request",
    "RequestHandle",
    "Completion",
    "SlotFailure",
    "FINISH_REASONS",
    "EngineServer",
    "ServerConfig",
    "TieredEngine",
    "TieredConfig",
    "TieredHandle",
    "EscalationJournal",
    "JournalReplayer",
    "InProcessTransport",
    "HttpTransport",
    "FlakyTransport",
    "LinkDown",
    "Observability",
    "MetricsRegistry",
    "Tracer",
    "parse_prometheus",
    "validate_chrome_trace",
]
