"""chatglm3-6b [dense]: 28L, d_model=4096, 32H GQA kv=2, d_ff=13696,
vocab=65024, 2d-RoPE (rotary on half of each head's dims,
rope_fraction=0.5), QKV bias [arXiv:2406.12793].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", arch_type="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab_size=65024,
    layer_pattern=("attn",),
    qkv_bias=True, rope_fraction=0.5,
)
