"""The four assigned input shapes and per-(arch, shape) input specs.

``input_specs`` returns ``jax.ShapeDtypeStruct`` stand-ins for every model
input — weak-type-correct, shardable, zero allocation — which is what the
multi-pod dry-run lowers against.

Shape semantics:
  train_4k     seq 4096,   global_batch 256 — train_step
  prefill_32k  seq 32768,  global_batch 32  — prefill_step (prompt pass)
  decode_32k   seq 32768,  global_batch 128 — serve_step (1 token, full cache)
  long_500k    seq 524288, global_batch 1   — serve_step, sub-quadratic only

long_500k eligibility: archs with at least one non-global-attention
mechanism (recurrent state or sliding window) run it — the global layers
of gemma3 are O(L) per decode step and its windowed layers bound 5/6 of
the cache; pure full-attention archs are skipped (DESIGN.md §4).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def supports_long_context(cfg: ModelConfig) -> bool:
    return any(k != "attn" for k in cfg.layer_kinds)


def shape_applicable(cfg: ModelConfig, shape: str) -> bool:
    if shape == "long_500k":
        return supports_long_context(cfg)
    return True


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def input_specs(cfg: ModelConfig, shape: str,
                batch_override: Optional[int] = None) -> Dict[str, object]:
    """Model-input ShapeDtypeStructs for (arch, shape).

    train/prefill return the batch dict consumed by forward()/prefill();
    decode returns {"token", "cache_len"} — the cache spec comes from
    ``jax.eval_shape(init_cache, ...)`` in the launcher (it is state, not
    input, and its shape follows the config + context length).
    """
    s = SHAPES[shape]
    b = batch_override or s.global_batch
    seq = s.seq_len

    if s.kind in ("train", "prefill"):
        specs: Dict[str, object] = {}
        if cfg.arch_type == "vlm":
            ft = cfg.frontend_tokens
            specs["embeds"] = _f32(b, ft, cfg.frontend_dim)
            specs["tokens"] = _i32(b, seq - ft)
        elif cfg.arch_type == "audio":
            # encoder consumes seq frames; decoder consumes seq tokens
            specs["embeds"] = _f32(b, seq, cfg.frontend_dim)
            specs["tokens"] = _i32(b, seq)
        else:
            specs["tokens"] = _i32(b, seq)
        if s.kind == "train":
            specs["labels"] = _i32(b, seq)
        return specs

    return {"token": _i32(b), "cache_len": _i32(b)}


def decode_context(cfg: ModelConfig, shape: str) -> Dict[str, int]:
    """Cache geometry for decode shapes: max context + encoder src length."""
    s = SHAPES[shape]
    src = s.seq_len if cfg.arch_type == "audio" else 0
    return {"batch": s.global_batch, "max_len": s.seq_len, "src_len": src}
