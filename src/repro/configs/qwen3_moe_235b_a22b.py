"""qwen3-moe-235b-a22b [moe]: 94L, d_model=4096, 64H GQA kv=4
(head_dim=128), vocab=151936, MoE: 128 routed experts top-8 (no shared),
expert d_ff=1536 [hf:Qwen/Qwen3 family]. The largest assigned config —
only ever lowered via the dry-run.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", arch_type="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=0, vocab_size=151936,
    layer_pattern=("attn",),
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536),
    rope_theta=1_000_000.0,
)
