"""llava-next-mistral-7b [vlm]: Mistral-7B decoder backbone, 32L,
d_model=4096, 32H GQA kv=8, d_ff=14336, vocab=32000
[hf:llava-hf/llava-v1.6-mistral-7b-hf]. The SigLIP/CLIP ViT + anyres
tiling frontend is the allowed stub: input_specs provides (B, 2880, 1024)
patch embeddings (anyres 4+1 tiles x 576); the 2-layer MLP projector IS
implemented (it belongs to the LM side).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", arch_type="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=32000,
    layer_pattern=("attn",),
    frontend="vision", frontend_dim=1024, frontend_tokens=2880,
    rope_theta=1_000_000.0,
)
