"""llama3.2-3b [dense]: 28L, d_model=3072, 24H GQA kv=8, d_ff=8192,
vocab=128256, RoPE theta 500k, tied embeddings [hf:meta-llama/Llama-3.2].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b", arch_type="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab_size=128256,
    layer_pattern=("attn",),
    rope_theta=500_000.0, tie_embeddings=True,
)
