"""xlstm-350m [ssm]: 24 xLSTM blocks, d_model=1024, 4 heads, no separate
MLP (d_ff=0; the blocks embed their own projections), vocab=50304
[arXiv:2405.04517]. Block ratio mLSTM:sLSTM = 7:1 (the paper's xLSTM[7:1]),
24 = 3 periods of 8.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", arch_type="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    layer_pattern=("mlstm",) * 7 + ("slstm",),
    act="gelu",
)
