"""seamless-m4t-medium [audio]: enc-dec speech/text transformer backbone.
12 encoder + 12 decoder layers, d_model=1024, 16 heads (MHA), d_ff=4096,
vocab=256206 [arXiv:2308.11596]. The mel-spectrogram + conformer frontend
is the allowed stub: input_specs provides (B, S, 1024) frame embeddings.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", arch_type="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=256206,
    layer_pattern=("attn",),
    n_encoder_layers=12,
    frontend="audio", frontend_dim=1024,
    act="gelu",
)
