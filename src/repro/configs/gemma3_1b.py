"""gemma3-1b [dense]: 26L, d_model=1152, 4H GQA kv=1 (head_dim=256),
d_ff=6912, vocab=262144, 5:1 local:global attention (window 512),
RoPE theta 10k local / 1M global, tied embeddings
[hf:google/gemma-3-1b-pt]. 26 = 4 periods of 6 + 2 remainder locals.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", arch_type="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab_size=262144,
    layer_pattern=("attn_local",) * 5 + ("attn",), window=512,
    rope_theta=10_000.0, rope_theta_global=1_000_000.0,
    act="gelu", tie_embeddings=True,
)
