"""qwen2-moe-a2.7b [moe]: 24L, d_model=2048, 16H MHA, vocab=151936,
MoE: 60 routed experts top-4 + 4 shared experts, expert d_ff=1408
[hf:Qwen/Qwen1.5-MoE-A2.7B]. QKV bias per Qwen1.5.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", arch_type="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=0, vocab_size=151936,
    layer_pattern=("attn",),
    moe=MoEConfig(n_experts=60, top_k=4, d_ff_expert=1408,
                  n_shared_experts=4),
    qkv_bias=True, rope_theta=1_000_000.0,
)
