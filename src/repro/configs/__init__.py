"""Architecture registry: ``--arch <id>`` lookup for the 10 assigned
architectures (+ the paper's own CNN graphs, exposed via models.cnn).

Every config cites its source in its module docstring. ``get_config``
returns the full production ModelConfig; ``get_config(name).smoke()``
returns the reduced same-family variant used by CPU smoke tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS: List[str] = [
    "seamless_m4t_medium",
    "qwen2_moe_a2_7b",
    "llava_next_mistral_7b",
    "recurrentgemma_9b",
    "gemma3_1b",
    "llama3_2_3b",
    "qwen3_moe_235b_a22b",
    "qwen2_1_5b",
    "xlstm_350m",
    "chatglm3_6b",
]

_ALIASES = {
    "seamless-m4t-medium": "seamless_m4t_medium",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "gemma3-1b": "gemma3_1b",
    "llama3.2-3b": "llama3_2_3b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "qwen2-1.5b": "qwen2_1_5b",
    "xlstm-350m": "xlstm_350m",
    "chatglm3-6b": "chatglm3_6b",
}


def canonical(name: str) -> str:
    return _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
