"""recurrentgemma-9b [hybrid]: Griffin architecture, 38 layers in a
(RG-LRU, RG-LRU, local-attention) 2:1 pattern, d_model=4096,
16H MQA (kv=1, head_dim=256), d_ff=12288, local window 2048,
vocab=256000 [arXiv:2402.19427]. 38 = 12 periods + 2 remainder RG-LRU.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", arch_type="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab_size=256000,
    layer_pattern=("rglru", "rglru", "attn_local"), window=2048,
    act="gelu", tie_embeddings=True,
)
