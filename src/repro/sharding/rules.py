"""Role-based PartitionSpec derivation with divisibility fallback.

Every parameter / cache / batch leaf gets a *candidate list* of specs
derived from its pytree path (its role) and rank; the first candidate
whose named axes all divide the corresponding dims (and use each mesh
axis at most once) wins, otherwise the leaf falls back down the list and
ultimately to replication. This is what makes ONE rule set serve all 10
architectures on both the (data=16, model=16) pod mesh and the
(pod=2, data=16, model=16) multi-pod mesh:

* qwen3-moe: 128 experts % 16 == 0 -> expert-parallel over "model".
* qwen2-moe: 60 experts % 16 != 0 -> the same rule falls through to
  per-expert tensor parallelism (d_ff_expert over "model").
* MQA (kv=1): wk/wv head dim unshardable -> falls back to d_model/"data".
* long_500k (batch=1): KV cache batch unshardable -> falls back to
  sequence sharding over ("data","model") — XLA then lowers the decode
  attention softmax as a sharded reduction (flash-decode analogue).

Weights use 2D sharding (FSDP over "data" x TP over "model"); the batch
shards over ("pod","data") — data parallel across pods over DCN, FSDP +
TP inside the pod over ICI. This mirrors Edge-PRUNE's principle that
distribution is a *mapping decision* external to the model definition.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Optional[object]   # axis name, tuple of names, or None


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def _fits(spec: Sequence[Axis], shape: Tuple[int, ...], mesh: Mesh) -> bool:
    if len(spec) != len(shape):
        return False
    used: List[str] = []
    for axis, dim in zip(spec, shape):
        if axis is None:
            continue
        names = axis if isinstance(axis, tuple) else (axis,)
        for n in names:
            if n not in mesh.shape or n in used:
                return False
            used.append(n)
        if dim % _axis_size(mesh, axis):
            return False
    return True


def _resolve(cands: List[Tuple[Axis, ...]], shape: Tuple[int, ...],
             mesh: Mesh) -> P:
    for c in cands:
        if _fits(c, shape, mesh):
            return P(*c)
    return P()   # replicate


def batch_axes(mesh: Mesh):
    """The meta-axis the global batch shards over."""
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


# ---------------------------------------------------------------------------
# parameter rules (matched against the flattened pytree path string)
# ---------------------------------------------------------------------------

_PARAM_RULES: List[Tuple[str, List[Tuple[Axis, ...]]]] = [
    # --- MoE expert banks (E, D, F) / (E, F, D): expert-parallel first,
    # then per-expert TP on the ff dim, then FSDP-only.
    (r"moe.*w_(gate|up)$", [("model", "data", None), (None, "data", "model"),
                            (None, None, "model"), (None, "data", None)]),
    (r"moe.*w_down$", [("model", None, "data"), (None, "model", "data"),
                       (None, "model", None), (None, None, "data")]),
    (r"moe.*router$", [("data", None), (None, None)]),
    # shared experts are ordinary MLPs (matched by the generic mlp rules)
    # --- attention projections
    (r"w[qkv]$", [("data", "model", None), (None, "model", None),
                  ("data", None, None)]),
    (r"wo$", [("model", None, "data"), ("model", None, None),
              (None, None, "data")]),
    (r"b[qkv]$", [("model", None), (None, None)]),
    # --- gated MLP
    (r"w_(gate|up)$", [("data", "model"), (None, "model"), ("data", None)]),
    (r"w_down$", [("model", "data"), ("model", None), (None, "data")]),
    # --- rglru / mlstm / slstm
    (r"w_in$", [("data", "model"), (None, "model"), ("data", None)]),
    (r"w_gates$", [("data", "model"), (None, "model"), ("data", None)]),
    (r"w_out$", [("model", "data"), ("model", None), (None, "data")]),
    (r"conv_w$", [(None, "model"), (None, None)]),
    (r"lam$", [("model",), (None,)]),
    (r"w_up$", [("data", "model"), (None, "model"), ("data", None)]),
    (r"\br$", [(None, "model", None, None), (None, None, None, None)]),
    (r"\bw$", [("data", None, "model", None), ("data", None, None, None)]),
    (r"w_if$", [("data", None), (None, None)]),
    # --- embeddings / head / projector
    (r"embed$", [("model", "data"), ("model", None), (None, "data")]),
    (r"lm_head$", [("data", "model"), (None, "model"), ("data", None)]),
    (r"frontend_proj.*w1$", [("data", "model"), (None, "model")]),
    (r"frontend_proj.*w2$", [("model", "data"), (None, "data")]),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def spec_for(path_str: str, shape: Tuple[int, ...], mesh: Mesh,
             *, stacked: bool = False) -> P:
    """Spec for one param leaf. ``stacked``: leading scan-period dim."""
    core_shape = shape[1:] if stacked else shape
    cands: List[Tuple[Axis, ...]] = []
    for pat, cs in _PARAM_RULES:
        if re.search(pat, path_str):
            cands.extend(cs)           # later-matching rules are fallbacks
    if not cands and core_shape:
        # generic fallback: FSDP the largest dim over "data" if divisible
        big = max(range(len(core_shape)), key=lambda i: core_shape[i])
        c: List[Axis] = [None] * len(core_shape)
        c[big] = "data"
        cands.append(tuple(c))
    spec = _resolve(cands, core_shape, mesh)
    if stacked:
        spec = P(*((None,) + tuple(spec)))
    return spec


def params_shardings(params_tree: Any, mesh: Mesh) -> Any:
    """NamedSharding pytree for a params (or optimizer-state) pytree.
    ``params_tree`` may hold arrays or ShapeDtypeStructs."""
    def one(path, leaf):
        ps = _path_str(path)
        stacked = "scan" in ps.split("/")
        return NamedSharding(mesh, spec_for(ps, leaf.shape, mesh,
                                            stacked=stacked))
    return jax.tree_util.tree_map_with_path(one, params_tree)


# ---------------------------------------------------------------------------
# batch / cache rules
# ---------------------------------------------------------------------------

def batch_shardings(batch_tree: Any, mesh: Mesh) -> Any:
    """Inputs: shard dim 0 (global batch) over ("pod","data") with
    divisibility fallback (long_500k batch=1 -> replicated)."""
    ba = batch_axes(mesh)

    def one(path, leaf):
        cands = [(ba,) + (None,) * (len(leaf.shape) - 1)]
        if len(ba) > 1:
            cands.append((ba[-1],) + (None,) * (len(leaf.shape) - 1))
        return NamedSharding(mesh, _resolve(cands, leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(one, batch_tree)


def cache_shardings(cache_tree: Any, mesh: Mesh) -> Any:
    """Decode caches. KV tensors (B, S, Hk, hd): batch x kv-head sharding
    when divisible, else sequence sharding (the long-context path).
    Recurrent states (B, ...): batch sharding, falling back to feature
    sharding for batch=1."""
    ba = batch_axes(mesh)

    def one(path, leaf):
        ps = _path_str(path)
        stacked = "scan" in ps.split("/")
        shape = leaf.shape[1:] if stacked else leaf.shape
        last = ps.split("/")[-1]
        if last in ("k", "v", "cross_k", "cross_v"):
            # batch x kv-heads when heads divide; otherwise batch x
            # SEQUENCE over "model" — the flash-decode layout: each model
            # shard scans its slice of the cache and the softmax combines
            # with a tiny stats psum. Keeping the model axis idle instead
            # (ba, None, None, None) left 2 x 7.5 GB fp32 cache reshards
            # per decoded token in the chatglm3 decode_32k baseline
            # (§Perf iteration 3.1).
            cands = [
                (ba, None, "model", None),
                (ba, "model", None, None),
                (ba, None, None, None),
                (None, ("data", "model"), None, None),
                (None, "data", None, None),
                (None, "model", None, None),
            ]
        elif last == "C":      # mlstm matrix memory (B, nh, dh, dh)
            cands = [(ba, "model", None, None), (ba, None, None, None),
                     (None, "model", None, None)]
        elif last == "conv":   # (B, W-1, D)
            cands = [(ba, None, "model"), (ba, None, None),
                     (None, None, "model")]
        elif len(shape) >= 2:  # other recurrent states (B, ...)
            cands = [(ba,) + (None,) * (len(shape) - 1),
                     (None, "model") + (None,) * (len(shape) - 2)]
        else:
            cands = [(ba,)]
        spec = _resolve(cands, shape, mesh)
        if stacked:
            spec = P(*((None,) + tuple(spec)))
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, cache_tree)


def replicated(tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


class ShardCtx:
    """Sharding context threaded through the model functions.

    * ``layer(p)`` — constrain ONE layer's (already bf16-cast) params to
      the model-only compute sharding. Called INSIDE the period-scan body,
      so the weight all-gather over "data" happens per scan step on the
      current slice (ZeRO-3); constraining the full stacked tree up front
      would materialize a gathered copy of every layer at once (observed
      as an 18.9 GB hoisted all-gather on qwen3's expert banks).
    * ``act(x)`` — constrain (B, S, D) activations to
      (batch-axes, None, "model"): keeps the scan-carry residual stack
      that AD saves for the backward pass sharded over BOTH batch and
      model axes (observed otherwise as a 50-100 GB residual buffer).
    """

    def __init__(self, mesh: Mesh):
        self.mesh = mesh

    def layer(self, layer_params: Any) -> Any:
        def one(path, leaf):
            ps = _path_str(path)
            spec = spec_for(ps, leaf.shape, self.mesh)
            kept = tuple(a if a == "model" else None for a in spec)
            return NamedSharding(self.mesh, P(*kept))
        sh = jax.tree_util.tree_map_with_path(one, layer_params)
        return jax.lax.with_sharding_constraint(layer_params, sh)

    def act(self, x) -> Any:
        ba = batch_axes(self.mesh)
        cands = [(ba,) + (None,) * (x.ndim - 2) + ("model",),
                 (ba,) + (None,) * (x.ndim - 1),
                 (None,) * (x.ndim - 1) + ("model",)]
        spec = _resolve(cands, x.shape, self.mesh)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def expert_tensor(self, x, *, expert_axis: int) -> Any:
        """MoE routing/buffer tensors: batch on dim 0, experts on "model".
        When the expert count doesn't divide (qwen2-moe: 60 experts on a
        16-wide axis), fall back to sharding the CAPACITY axis — it is
        batch-like (independent slots), always a multiple of 32 (see
        moe._capacity), and keeps the (G,T,E,C) tensors 16x smaller
        (qwen2-moe prefill_32k: 132 GB -> fits). Last resort: batch-only.
        """
        ba = batch_axes(self.mesh)
        ex = [None] * x.ndim
        ex[0] = ba
        ex[expert_axis] = "model"
        cx = [None] * x.ndim
        cx[0] = ba
        if expert_axis + 1 < x.ndim:
            cx[expert_axis + 1] = "model"
        cands = [tuple(ex), tuple(cx), (ba,) + (None,) * (x.ndim - 1)]
        spec = _resolve(cands, x.shape, self.mesh)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def batch_only(self, x) -> Any:
        """(B, ...) constrained to batch-axes sharding only: used on the
        final-norm output right before the LM head, where a model-sharded
        feature dim would conflict with the vocab dim ("model" twice in
        one dot) and make GSPMD replicate the larger of the two."""
        ba = batch_axes(self.mesh)
        cands = [(ba,) + (None,) * (x.ndim - 1), (None,) * x.ndim]
        spec = _resolve(cands, x.shape, self.mesh)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


class NoopShardCtx:
    def layer(self, p):
        return p

    def act(self, x):
        return x

    def batch_only(self, x):
        return x

    def expert_tensor(self, x, *, expert_axis: int):
        return x


def compute_params_shardings(params_tree: Any, mesh: Mesh) -> Any:
    """Shardings for the bf16 COMPUTE copy of the weights: the storage
    sharding with every axis except "model" dropped.

    This is ZeRO-3 made explicit: master params + optimizer state live
    fully sharded (FSDP over "data" x TP over "model"); the step casts to
    bf16 and constrains to model-only sharding, which lowers to an
    all-gather over "data" right before use — and the grad of that
    constraint is the reduce-scatter. Without it GSPMD resolves the
    batch-vs-weight "data"-axis conflict the expensive way (un-sharding
    the batch; observed as full-batch f32 all-reduces in the dry-run).
    Inside the period-scan only the current period's weights are gathered,
    so the transient is one period's bf16 weights, not the whole model.
    """
    def one(path, leaf):
        ps = _path_str(path)
        stacked = "scan" in ps.split("/")
        spec = spec_for(ps, leaf.shape, mesh, stacked=stacked)
        kept = tuple(a if a == "model" else None for a in spec)
        return NamedSharding(mesh, P(*kept))
    return jax.tree_util.tree_map_with_path(one, params_tree)


def activation_spec(mesh: Mesh) -> P:
    """(B, S, D) activations: batch over ("pod","data"), features over
    "model" — applied via with_sharding_constraint at step boundaries."""
    return P(batch_axes(mesh), None, "model")


def logits_spec(mesh: Mesh) -> P:
    return P(batch_axes(mesh), None, "model")
