from repro.sharding.rules import (batch_shardings, cache_shardings,
                                  compute_params_shardings, params_shardings,
                                  replicated, spec_for)
