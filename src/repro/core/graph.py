"""VR-PRUNE dataflow model of computation (Edge-PRUNE, Sec III.A).

A DNN application is a directed graph ``G = (A, F)``: nodes ``A`` are
*actors* (computation, e.g. groups of DNN layers) and edges ``F`` are FIFO
buffers carrying *tokens* (tensors) in first-in-first-out order.

Token-rate semantics
--------------------
Every port ``p`` carries three non-negative integers::

    lrl(p) <= atr(p) <= url(p)

``lrl`` (lower rate limit) and ``url`` (upper rate limit) are fixed at
design time; ``atr`` (active token rate) may be set before each firing of
``parent(p)`` — but only inside dynamic processing subgraphs (DPGs), and
subject to the *symmetric token rate requirement*: for every edge
``f = fifo(p_a) = fifo(p_b)`` it must hold that ``atr(p_a) == atr(p_b)``.

Actor taxonomy (Sec III.A):

* ``SPA``  static processing actor — fixed rates (lrl == url on all ports).
* ``DA``   dynamic actor — DPG boundary actor implementing rate variability.
* ``CA``   configuration actor — sets the current token rate within a DPG.
* ``DPA``  dynamic processing actor — variable-rate compute inside a DPG.

DAs, DPAs and CAs may only appear inside DPGs; a DPG consists of exactly
one CA, exactly two DAs (entry + exit), and any number of DPAs/SPAs.
Well-formed DPGs are compile-time analyzable for consistency (absence of
deadlock / buffer overflow) — see ``analyzer.py``.

Distribution (Sec III.B): the application graph never changes for
distributed execution. TX/RX FIFO pairs are inserted automatically at
synthesis time wherever an edge crosses a device boundary (``synthesis.py``).
"""
from __future__ import annotations

import enum
import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple


class ActorType(enum.Enum):
    SPA = "spa"  # static processing actor
    DA = "da"    # dynamic (DPG boundary) actor
    CA = "ca"    # configuration actor
    DPA = "dpa"  # dynamic processing actor


class PortDir(enum.Enum):
    IN = "in"
    OUT = "out"


@dataclass
class Port:
    """Connection point between an edge and an actor.

    ``token_shape``/``token_dtype`` describe one token (one tensor). The
    byte size of a token — used by the explorer's communication model and
    reported in the paper's Fig. 2/3 — is ``token_bytes``.
    """

    name: str
    direction: PortDir
    lrl: int = 1
    url: int = 1
    token_shape: Tuple[int, ...] = ()
    token_dtype: str = "float32"
    # Set by the framework when the port is attached.
    actor: Optional["Actor"] = field(default=None, repr=False)
    fifo: Optional["Fifo"] = field(default=None, repr=False)

    def __post_init__(self) -> None:
        if not (0 <= self.lrl <= self.url):
            raise ValueError(
                f"port {self.name}: rate limits must satisfy 0 <= lrl <= url, "
                f"got lrl={self.lrl} url={self.url}")

    @property
    def is_static_rate(self) -> bool:
        return self.lrl == self.url

    @property
    def token_bytes(self) -> int:
        itemsize = {"float32": 4, "bfloat16": 2, "float16": 2, "int32": 4,
                    "int8": 1, "uint8": 1, "bool": 1, "int64": 8,
                    "float64": 8}.get(self.token_dtype)
        if itemsize is None:
            raise ValueError(f"unknown dtype {self.token_dtype}")
        return itemsize * int(math.prod(self.token_shape)) if self.token_shape else itemsize


def parent(port: Port) -> "Actor":
    """``parent(p)`` from the paper: the actor owning port ``p``."""
    if port.actor is None:
        raise ValueError(f"port {port.name} is not attached to an actor")
    return port.actor


def fifo(port: Port) -> "Fifo":
    """``fifo(p)`` from the paper: the edge connected to port ``p``."""
    if port.fifo is None:
        raise ValueError(f"port {port.name} is not connected to a fifo")
    return port.fifo


@dataclass
class Actor:
    """A dataflow actor: computation triggered by input-token availability.

    ``fire_fn(inputs, state, atr) -> (outputs, state)`` implements the
    firing behaviour: ``inputs`` maps input-port name -> list of tokens
    (length == the port's active token rate), and it must return one list
    of tokens per output port. ``init_fn() -> state`` and ``deinit_fn``
    mirror the paper's initialization / deinitialization behaviours.
    """

    name: str
    actor_type: ActorType = ActorType.SPA
    in_ports: List[Port] = field(default_factory=list)
    out_ports: List[Port] = field(default_factory=list)
    fire_fn: Optional[Callable[..., Any]] = field(default=None, repr=False)
    init_fn: Optional[Callable[[], Any]] = field(default=None, repr=False)
    deinit_fn: Optional[Callable[[Any], None]] = field(default=None, repr=False)
    # DPG membership (None for actors outside any dynamic subgraph).
    dpg: Optional[str] = None
    # Estimated MACs/FLOPs per firing, used by the explorer cost model.
    cost_flops: float = 0.0
    # Bytes of parameter/weight traffic per firing (roofline memory term).
    cost_mem_bytes: float = 0.0
    # Arbitrary metadata (e.g. which DNN layers this actor encapsulates).
    meta: Dict[str, Any] = field(default_factory=dict)

    def __post_init__(self) -> None:
        for p in self.in_ports:
            if p.direction != PortDir.IN:
                raise ValueError(f"{self.name}: {p.name} in in_ports is not IN")
            p.actor = self
        for p in self.out_ports:
            if p.direction != PortDir.OUT:
                raise ValueError(f"{self.name}: {p.name} in out_ports is not OUT")
            p.actor = self
        names = [p.name for p in self.in_ports + self.out_ports]
        if len(names) != len(set(names)):
            raise ValueError(f"{self.name}: duplicate port names {names}")
        if self.actor_type == ActorType.SPA:
            for p in self.in_ports + self.out_ports:
                if not p.is_static_rate:
                    raise ValueError(
                        f"SPA {self.name} has variable-rate port {p.name} "
                        f"(lrl={p.lrl} != url={p.url}); only DA/DPA/CA ports "
                        f"inside DPGs may vary")

    def port(self, name: str) -> Port:
        for p in self.in_ports + self.out_ports:
            if p.name == name:
                return p
        raise KeyError(f"{self.name} has no port {name}")

    @property
    def is_source(self) -> bool:
        return not self.in_ports

    @property
    def is_sink(self) -> bool:
        return not self.out_ports


class FifoKind(enum.Enum):
    LOCAL = "local"      # ordinary in-memory FIFO
    TRANSMIT = "tx"      # boundary-crossing sender half (synthesis-inserted)
    RECEIVE = "rx"       # boundary-crossing receiver half (synthesis-inserted)


@dataclass
class Fifo:
    """A FIFO buffer edge with a fixed token ``capacity``.

    ``src`` is an OUT port, ``dst`` an IN port. TX/RX FIFOs (Sec III.B) are
    never authored by the user — ``synthesis.py`` splits a LOCAL fifo into a
    TX/RX pair when the mapping places ``src`` and ``dst`` on different
    devices. ``delay_tokens`` are initial tokens (dataflow "delays"),
    required on feedback edges for deadlock-freedom.
    """

    name: str
    src: Port
    dst: Port
    capacity: int = 2
    kind: FifoKind = FifoKind.LOCAL
    delay_tokens: int = 0

    def __post_init__(self) -> None:
        if self.src.direction != PortDir.OUT:
            raise ValueError(f"fifo {self.name}: src must be an OUT port")
        if self.dst.direction != PortDir.IN:
            raise ValueError(f"fifo {self.name}: dst must be an IN port")
        if self.capacity < 1:
            raise ValueError(f"fifo {self.name}: capacity must be >= 1")
        self.src.fifo = self
        self.dst.fifo = self

    @property
    def token_bytes(self) -> int:
        return self.src.token_bytes


@dataclass
class Dpg:
    """A dynamic processing subgraph: 1 CA, 2 DAs, any number of DPAs/SPAs."""

    name: str
    ca: str                 # configuration actor name
    entry_da: str           # DA at the DPG entry
    exit_da: str            # DA at the DPG exit
    members: List[str]      # all actor names inside the DPG (incl. above)


class Graph:
    """Application graph ``G = (A, F)`` with DPG annotations."""

    def __init__(self, name: str):
        self.name = name
        self.actors: Dict[str, Actor] = {}
        self.fifos: Dict[str, Fifo] = {}
        self.dpgs: Dict[str, Dpg] = {}

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    def add_actor(self, actor: Actor) -> Actor:
        if actor.name in self.actors:
            raise ValueError(f"duplicate actor {actor.name}")
        self.actors[actor.name] = actor
        return actor

    def connect(self, src: Port, dst: Port, *, capacity: int = 2,
                name: Optional[str] = None, delay_tokens: int = 0) -> Fifo:
        if src.token_shape != dst.token_shape or src.token_dtype != dst.token_dtype:
            raise ValueError(
                f"token type mismatch on edge {src.actor.name}.{src.name} -> "
                f"{dst.actor.name}.{dst.name}: {src.token_shape}/{src.token_dtype}"
                f" vs {dst.token_shape}/{dst.token_dtype}")
        fname = name or f"{src.actor.name}.{src.name}->{dst.actor.name}.{dst.name}"
        if fname in self.fifos:
            raise ValueError(f"duplicate fifo {fname}")
        f = Fifo(fname, src, dst, capacity=capacity, delay_tokens=delay_tokens)
        self.fifos[fname] = f
        return f

    def add_dpg(self, dpg: Dpg) -> Dpg:
        if dpg.name in self.dpgs:
            raise ValueError(f"duplicate DPG {dpg.name}")
        self.dpgs[dpg.name] = dpg
        for member in dpg.members:
            self.actors[member].dpg = dpg.name
        return dpg

    # ------------------------------------------------------------------
    # queries
    # ------------------------------------------------------------------
    def in_edges(self, actor: Actor) -> List[Fifo]:
        return [p.fifo for p in actor.in_ports if p.fifo is not None]

    def out_edges(self, actor: Actor) -> List[Fifo]:
        return [p.fifo for p in actor.out_ports if p.fifo is not None]

    def predecessors(self, actor: Actor) -> List[Actor]:
        return [f.src.actor for f in self.in_edges(actor)]

    def successors(self, actor: Actor) -> List[Actor]:
        return [f.dst.actor for f in self.out_edges(actor)]

    def sources(self) -> List[Actor]:
        return [a for a in self.actors.values() if a.is_source]

    def sinks(self) -> List[Actor]:
        return [a for a in self.actors.values() if a.is_sink]

    def topo_order(self, *, ignore_delay_edges: bool = True) -> List[Actor]:
        """Topological order of actors (Kahn). Edges carrying initial delay
        tokens are feedback edges and are excluded from the precedence
        relation (they do not constrain the first firing)."""
        indeg: Dict[str, int] = {n: 0 for n in self.actors}
        adj: Dict[str, List[str]] = {n: [] for n in self.actors}
        for f in self.fifos.values():
            if ignore_delay_edges and f.delay_tokens > 0:
                continue
            adj[f.src.actor.name].append(f.dst.actor.name)
            indeg[f.dst.actor.name] += 1
        queue = sorted(n for n, d in indeg.items() if d == 0)
        order: List[Actor] = []
        while queue:
            n = queue.pop(0)
            order.append(self.actors[n])
            for m in adj[n]:
                indeg[m] -= 1
                if indeg[m] == 0:
                    # insertion sort to keep deterministic order
                    import bisect
                    bisect.insort(queue, m)
        if len(order) != len(self.actors):
            cyclic = set(self.actors) - {a.name for a in order}
            raise ValueError(
                f"graph {self.name} has a zero-delay cycle through {sorted(cyclic)}; "
                f"add delay tokens on a feedback edge")
        return order

    def precedence_index(self) -> Dict[str, int]:
        """Ascending precedence index per actor — the ordering the Explorer
        uses to enumerate partition points (Sec III.C, 'Explorer')."""
        return {a.name: i for i, a in enumerate(self.topo_order())}

    # ------------------------------------------------------------------
    # convenience constructors
    # ------------------------------------------------------------------
    @staticmethod
    def chain(name: str, stages: Sequence[Tuple[str, Callable, Tuple[int, ...]]],
              *, dtype: str = "float32", input_shape: Tuple[int, ...] = (),
              costs: Optional[Sequence[float]] = None) -> "Graph":
        """Build a simple chain graph: source -> stage1 -> ... -> sink-ish.

        ``stages`` is a list of (actor_name, fire_fn, output_token_shape).
        ``fire_fn`` receives a single token and returns a single token.
        The first stage consumes tokens of ``input_shape``.
        """
        g = Graph(name)
        prev_shape = input_shape
        prev_out: Optional[Port] = None
        for i, (aname, fn, oshape) in enumerate(stages):
            inp = [] if prev_out is None else [
                Port("in", PortDir.IN, token_shape=prev_shape, token_dtype=dtype)]
            outp = [Port("out", PortDir.OUT, token_shape=oshape, token_dtype=dtype)]

            def make_fire(fn):
                def fire(inputs, state, atr):
                    if inputs:
                        (tok,) = inputs["in"]
                        return {"out": [fn(tok)]}, state
                    return {"out": [fn()]}, state
                return fire

            a = Actor(aname, ActorType.SPA, inp, outp, fire_fn=make_fire(fn),
                      cost_flops=(costs[i] if costs else 0.0))
            g.add_actor(a)
            if prev_out is not None:
                g.connect(prev_out, a.port("in"))
            prev_out = a.port("out")
            prev_shape = oshape
        return g

    def __repr__(self) -> str:
        return (f"Graph({self.name!r}, actors={len(self.actors)}, "
                f"fifos={len(self.fifos)}, dpgs={len(self.dpgs)})")
