"""Token-accurate execution of a VR-PRUNE graph (the Edge-PRUNE "runtime").

The paper's runtime instantiates each actor as a thread and synchronizes
FIFOs with mutexes. A literal thread-per-actor port is the wrong idiom for
both this CPU container and the TPU target; the simulator instead executes
the *identical* firing semantics — an actor fires iff every input FIFO
holds atr(p) tokens and every output FIFO has space — under a sequential
event loop. This keeps the MoC behaviour bit-exact while staying
deterministic and profileable.

Two clocks are maintained per firing:

* ``wall`` — real measured wall-clock of the fire function on this CPU
  (used to reproduce the paper's *measured* experiments), and
* ``modeled`` — cost_flops / device_flops + token_bytes / link_bandwidth
  under a ``PlatformModel`` (used to transplant the sweep onto the paper's
  N2 / N270 / i7 devices and Ethernet / WiFi links, and onto TPU pods).

Distributed semantics: when a ``Mapping`` is supplied, every edge whose
endpoints map to different processing units is treated as a TX/RX FIFO pair
(Sec III.B) — tokens flow identically, but the modeled clock charges the
link with ``token_bytes / bandwidth + latency`` and the per-device busy
clocks advance independently, mimicking pipelined client/server execution.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.graph import Actor, ActorType, Fifo, Graph
from repro.core.mapping import Mapping, PlatformModel


@dataclass
class FiringRecord:
    actor: str
    firing_index: int
    wall_s: float
    modeled_s: float
    unit: str
    # Pipelined-clock timeline: when this firing started/finished on its
    # unit's concurrent busy clock (0.0/modeled_s without a platform).
    start_s: float = 0.0
    finish_s: float = 0.0


@dataclass
class SimResult:
    outputs: Dict[str, List[Any]]
    firings: List[FiringRecord] = field(default_factory=list)
    # Per processing unit: total modeled busy seconds (compute only — the
    # Figs 4-6 accounting; sender-side TX CPU cost is ledgered apart).
    unit_busy_s: Dict[str, float] = field(default_factory=dict)
    # Modeled seconds spent on boundary (TX/RX) transfers, per edge.
    link_busy_s: Dict[str, float] = field(default_factory=dict)
    # Sender-side CPU cost of boundary transfers (readback + syscalls),
    # per unit. Charged to the sender's concurrent clock as well.
    tx_cpu_busy_s: Dict[str, float] = field(default_factory=dict)
    wall_total_s: float = 0.0
    # Modeled completion time with per-device busy clocks advancing
    # concurrently (pipelined client/server execution, Sec III.B). The
    # sequential reference is ``modeled_total_s()``.
    modeled_makespan_s: float = 0.0

    @property
    def modeled_endpoint_s(self) -> float:
        """Modeled busy time summed over every non-server unit — the
        'endpoint device inference time' metric of Figs 4-6."""
        return sum(v for k, v in self.unit_busy_s.items() if not k.startswith("server"))

    def modeled_total_s(self) -> float:
        return (sum(self.unit_busy_s.values())
                + sum(self.link_busy_s.values())
                + sum(self.tx_cpu_busy_s.values()))

    @property
    def pipeline_speedup(self) -> float:
        """Sequential / pipelined modeled time — the overlap win."""
        if not self.modeled_makespan_s:
            return 1.0
        return self.modeled_total_s() / self.modeled_makespan_s


class FifoState:
    """Run-time state of one FIFO edge: a bounded token deque.

    Each token carries a modeled *availability timestamp* (when it lands
    at the consuming unit) in a parallel deque, so the event loop can
    advance per-device clocks concurrently."""

    def __init__(self, f: Fifo):
        self.fifo = f
        self.q: deque = deque()
        self.ts: deque = deque()
        for _ in range(f.delay_tokens):
            self.q.append(None)  # initial delay tokens carry no payload
            self.ts.append(0.0)

    def can_pop(self, n: int) -> bool:
        return len(self.q) >= n

    def can_push(self, n: int) -> bool:
        return len(self.q) + n <= self.fifo.capacity

    def pop(self, n: int) -> List[Any]:
        return self.pop_timed(n)[0]

    def pop_timed(self, n: int) -> Tuple[List[Any], float]:
        """Pop ``n`` tokens; also return when the last became available."""
        ready = 0.0
        toks = []
        for _ in range(n):
            ready = max(ready, self.ts.popleft())
            toks.append(self.q.popleft())
        return toks, ready

    def push(self, toks: List[Any], ready_s: float = 0.0) -> None:
        if len(self.q) + len(toks) > self.fifo.capacity:
            raise OverflowError(
                f"fifo {self.fifo.name} overflow: {len(self.q)}+{len(toks)} > "
                f"{self.fifo.capacity}")
        self.q.extend(toks)
        self.ts.extend([ready_s] * len(toks))


class Simulator:
    def __init__(self, g: Graph, *, mapping: Optional[Mapping] = None,
                 platform: Optional[PlatformModel] = None,
                 atr_fn: Optional[Callable[[Actor, int], Dict[str, int]]] = None):
        """``atr_fn(actor, firing_index) -> {port_name: atr}`` plays the CA
        role for variable-rate ports; defaults to url on every port."""
        self.g = g
        self.mapping = mapping
        self.platform = platform
        self.atr_fn = atr_fn
        self.states: Dict[str, Any] = {}

    def _atr(self, a: Actor, k: int) -> Dict[str, int]:
        rates = {p.name: p.url for p in a.in_ports + a.out_ports}
        if self.atr_fn is not None and a.actor_type in (ActorType.DA, ActorType.DPA,
                                                        ActorType.CA):
            over = self.atr_fn(a, k)
            for pname, r in over.items():
                p = a.port(pname)
                if not (p.lrl <= r <= p.url):
                    raise ValueError(
                        f"atr({a.name}.{pname})={r} outside [{p.lrl},{p.url}]")
                rates[pname] = r
        return rates

    def _unit(self, a: Actor) -> str:
        return self.mapping.unit_of(a.name) if self.mapping else "local"

    def run(self, num_source_firings: int, *,
            source_inputs: Optional[Dict[str, List[Any]]] = None,
            max_steps: int = 10_000_000) -> SimResult:
        """Run until every source actor has fired ``num_source_firings``
        times and no further firings are possible.

        ``source_inputs`` optionally supplies per-source-actor token
        payloads (one per firing); otherwise the source fire_fn is invoked
        with no input tokens.
        """
        fstate = {name: FifoState(f) for name, f in self.g.fifos.items()}
        for a in self.g.actors.values():
            self.states[a.name] = a.init_fn() if a.init_fn else None
        fired: Dict[str, int] = {n: 0 for n in self.g.actors}
        result = SimResult(outputs={})
        sink_capture: Dict[str, List[Any]] = {a.name: [] for a in self.g.sinks()}
        order = self.g.topo_order()
        t0 = time.perf_counter()
        src_feed = source_inputs or {}
        unit_clock: Dict[str, float] = {}

        steps = 0
        progress = True
        while progress and steps < max_steps:
            progress = False
            for a in order:
                steps += 1
                if a.is_source and fired[a.name] >= num_source_firings:
                    continue
                rates = self._atr(a, fired[a.name])
                # firing rule: inputs available AND output space available
                ready = all(fstate[p.fifo.name].can_pop(rates[p.name])
                            for p in a.in_ports if p.fifo is not None)
                space = all(fstate[p.fifo.name].can_push(rates[p.name])
                            for p in a.out_ports if p.fifo is not None)
                if not (ready and space):
                    continue
                inputs = {}
                in_ready = 0.0
                for p in a.in_ports:
                    if p.fifo is None:
                        continue
                    toks, t_ready = fstate[p.fifo.name].pop_timed(rates[p.name])
                    inputs[p.name] = toks
                    in_ready = max(in_ready, t_ready)
                if a.is_source and a.name in src_feed:
                    inputs["__feed__"] = [src_feed[a.name][fired[a.name]]]
                tstart = time.perf_counter()
                if a.fire_fn is not None:
                    outputs, self.states[a.name] = a.fire_fn(
                        inputs, self.states[a.name], rates)
                else:
                    outputs = {}
                wall = time.perf_counter() - tstart
                unit = self._unit(a)
                modeled = 0.0
                if self.platform is not None:
                    modeled = self.platform.actor_time_s(unit, a)
                result.unit_busy_s[unit] = result.unit_busy_s.get(unit, 0.0) + modeled
                # Concurrent per-device clocks: the firing starts once its
                # inputs have landed AND its unit is free; devices overlap.
                mstart = max(in_ready, unit_clock.get(unit, 0.0))
                mfinish = mstart + modeled
                result.firings.append(FiringRecord(a.name, fired[a.name], wall,
                                                   modeled, unit,
                                                   start_s=mstart,
                                                   finish_s=mfinish))
                for p in a.out_ports:
                    if p.fifo is None:
                        continue
                    toks = outputs.get(p.name, [])
                    if len(toks) != rates[p.name]:
                        raise ValueError(
                            f"{a.name} produced {len(toks)} tokens on {p.name}, "
                            f"atr says {rates[p.name]} (symmetric token rate "
                            f"requirement violated)")
                    # TX/RX modeled link charge when the edge crosses units.
                    dst_unit = self._unit(p.fifo.dst.actor)
                    tok_ready = mfinish
                    if self.platform is not None and dst_unit != unit:
                        cpu_s, link_s, block_s, delay_s = (
                            self.platform.boundary_charge_s(
                                unit, dst_unit,
                                p.token_bytes * rates[p.name]))
                        result.link_busy_s[p.fifo.name] = (
                            result.link_busy_s.get(p.fifo.name, 0.0) + link_s)
                        result.tx_cpu_busy_s[unit] = (
                            result.tx_cpu_busy_s.get(unit, 0.0) + cpu_s)
                        tok_ready = mfinish + delay_s
                        mfinish += block_s
                    fstate[p.fifo.name].push(toks, tok_ready)
                    result.modeled_makespan_s = max(result.modeled_makespan_s,
                                                    tok_ready)
                unit_clock[unit] = mfinish
                result.modeled_makespan_s = max(result.modeled_makespan_s,
                                                mfinish)
                if a.is_sink:
                    # Sinks with no out ports: capture whatever fire returned
                    # under the reserved key "result".
                    if isinstance(outputs, dict) and "result" in outputs:
                        sink_capture[a.name].extend(outputs["result"])
                fired[a.name] += 1
                progress = True
        result.wall_total_s = time.perf_counter() - t0
        result.outputs = sink_capture
        for a in self.g.actors.values():
            if a.deinit_fn:
                a.deinit_fn(self.states[a.name])
        return result
