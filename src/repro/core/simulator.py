"""Token-accurate execution of a VR-PRUNE graph (the Edge-PRUNE "runtime").

The paper's runtime instantiates each actor as a thread and synchronizes
FIFOs with mutexes. A literal thread-per-actor port is the wrong idiom for
both this CPU container and the TPU target; the simulator instead executes
the *identical* firing semantics — an actor fires iff every input FIFO
holds atr(p) tokens and every output FIFO has space — under a sequential
event loop. This keeps the MoC behaviour bit-exact while staying
deterministic and profileable.

Two clocks are maintained per firing:

* ``wall`` — real measured wall-clock of the fire function on this CPU
  (used to reproduce the paper's *measured* experiments), and
* ``modeled`` — cost_flops / device_flops + token_bytes / link_bandwidth
  under a ``PlatformModel`` (used to transplant the sweep onto the paper's
  N2 / N270 / i7 devices and Ethernet / WiFi links, and onto TPU pods).

Distributed semantics: when a ``Mapping`` is supplied, every edge whose
endpoints map to different processing units is treated as a TX/RX FIFO pair
(Sec III.B) — tokens flow identically, but the modeled clock charges the
link with ``token_bytes / bandwidth + latency`` and the per-device busy
clocks advance independently, mimicking pipelined client/server execution.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.clocks import UnitClocks
from repro.core.graph import Actor, ActorType, Fifo, Graph
from repro.core.mapping import Mapping, PlatformModel


@dataclass
class FiringRecord:
    actor: str
    firing_index: int
    wall_s: float
    modeled_s: float
    unit: str
    # Pipelined-clock timeline: when this firing started/finished on its
    # unit's concurrent busy clock (0.0/modeled_s without a platform).
    start_s: float = 0.0
    finish_s: float = 0.0


@dataclass
class SimResult:
    outputs: Dict[str, List[Any]]
    firings: List[FiringRecord] = field(default_factory=list)
    # Per processing unit: total modeled busy seconds (compute only — the
    # Figs 4-6 accounting; sender-side TX CPU cost is ledgered apart).
    unit_busy_s: Dict[str, float] = field(default_factory=dict)
    # Modeled seconds spent on boundary (TX/RX) transfers, per edge.
    link_busy_s: Dict[str, float] = field(default_factory=dict)
    # Sender-side CPU cost of boundary transfers (readback + syscalls),
    # per unit. Charged to the sender's concurrent clock as well.
    tx_cpu_busy_s: Dict[str, float] = field(default_factory=dict)
    wall_total_s: float = 0.0
    # Modeled completion time with per-device busy clocks advancing
    # concurrently (pipelined client/server execution, Sec III.B). The
    # sequential reference is ``modeled_total_s()``.
    modeled_makespan_s: float = 0.0
    # Failure accounting (populated when ``failures=`` is given): frames
    # whose in-flight tokens were lost and re-fired from the last
    # consistent frame boundary, and frames lost for good (the failed
    # component never revived, so replaying onto it cannot succeed —
    # recovering those is the FailoverController's job, via re-mapping).
    frames_replayed: List[int] = field(default_factory=list)
    frames_lost: List[int] = field(default_factory=list)
    failure_log: List[str] = field(default_factory=list)

    @property
    def modeled_endpoint_s(self) -> float:
        """Modeled busy time summed over every non-server unit — the
        'endpoint device inference time' metric of Figs 4-6."""
        return sum(v for k, v in self.unit_busy_s.items() if not k.startswith("server"))

    def modeled_total_s(self) -> float:
        return (sum(self.unit_busy_s.values())
                + sum(self.link_busy_s.values())
                + sum(self.tx_cpu_busy_s.values()))

    @property
    def pipeline_speedup(self) -> float:
        """Sequential / pipelined modeled time — the overlap win. An empty
        run (no firings, or no platform so every modeled charge is zero)
        has no overlap to measure on either side: report 1.0 rather than
        dividing zero by zero."""
        if not self.modeled_makespan_s or not self.modeled_total_s():
            return 1.0
        return self.modeled_total_s() / self.modeled_makespan_s


class FifoState:
    """Run-time state of one FIFO edge: a bounded token deque.

    Each token carries a modeled *availability timestamp* (when it lands
    at the consuming unit) and a *frame tag* (which source firing it
    descends from) in parallel deques. Timestamps let the event loop
    advance per-device clocks concurrently; frame tags let failure
    handling re-fire a lost frame from its last consistent boundary.
    Initial delay tokens carry frame ``-1`` (they precede every frame)."""

    def __init__(self, f: Fifo):
        self.fifo = f
        self.q: deque = deque()
        self.ts: deque = deque()
        self.fr: deque = deque()
        for _ in range(f.delay_tokens):
            self.q.append(None)  # initial delay tokens carry no payload
            self.ts.append(0.0)
            self.fr.append(-1)

    def can_pop(self, n: int) -> bool:
        return len(self.q) >= n

    def can_push(self, n: int) -> bool:
        return len(self.q) + n <= self.fifo.capacity

    def pop(self, n: int) -> List[Any]:
        return self.pop_timed(n)[0]

    def pop_timed(self, n: int) -> Tuple[List[Any], float]:
        """Pop ``n`` tokens; also return when the last became available."""
        toks, ready, _ = self.pop_full(n)
        return toks, ready

    def pop_full(self, n: int) -> Tuple[List[Any], float, List[Tuple[float, int]]]:
        """Pop ``n`` tokens; return (tokens, last-availability, per-token
        (availability, frame) pairs)."""
        ready = 0.0
        toks: List[Any] = []
        meta: List[Tuple[float, int]] = []
        for _ in range(n):
            t = self.ts.popleft()
            ready = max(ready, t)
            toks.append(self.q.popleft())
            meta.append((t, self.fr.popleft()))
        return toks, ready, meta

    def push(self, toks: List[Any], ready_s: float = 0.0,
             frame: int = -1) -> None:
        if len(self.q) + len(toks) > self.fifo.capacity:
            raise OverflowError(
                f"fifo {self.fifo.name} overflow: {len(self.q)}+{len(toks)} > "
                f"{self.fifo.capacity}")
        self.q.extend(toks)
        self.ts.extend([ready_s] * len(toks))
        self.fr.extend([frame] * len(toks))

    def purge_frame(self, frame: int) -> int:
        """Drop every buffered token of ``frame``. Replay (and permanent
        loss) is whole-frame: a lost frame's surviving tokens on healthy
        branches must go too, or a later join would pair branch outputs
        from different frames."""
        keep = [(q, t, f) for q, t, f in zip(self.q, self.ts, self.fr)
                if f != frame]
        dropped = len(self.q) - len(keep)
        if dropped:
            self.q = deque(x[0] for x in keep)
            self.ts = deque(x[1] for x in keep)
            self.fr = deque(x[2] for x in keep)
        return dropped


class Simulator:
    def __init__(self, g: Graph, *, mapping: Optional[Mapping] = None,
                 platform: Optional[PlatformModel] = None,
                 atr_fn: Optional[Callable[[Actor, int], Dict[str, int]]] = None):
        """``atr_fn(actor, firing_index) -> {port_name: atr}`` plays the CA
        role for variable-rate ports; defaults to url on every port."""
        self.g = g
        self.mapping = mapping
        self.platform = platform
        self.atr_fn = atr_fn
        self.states: Dict[str, Any] = {}

    def _atr(self, a: Actor, k: int) -> Dict[str, int]:
        rates = {p.name: p.url for p in a.in_ports + a.out_ports}
        if self.atr_fn is not None and a.actor_type in (ActorType.DA, ActorType.DPA,
                                                        ActorType.CA):
            over = self.atr_fn(a, k)
            for pname, r in over.items():
                p = a.port(pname)
                if not (p.lrl <= r <= p.url):
                    raise ValueError(
                        f"atr({a.name}.{pname})={r} outside [{p.lrl},{p.url}]")
                rates[pname] = r
        return rates

    def _unit(self, a: Actor) -> str:
        return self.mapping.unit_of(a.name) if self.mapping else "local"

    MAX_REPLAYS_PER_FRAME = 4

    def run(self, num_source_firings: int, *,
            source_inputs: Optional[Dict[str, List[Any]]] = None,
            max_steps: int = 10_000_000,
            failures: Optional[Any] = None) -> SimResult:
        """Run until every source actor has fired ``num_source_firings``
        times and no further firings are possible.

        ``source_inputs`` optionally supplies per-source-actor token
        payloads (one per firing); otherwise the source fire_fn is invoked
        with no input tokens.

        ``failures`` (a ``repro.runtime.resilience.FailureTrace``, duck-
        typed so core stays import-free of runtime) injects unit/link
        kills and revivals on the modeled clocks:

        * a firing whose start falls inside a dead interval of its unit is
          delayed to the revival (blocked forever without one);
        * tokens buffered on a unit across a kill, or landing at a dead
          unit, are lost, and their frame is re-fired from the source —
          the last consistent frame boundary (replay is deterministic when
          ``source_inputs`` feeds the sources);
        * transfers over a dead link wait for its revival; without one the
          token (and frame) is lost.

        Frames whose failed component never revives are reported in
        ``frames_lost`` — recovering *those* requires a different mapping,
        which is the ``FailoverController``'s job, not the simulator's.
        Replay granularity is whole frames: a lost frame's surviving
        in-flight tokens are purged everywhere (so joins stay
        frame-aligned) and the healthy branches recompute.

        Whole-frame replay is only sound for the synthesis-path graph
        class — static-rate, acyclic, stateless actors (the paper's and
        our DNN inference graphs). Stateful actors cannot be rolled back,
        loop-carried delay tokens would be purged, and variable rates
        cannot be reproduced at replay time, so ``failures=`` combined
        with ``atr_fn``, delay tokens, or ``init_fn`` raises rather than
        silently corrupting outputs.
        """
        if failures is not None:
            if self.atr_fn is not None:
                raise ValueError(
                    "failure injection requires static-rate graphs: replay "
                    "cannot reproduce atr_fn's per-firing-index rates")
            cyclic = [f.name for f in self.g.fifos.values() if f.delay_tokens]
            if cyclic:
                raise ValueError(
                    f"failure injection does not support feedback edges "
                    f"(delay tokens on {cyclic}): whole-frame replay would "
                    f"purge loop-carried state")
            stateful = [a.name for a in self.g.actors.values() if a.init_fn]
            if stateful:
                raise ValueError(
                    f"failure injection requires stateless actors (init_fn "
                    f"on {stateful}): replay cannot roll back actor state")
        fstate = {name: FifoState(f) for name, f in self.g.fifos.items()}
        for a in self.g.actors.values():
            self.states[a.name] = a.init_fn() if a.init_fn else None
        fired: Dict[str, int] = {n: 0 for n in self.g.actors}
        result = SimResult(outputs={})
        sink_capture: Dict[str, List[Any]] = {a.name: [] for a in self.g.sinks()}
        # Failure mode captures sinks per frame so a replayed frame lands
        # exactly once, in frame order, no matter how often it re-fires.
        sink_by_frame: Dict[str, Dict[int, List[Any]]] = \
            {a.name: {} for a in self.g.sinks()}
        order = self.g.topo_order()
        t0 = time.perf_counter()
        src_feed = source_inputs or {}
        unit_clock = UnitClocks()
        source_names = [a.name for a in self.g.sources()]

        # Replay state: per-source queues of frames to re-fire, the time
        # each replay may start (failure observation), and attempt caps so
        # a frame that keeps dying eventually lands in frames_lost.
        src_next: Dict[str, int] = {n: 0 for n in source_names}
        replay_q: Dict[str, deque] = {n: deque() for n in source_names}
        replay_ready: Dict[int, float] = {}
        replay_attempts: Dict[int, int] = {}
        lost_frames: set = set()
        replayed_frames: List[int] = []

        def lose(frames: List[int], *, recoverable: bool, when: float,
                 what: str) -> None:
            for f in sorted({f for f in frames if f >= 0}):
                # Several token losses from one outage belong to one
                # replay round: only a *new* round (frame not already
                # queued at the sources) consumes a replay attempt.
                pending = any(f in replay_q[s] for s in source_names)
                can_retry = recoverable and (
                    pending or
                    replay_attempts.get(f, 0) < self.MAX_REPLAYS_PER_FRAME)
                if can_retry:
                    if not pending:
                        replay_attempts[f] = replay_attempts.get(f, 0) + 1
                    replay_ready[f] = max(replay_ready.get(f, 0.0), when)
                    for s in source_names:
                        if f not in replay_q[s]:
                            replay_q[s].append(f)
                    if f not in replayed_frames:
                        replayed_frames.append(f)
                else:
                    lost_frames.add(f)
                    # A permanently lost frame must not leave partial
                    # outputs behind (multi-sink graphs).
                    for by_f in sink_by_frame.values():
                        by_f.pop(f, None)
                # Whole-frame consistency: drop the frame's surviving
                # in-flight tokens everywhere, or a downstream join would
                # pair branch outputs from different frames.
                for fs in fstate.values():
                    fs.purge_frame(f)
                result.failure_log.append(
                    f"t={when:.6g} {what}: frame {f} "
                    f"{'replayed' if can_retry else 'lost'}")

        steps = 0
        progress = True
        while progress and steps < max_steps:
            progress = False
            for a in order:
                steps += 1
                frame = -1            # frame tag this firing belongs to
                is_replay = False
                if a.is_source:
                    if src_next[a.name] < num_source_firings:
                        frame = src_next[a.name]
                    elif replay_q[a.name]:
                        frame = replay_q[a.name][0]
                        is_replay = True
                    else:
                        continue
                rates = self._atr(a, fired[a.name])
                # firing rule: inputs available AND output space available
                ready = all(fstate[p.fifo.name].can_pop(rates[p.name])
                            for p in a.in_ports if p.fifo is not None)
                space = all(fstate[p.fifo.name].can_push(rates[p.name])
                            for p in a.out_ports if p.fifo is not None)
                if not (ready and space):
                    continue
                inputs = {}
                in_ready = 0.0
                tok_meta: List[Tuple[float, int]] = []
                for p in a.in_ports:
                    if p.fifo is None:
                        continue
                    toks, t_ready, meta = fstate[p.fifo.name].pop_full(
                        rates[p.name])
                    inputs[p.name] = toks
                    in_ready = max(in_ready, t_ready)
                    tok_meta.extend(meta)
                if not a.is_source and tok_meta:
                    frame = max(fr for _, fr in tok_meta)
                if is_replay:
                    in_ready = max(in_ready, replay_ready.get(frame, 0.0))
                unit = self._unit(a)
                # Concurrent per-device clocks: the firing starts once its
                # inputs have landed AND its unit is free; devices overlap.
                mstart = unit_clock.start(unit, in_ready)
                if failures is not None:
                    alive = failures.unit_next_alive(unit, mstart)
                    if alive is None:
                        # Dead forever: a source simply never fires again;
                        # buffered inputs are stranded on a dead unit.
                        if tok_meta:
                            lose([fr for _, fr in tok_meta],
                                 recoverable=False, when=mstart,
                                 what=f"unit {unit} dead (no revival), "
                                      f"tokens at {a.name} stranded")
                            progress = True
                        continue
                    if any(failures.unit_killed_between(unit, ts, alive)
                           for ts, _ in tok_meta):
                        # Unit died while these tokens sat in its FIFOs:
                        # in-flight state is gone, re-fire the frame(s)
                        # once the unit is back.
                        lose([fr for _, fr in tok_meta], recoverable=True,
                             when=alive,
                             what=f"unit {unit} died holding {a.name} inputs")
                        progress = True
                        continue
                    mstart = alive
                if a.is_source and a.name in src_feed:
                    inputs["__feed__"] = [src_feed[a.name][frame]]
                if a.is_source:
                    if is_replay:
                        replay_q[a.name].popleft()
                    else:
                        src_next[a.name] += 1
                tstart = time.perf_counter()
                if a.fire_fn is not None:
                    outputs, self.states[a.name] = a.fire_fn(
                        inputs, self.states[a.name], rates)
                else:
                    outputs = {}
                wall = time.perf_counter() - tstart
                modeled = 0.0
                if self.platform is not None:
                    modeled = self.platform.actor_time_s(unit, a)
                result.unit_busy_s[unit] = result.unit_busy_s.get(unit, 0.0) + modeled
                mfinish = mstart + modeled
                result.firings.append(FiringRecord(a.name, fired[a.name], wall,
                                                   modeled, unit,
                                                   start_s=mstart,
                                                   finish_s=mfinish))
                frame_lost_in_firing = False
                for p in a.out_ports:
                    if p.fifo is None:
                        continue
                    toks = outputs.get(p.name, [])
                    if len(toks) != rates[p.name]:
                        raise ValueError(
                            f"{a.name} produced {len(toks)} tokens on {p.name}, "
                            f"atr says {rates[p.name]} (symmetric token rate "
                            f"requirement violated)")
                    # TX/RX modeled link charge when the edge crosses units.
                    dst_unit = self._unit(p.fifo.dst.actor)
                    tok_ready = mfinish
                    if self.platform is not None and dst_unit != unit:
                        send_start = mfinish
                        if failures is not None:
                            w = failures.link_next_alive(unit, dst_unit,
                                                         mfinish)
                            if w is None:
                                lose([frame], recoverable=False, when=mfinish,
                                     what=f"link {unit}-{dst_unit} dead "
                                          f"(no revival)")
                                frame_lost_in_firing = True
                                continue
                            send_start = w
                        cpu_s, link_s, block_s, delay_s = (
                            self.platform.boundary_charge_s(
                                unit, dst_unit,
                                p.token_bytes * rates[p.name]))
                        result.link_busy_s[p.fifo.name] = (
                            result.link_busy_s.get(p.fifo.name, 0.0) + link_s)
                        result.tx_cpu_busy_s[unit] = (
                            result.tx_cpu_busy_s.get(unit, 0.0) + cpu_s)
                        tok_ready = send_start + delay_s
                        mfinish = send_start + block_s
                    if failures is not None:
                        d_alive = failures.unit_next_alive(dst_unit, tok_ready)
                        if d_alive is None:
                            lose([frame], recoverable=False, when=tok_ready,
                                 what=f"unit {dst_unit} dead (no revival), "
                                      f"token from {a.name} dropped")
                            frame_lost_in_firing = True
                            continue
                        if d_alive > tok_ready:
                            lose([frame], recoverable=True, when=d_alive,
                                 what=f"token from {a.name} landed at dead "
                                      f"unit {dst_unit}")
                            frame_lost_in_firing = True
                            continue
                    fstate[p.fifo.name].push(toks, tok_ready, frame)
                    result.modeled_makespan_s = max(result.modeled_makespan_s,
                                                    tok_ready)
                if frame_lost_in_firing:
                    # Out-ports pushed after the losing one re-introduced
                    # tokens of the lost frame: finish the whole-frame purge.
                    for fs in fstate.values():
                        fs.purge_frame(frame)
                unit_clock.set(unit, mfinish)
                result.modeled_makespan_s = max(result.modeled_makespan_s,
                                                mfinish)
                if a.is_sink:
                    # Sinks with no out ports: capture whatever fire returned
                    # under the reserved key "result".
                    if isinstance(outputs, dict) and "result" in outputs:
                        sink_capture[a.name].extend(outputs["result"])
                        sink_by_frame[a.name][frame] = list(outputs["result"])
                fired[a.name] += 1
                progress = True
        result.wall_total_s = time.perf_counter() - t0
        if failures is not None:
            # Frames the sources never (re-)fired — a source on a dead-
            # forever unit, or a replay that could not run before the
            # drain stalled — are lost too, not silently absent.
            for s in source_names:
                for f in range(src_next[s], num_source_firings):
                    lost_frames.add(f)
                for f in replay_q[s]:
                    lost_frames.add(f)
            # Likewise frames whose tokens are still stranded in FIFOs
            # when the drain stalls: they never completed.
            for fs in fstate.values():
                for f in fs.fr:
                    if f >= 0:
                        lost_frames.add(f)
        if failures is not None:
            # Frame-ordered, replay-deduplicated sink outputs.
            result.outputs = {name: [tok for f in sorted(by_f)
                                     for tok in by_f[f]]
                              for name, by_f in sink_by_frame.items()}
        else:
            result.outputs = sink_capture
        result.frames_replayed = sorted(replayed_frames)
        result.frames_lost = sorted(lost_frames)
        for a in self.g.actors.values():
            if a.deinit_fn:
                a.deinit_fn(self.states[a.name])
        return result
