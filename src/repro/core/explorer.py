"""Explorer: partition-point design-space exploration (Sec III.C).

The Edge-PRUNE Explorer indexes the N actors of the application graph into
ascending precedence order and generates N mapping-file pairs (endpoint +
server) by shifting the client-server partition point actor-by-actor from
the inference input towards the inference output, plus profiling scripts
for every alternative.

This module reproduces that workflow with two evaluation backends:

* ``evaluate_modeled`` — the analytic platform model (calibrated paper
  devices or TPU pods): per-frame *endpoint inference time* =
  endpoint-mapped actor compute + boundary token transfer. This is the
  quantity plotted in the paper's Figs 4-6.
* ``evaluate_simulated`` — token-accurate simulation, actually executing
  the actor fire functions (real conv/GEMM in JAX) and recording both
  wall-clock on this CPU and the modeled clocks.

``explore`` sweeps every partition point, optionally writes the mapping
file pairs + a profiling script (the paper's artifact set), and returns a
table of records from which benchmarks derive the figures.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.graph import Graph
from repro.core.mapping import Mapping, PlatformGraph, PlatformModel
from repro.core.simulator import Simulator
from repro.core.synthesis import synthesize, write_mapping_file


@dataclass
class PartitionRecord:
    pp: int
    mapping_name: str
    endpoint_actors: List[str]
    boundary_bytes: int
    endpoint_compute_s: float
    transfer_s: float
    server_compute_s: float
    endpoint_time_s: float       # the Fig-4/5/6 metric
    e2e_latency_s: float         # single-frame end-to-end latency (Sec IV.D)
    wall_s: Optional[float] = None  # measured on this CPU when simulated


@dataclass
class ExplorationResult:
    graph: str
    platform: str
    records: List[PartitionRecord] = field(default_factory=list)

    def best(self, *, privacy: bool = False,
             metric: str = "endpoint_time_s") -> PartitionRecord:
        """Best partition point. ``privacy=True`` excludes pp<=1 — i.e.
        configurations that ship the raw input off-device (the paper's
        privacy-preserving constraint)."""
        cands = [r for r in self.records if (r.pp > 1 if privacy else True)]
        return min(cands, key=lambda r: getattr(r, metric))

    def full_endpoint(self) -> PartitionRecord:
        return max(self.records, key=lambda r: r.pp)

    def speedup(self, *, privacy: bool = False) -> float:
        return (self.full_endpoint().endpoint_time_s /
                self.best(privacy=privacy).endpoint_time_s)


class Explorer:
    def __init__(self, g: Graph, platform: PlatformGraph,
                 *, endpoint: str = "endpoint", server: str = "server"):
        self.g = g
        self.platform = platform
        self.model = PlatformModel(platform)
        self.endpoint = endpoint
        self.server = server
        self.order = g.topo_order()

    # ------------------------------------------------------------------
    def mappings(self) -> List[Mapping]:
        """One mapping per partition point pp = 1..N (pp actors on the
        endpoint). pp=1 keeps only the input/source actor on-device (raw
        offload); pp=N is full endpoint inference."""
        n = len(self.order)
        return [Mapping.partition_point(self.g, pp, endpoint=self.endpoint,
                                        server=self.server,
                                        platform=self.platform)
                for pp in range(1, n + 1)]

    def rank_fallbacks(self, *, exclude_units: Sequence[str] = (),
                       exclude_links: Sequence[Tuple[str, str]] = ()
                       ) -> List[Mapping]:
        """Ranked fallback mappings for the resilience subsystem.

        Candidates are the partition-point family plus one all-on-a-single-
        unit mapping per platform unit (the degenerate recovery mappings —
        full endpoint inference when the server dies, raw offload when the
        endpoint's accelerator dies). A candidate survives the filter when
        it touches no unit in ``exclude_units`` and none of its boundary
        edges needs a link in ``exclude_links`` (or a link the platform
        doesn't have); survivors are ranked best-first by modeled
        single-frame end-to-end latency — computed per mapped unit (not
        just the endpoint/server pair), so mappings onto arbitrarily
        named units rank correctly. Precomputing this list at deployment
        time is the failover analogue of the Explorer's mapping-file
        artifact set.
        """
        dead_u = set(exclude_units)
        dead_l = {frozenset(p) for p in exclude_links}
        candidates: List[Mapping] = list(self.mappings())
        for u in self.platform.units:
            candidates.append(Mapping(f"{self.g.name}-all-{u}",
                                      {n: u for n in self.g.actors},
                                      self.platform))
        seen: set = set()
        ranked: List[Tuple[float, Mapping]] = []
        for m in candidates:
            key = tuple(sorted(m.assignment.items()))
            if key in seen:
                continue
            seen.add(key)
            if dead_u & set(m.units_used()):
                continue
            pairs = {frozenset((m.unit_of(f.src.actor.name),
                                m.unit_of(f.dst.actor.name)))
                     for f in m.boundary_edges(self.g)}
            if any(p in dead_l or self.platform.links.get(p) is None
                   for p in pairs):
                continue
            ranked.append((self._e2e_latency_s(m), m))
        ranked.sort(key=lambda t: t[0])
        return [m for _, m in ranked]

    def _e2e_latency_s(self, m: Mapping) -> float:
        """Modeled single-frame end-to-end latency of an arbitrary
        mapping: every actor's compute on its assigned unit, plus every
        boundary channel's wire time, link latency, and sender-side TX
        CPU cost (nothing overlaps within one frame, Sec IV.D)."""
        prog = synthesize(self.g, m)
        t = sum(self.model.actor_time_s(m.unit_of(a.name), a)
                for a in self.order)
        t += sum(self.model.transfer_time_s(c.src_unit, c.dst_unit,
                                            c.token_bytes)
                 for c in prog.channels)
        t += sum(self.model.tx_cpu_time_s(c.src_unit, c.token_bytes)
                 for c in prog.channels)
        return t

    def generate_artifacts(self, outdir: str) -> List[str]:
        """Write the paper's artifact set: per-partition-point mapping file
        pairs (endpoint-side + server-side) and a profiling script."""
        os.makedirs(outdir, exist_ok=True)
        paths: List[str] = []
        for pp, m in enumerate(self.mappings(), start=1):
            for side, unit in (("endpoint", self.endpoint), ("server", self.server)):
                p = os.path.join(outdir, f"pp{pp:02d}.{side}.mapping.json")
                write_mapping_file(p, m, local_unit=unit)
                paths.append(p)
        script = os.path.join(outdir, "profile_all.sh")
        with open(script, "w") as fh:
            fh.write("#!/bin/sh\n# Auto-generated by the Edge-PRUNE Explorer.\n")
            fh.write("# Profiles every partition-point mapping alternative.\n")
            for pp in range(1, len(self.order) + 1):
                fh.write(
                    f"python -m repro.launch.profile_mapping --graph {self.g.name} "
                    f"--mapping pp{pp:02d} --frames \"$1\"\n")
        os.chmod(script, 0o755)
        paths.append(script)
        return paths

    # ------------------------------------------------------------------
    def _modeled_record(self, pp: int, m: Mapping) -> PartitionRecord:
        prog = synthesize(self.g, m)
        endpoint_actors = [a.name for a in self.order
                           if m.unit_of(a.name) == self.endpoint]
        ep_s = sum(self.model.actor_time_s(self.endpoint, a)
                   for a in self.order if m.unit_of(a.name) == self.endpoint)
        sv_s = sum(self.model.actor_time_s(self.server, a)
                   for a in self.order if m.unit_of(a.name) == self.server)
        # Split boundary traffic by link semantics: additive links charge
        # the endpoint's per-frame budget; overlapping links (buffered
        # sockets) pipeline against compute -> max() combining.
        tx_add = sum(self.model.transfer_bw_time_s(c.src_unit, c.dst_unit,
                                                   c.token_bytes)
                     for c in prog.channels
                     if not self.model.link_overlaps(c.src_unit, c.dst_unit))
        tx_ovl = sum(self.model.transfer_bw_time_s(c.src_unit, c.dst_unit,
                                                   c.token_bytes)
                     for c in prog.channels
                     if self.model.link_overlaps(c.src_unit, c.dst_unit))
        lat = sum(self.model.transfer_time_s(c.src_unit, c.dst_unit, 0)
                  for c in prog.channels)
        # Sender-side CPU cost (GPU readback + socket syscalls) never
        # overlaps: it is charged to the endpoint's per-frame budget.
        tx_cpu = sum(self.model.tx_cpu_time_s(c.src_unit, c.token_bytes)
                     for c in prog.channels if c.src_unit == self.endpoint)
        endpoint_time = max(ep_s + tx_add + tx_cpu, tx_ovl)
        return PartitionRecord(
            pp=pp, mapping_name=m.name, endpoint_actors=endpoint_actors,
            boundary_bytes=prog.comm_bytes_per_iteration(),
            endpoint_compute_s=ep_s, transfer_s=tx_add + tx_ovl,
            server_compute_s=sv_s,
            # Fig 4-6 metric: what the endpoint spends per frame.
            endpoint_time_s=endpoint_time,
            # Single-frame latency: nothing overlaps (Sec IV.D).
            e2e_latency_s=ep_s + tx_add + tx_ovl + tx_cpu + lat + sv_s)

    def evaluate_modeled(self) -> ExplorationResult:
        res = ExplorationResult(self.g.name, self.platform.name)
        for pp, m in enumerate(self.mappings(), start=1):
            res.records.append(self._modeled_record(pp, m))
        return res

    def evaluate_simulated(self, frames: int,
                           source_inputs: Optional[Dict[str, List[Any]]] = None
                           ) -> ExplorationResult:
        """Token-accurate simulation: actually fires the actors (real JAX
        compute on this CPU) at every partition point."""
        res = ExplorationResult(self.g.name, self.platform.name)
        for pp, m in enumerate(self.mappings(), start=1):
            sim = Simulator(self.g, mapping=m, platform=self.model)
            out = sim.run(frames, source_inputs=source_inputs)
            rec = self._modeled_record(pp, m)
            rec.wall_s = out.wall_total_s / max(frames, 1)
            res.records.append(rec)
        return res
