"""Calibrated device/link constants for the paper's platforms (Tables I-II).

The paper reports *measured* inference times on three devices the container
does not have (ODROID N2 / Mali G52, Intel Atom N270, Intel i7-8650U). To
reproduce the partition-point sweeps (Figs 4-6) we calibrate an analytic
per-device model

    t_actor = overhead + max(flops / FLOPS, weight_bytes / MEM_BW)

against the paper's own anchor measurements, then *predict* every other
point of the sweeps and check the optimal partition points and speedups
match. Derivations:

Vehicle CNN (Fig 2, input 96x96x3 fp32 = 110592 B/token):
  * token sizes: L1->L2 = 294912 B = 48x48x32 fp32, L2->L3 = 73728 B =
    24x24x32 fp32 — both match the paper's figure exactly, fixing the
    layer geometry (conv 5x5x32 + maxpool/2, twice; then dense 100, 100,
    n_classes).
  * N2 full-endpoint = 18.9 ms and PP3 endpoint time = 14.9 ms with a
    73728 B boundary token over 11.2 MB/s Ethernet (6.6 ms) imply
    conv-compute(L1+L2) ~ 8.3 ms -> N2 conv throughput ~ 19.5 GFLOP/s,
    and dense-side (L3..L5) ~ 10.6 ms, dominated by L3's 7.37 MB weight
    read -> effective FC bandwidth ~ 0.77 GB/s (ARM CL fp32 FC on Mali).
  * N270 full-endpoint = 443 ms -> ~0.38 GFLOP/s plain-C throughput, with
    PP2 = 167 ms (Ethernet) pinning conv1 time ~ 140 ms.
  * i7 server: calibrated from the end-to-end latency split (Sec IV.D:
    6.3 ms for L3..L5 on oneDNN) -> FC bandwidth ~ 1.3 GB/s effective,
    conv throughput ~ 40 GFLOP/s (never the bottleneck in the sweeps).

Known residual (documented, not hidden): the paper's PP3-on-WiFi point
(17.1 ms) implies an *effective* in-application WiFi throughput of
~8.4 MB/s, higher than Table II's synthetic 2.3 MB/s measurement —
consistent with socket buffering overlapping computation during the
pipelined 384-frame run. We therefore keep two link models per network:
``synthetic`` (Table II) and ``effective`` (calibrated); EXPERIMENTS.md
reports the sweep under both.

SSD-Mobilenet (Fig 3): N2 full-endpoint = 2360 ms over ~2.44 GFLOP of
conv work -> ~1.0 GFLOP/s effective OpenCL throughput (depthwise convs
have very low arithmetic intensity on Mali); best Ethernet partition
(after DWCL9) = 406 ms = 5.8x, WiFi best 470 ms at PP9.
"""
from __future__ import annotations

# Effective sustained conv/GEMM throughput (FLOP/s), calibrated as above.
N2_FLOPS = 19.5e9          # Mali G52, ARM CL conv layers
N2_FC_MEM_BW = 0.77e9      # Mali G52, ARM CL fully-connected weight read
N2_OPENCL_FLOPS = 1.03e9   # Mali G52, generic OpenCL kernels (SSD-Mobilenet)
N270_FLOPS = 0.382e9       # Atom N270, plain C
N270_FC_MEM_BW = 0.30e9
I7_FLOPS = 40e9            # i7-8650U, oneDNN
I7_FC_MEM_BW = 1.3e9
I7_OPENCL_FLOPS = 6.0e9    # i7 UHD 620, OpenCL (SSD-Mobilenet server side)

# Per-firing overhead: thread wakeup + kernel launch.
N2_FIRING_OVERHEAD_S = 2.5e-4
N270_FIRING_OVERHEAD_S = 1.0e-4
I7_FIRING_OVERHEAD_S = 1.0e-4

# Link models: (bandwidth bytes/s, latency s, overlap). ``synthetic`` =
# Table II measured throughput, additive cost; ``effective`` = calibrated
# in-application behaviour. Calibration finding (documented residual): the
# paper's N2 WiFi sweep is only self-consistent if transmission OVERLAPS
# endpoint compute (socket buffering) at ~4.3 MB/s sustained: then
# PP3 = max(9.1 ms compute, 73728 B / 4.31 MB/s) = 17.1 ms  (paper: 17.1)
# PP1 = max(~0,      110592 B / 4.31 MB/s) = 25.7 ms  > 18.9 full-endpoint
# both matching Sec IV.B. The Ethernet path is CPU-bound (100 Mbit NIC)
# and behaves additively at the Table II throughput.
LINKS = {
    ("N2", "ethernet", "synthetic"):   (11.2e6, 1.49e-3, False),
    ("N2", "ethernet", "effective"):   (11.2e6, 1.49e-3, False),
    ("N2", "wifi", "synthetic"):       (2.3e6, 2.15e-3, False),
    ("N2", "wifi", "effective"):       (4.31e6, 2.15e-3, True),
    # SSD tokens (739 KB) far exceed the socket buffer, so WiFi transfers
    # cannot fully overlap compute there: additive at the sustained rate.
    ("N2", "wifi", "ssd_effective"):   (4.31e6, 2.15e-3, False),
    ("N270", "ethernet", "synthetic"): (11.2e6, 1.21e-3, False),
    ("N270", "ethernet", "effective"): (11.2e6, 1.21e-3, False),
    ("N270", "wifi", "synthetic"):     (4.7e6, 1.22e-3, False),
    ("N270", "wifi", "effective"):     (4.7e6, 1.22e-3, False),
}

# SSD-Mobilenet per-actor calibration (N2 OpenCL). Three regimes govern
# Mali OpenCL layer times:  t = ovh + max(conv_flops/CONV, dw_flops/DW,
# activation_traffic/MEM_BW).  The early high-resolution blocks are
# MEMORY-bound (large feature maps through a ~0.2 GB/s effective OpenCL
# buffer path), which is exactly why the paper's optimal Ethernet cut sits
# as deep as DWCL9: everything before it is expensive per FLOP, everything
# after it is cheap-but-large-weights, and the 19x19x512 token (739328 B)
# is the first 'cheap to ship' boundary. Constants solved against the
# paper's anchors: full-endpoint 2360 ms, best-Ethernet 406 ms at
# Input..DWCL9, best-WiFi 470 ms (Sec IV.B).
N2_SSD_CONV_FLOPS = 9.5e9    # pointwise / standard convs, OpenCL on Mali
N2_SSD_DW_FLOPS = 1.2e9      # depthwise convs, OpenCL on Mali
N2_SSD_MEM_BW = 0.26e9       # effective OpenCL activation r/w bandwidth
N2_SSD_NMS_S = 0.26          # plain-C NMS over 1917 priors x classes
N2_SSD_TRACKER_S = 1.62      # plain-C object tracker
# CPU cost of shipping a byte off the N2 during the SSD runs: OpenCL
# buffer readback from the Mali + socket syscalls (~17 MB/s effective);
# the ARM CL vehicle pipeline keeps tensors CPU-side (zero readback).
N2_SSD_TX_COST_PER_BYTE = 56e-9
I7_SSD_SPEEDUP = 8.0         # i7 UHD620 OpenCL vs Mali, per actor

# Sec IV.D: "inference time for single images [is] much slower than
# inference for image sequences due to CPU cache behavior" — single-frame
# endpoint compute runs cache-cold. Calibrated from 17.5 ms single-frame
# vs 9.07 ms pipelined for Input+L1+L2 on the N2.
N2_COLD_START_FACTOR = 1.93

# Paper anchor measurements (seconds) used for validation in the benchmarks.
PAPER_ANCHORS = {
    "vehicle_n2_full_endpoint": 18.9e-3,
    "vehicle_n2_pp3_ethernet": 14.9e-3,
    "vehicle_n2_pp3_wifi": 17.1e-3,
    "vehicle_n2_pp1_ethernet": 9.0e-3,
    "vehicle_n270_full_endpoint": 443e-3,
    "vehicle_n270_pp2_ethernet": 167e-3,
    "vehicle_n270_pp2_wifi": 191e-3,
    "ssd_n2_full_endpoint": 2360e-3,
    "ssd_n2_best_ethernet": 406e-3,
    "ssd_n2_best_wifi": 470e-3,
    "ssd_speedup": 5.8,
    "latency_e2e": 31.2e-3,
    "latency_split": (0.57, 0.23, 0.20),  # endpoint / network / server
}
