"""Platform graph + mapping files (Edge-PRUNE Sec III.C) and device models.

Edge-PRUNE requires, besides the application graph, (a) an undirected
*platform graph* listing processing units and their interconnections, and
(b) a *mapping file* assigning each actor to exactly one processing unit.
Only the mapping file changes between distributed scenarios.

``PlatformModel`` additionally carries analytic device/link constants so
the Explorer can *model* execution time on hardware we do not have (the
paper's N2 / N270 / i7 devices, and TPU v5e pods). Constants for the
paper's platforms are calibrated in ``repro.core.calibration`` from the
paper's own measurements (Tables I-II, Figs 4-6).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Tuple


@dataclass(frozen=True)
class ProcessingUnit:
    name: str
    kind: str = "cpu"             # cpu | gpu | tpu
    flops: float = 1e9            # effective sustained FLOP/s for conv/gemm
    mem_bandwidth: float = 1e9    # bytes/s effective weight-read bandwidth
    firing_overhead_s: float = 0.0  # thread wakeup / kernel launch per firing
    # CPU-side cost of *sending* one byte off-device (GPU buffer readback +
    # socket syscalls); charged to the sender on top of link time.
    tx_cost_per_byte: float = 0.0


@dataclass(frozen=True)
class Link:
    a: str
    b: str
    bandwidth: float              # bytes/s (measured throughput, Table II)
    latency_s: float = 0.0
    # overlap=True: transfers overlap with endpoint compute (DMA/socket
    # buffering), so per-frame time is max(compute, tx) instead of
    # compute + tx. Calibration shows the paper's WiFi runs behave this
    # way while the (CPU-bound) 100 Mbit Ethernet path is additive.
    overlap: bool = False

    @property
    def key(self) -> FrozenSet[str]:
        return frozenset((self.a, self.b))


@dataclass
class PlatformGraph:
    """Undirected platform graph: processing units + interconnections."""

    name: str
    units: Dict[str, ProcessingUnit] = field(default_factory=dict)
    links: Dict[FrozenSet[str], Link] = field(default_factory=dict)

    def add_unit(self, u: ProcessingUnit) -> "PlatformGraph":
        self.units[u.name] = u
        return self

    def add_link(self, link: Link) -> "PlatformGraph":
        if link.a not in self.units or link.b not in self.units:
            raise ValueError(f"link {link.a}-{link.b} references unknown unit")
        self.links[link.key] = link
        return self

    def link_between(self, a: str, b: str) -> Optional[Link]:
        return self.links.get(frozenset((a, b)))


@dataclass
class PlatformModel:
    """Analytic roofline-style execution model on a platform graph:

        t_actor = overhead + max(flops / FLOPS, weight_bytes / MEM_BW)
    """

    platform: PlatformGraph

    def compute_time_s(self, unit: str, flops: float,
                       mem_bytes: float = 0.0) -> float:
        u = self.platform.units[unit]
        return u.firing_overhead_s + max(flops / u.flops,
                                         mem_bytes / u.mem_bandwidth)

    def actor_time_s(self, unit: str, actor) -> float:
        """Per-actor modeled time. An actor may pin calibrated wall times
        per unit in ``meta['unit_time_s']`` (used for the SSD-Mobilenet
        actors whose OpenCL depthwise/NMS/tracking costs do not follow a
        single per-device FLOP rate); otherwise the roofline formula."""
        pinned = actor.meta.get("unit_time_s") if actor.meta else None
        if pinned and unit in pinned:
            return pinned[unit]
        return self.compute_time_s(unit, actor.cost_flops, actor.cost_mem_bytes)

    def stage_time_s(self, unit: str, actors) -> float:
        """Modeled time for one pipeline stage: every actor mapped to
        ``unit`` firing once (one graph iteration's worth of work)."""
        return sum(self.actor_time_s(unit, a) for a in actors)

    def transfer_bw_time_s(self, src_unit: str, dst_unit: str,
                           nbytes: int) -> float:
        if src_unit == dst_unit:
            return 0.0
        link = self.platform.link_between(src_unit, dst_unit)
        if link is None:
            raise ValueError(f"no link between {src_unit} and {dst_unit}")
        return nbytes / link.bandwidth

    def transfer_time_s(self, src_unit: str, dst_unit: str, nbytes: int) -> float:
        if src_unit == dst_unit:
            return 0.0
        link = self.platform.link_between(src_unit, dst_unit)
        if link is None:
            raise ValueError(f"no link between {src_unit} and {dst_unit}")
        return link.latency_s + nbytes / link.bandwidth

    def link_overlaps(self, src_unit: str, dst_unit: str) -> bool:
        link = self.platform.link_between(src_unit, dst_unit)
        return bool(link and link.overlap)

    def tx_cpu_time_s(self, src_unit: str, nbytes: int) -> float:
        return self.platform.units[src_unit].tx_cost_per_byte * nbytes

    def boundary_charge_s(self, src_unit: str, dst_unit: str,
                          nbytes: int) -> Tuple[float, float, float, float]:
        """The single source of truth for how a cross-unit transfer is
        charged on pipelined clocks. Returns ``(cpu_s, link_s,
        sender_block_s, token_delay_s)`` relative to the sender's compute
        finish: the sender stays busy for its CPU readback/syscall cost
        plus — on additive (non-overlapping) links — the wire time; the
        token lands at the receiver after CPU + wire time either way."""
        link_s = self.transfer_time_s(src_unit, dst_unit, nbytes)
        cpu_s = self.tx_cpu_time_s(src_unit, nbytes)
        block_s = cpu_s + (0.0 if self.link_overlaps(src_unit, dst_unit)
                           else link_s)
        return cpu_s, link_s, block_s, cpu_s + link_s


class Mapping:
    """Assigns each actor to exactly one processing unit.

    In each platform-specific mapping file, each actor is defined either
    for local or remote execution; the Edge-PRUNE compiler needs only this
    file to change the distributed scenario.
    """

    def __init__(self, name: str, assignment: Dict[str, str],
                 platform: Optional[PlatformGraph] = None):
        self.name = name
        self.assignment = dict(assignment)
        self.platform = platform
        if platform is not None:
            for actor, unit in assignment.items():
                if unit not in platform.units:
                    raise ValueError(
                        f"mapping {name}: actor {actor} mapped to unknown "
                        f"unit {unit}")

    def unit_of(self, actor_name: str) -> str:
        try:
            return self.assignment[actor_name]
        except KeyError:
            raise KeyError(
                f"mapping {self.name}: actor {actor_name} is unmapped — every "
                f"actor must be assigned to exactly one processing unit")

    def units_used(self) -> List[str]:
        return sorted(set(self.assignment.values()))

    def boundary_edges(self, g) -> List:
        """Edges whose endpoints live on different units — these are the
        edges the synthesizer replaces with TX/RX FIFO pairs."""
        out = []
        for f in g.fifos.values():
            if self.unit_of(f.src.actor.name) != self.unit_of(f.dst.actor.name):
                out.append(f)
        return out

    def excluding(self, dead_units, fallback_unit: str, *,
                  name: Optional[str] = None) -> "Mapping":
        """Re-map every actor assigned to a unit in ``dead_units`` onto
        ``fallback_unit`` — the failover controller's last-resort recovery
        when no precomputed fallback mapping avoids the dead set. The
        application graph is untouched (the Edge-PRUNE invariant): only
        the assignment changes, so the re-synthesized program computes the
        same function on the surviving units."""
        dead = set(dead_units)
        if fallback_unit in dead:
            raise ValueError(
                f"fallback unit {fallback_unit} is itself in the dead set")
        if self.platform is not None and fallback_unit not in self.platform.units:
            raise ValueError(f"fallback unit {fallback_unit} not in platform")
        assignment = {actor: (fallback_unit if unit in dead else unit)
                      for actor, unit in self.assignment.items()}
        return Mapping(name or f"{self.name}-sans-{'+'.join(sorted(dead))}",
                       assignment, self.platform)

    @staticmethod
    def partition_point(g, pp: int, *, endpoint: str = "endpoint",
                        server: str = "server",
                        platform: Optional[PlatformGraph] = None) -> "Mapping":
        """The Explorer's canonical mapping family: actors with precedence
        index < pp run on the endpoint device, the rest on the server.
        ``pp == 0`` → everything on the server (raw-input offload);
        ``pp == len(actors)`` → full endpoint inference."""
        prec = g.precedence_index()
        assignment = {name: (endpoint if idx < pp else server)
                      for name, idx in prec.items()}
        return Mapping(f"{g.name}-pp{pp}", assignment, platform)


# ---------------------------------------------------------------------------
# Paper platforms (Tables I and II) with calibrated effective FLOP rates.
# ---------------------------------------------------------------------------

def paper_platform(endpoint: str = "N2", connection: str = "ethernet",
                   *, link_model: str = "effective",
                   workload: str = "vehicle") -> PlatformGraph:
    """Platform graph for the paper's experiments (Tables I-II).

    Effective FLOP/s and FC memory bandwidths are *calibrated* from the
    paper's own anchor measurements — see ``repro.core.calibration`` for
    the derivation and EXPERIMENTS.md for the fidelity check.

    ``workload`` selects the endpoint compute library the paper used:
    'vehicle' = ARM CL (N2) / plain C (N270); 'ssd' = generic OpenCL.
    ``link_model`` is 'synthetic' (Table II measured throughput) or
    'effective' (calibrated in-application throughput; differs only for
    WiFi — see calibration.py).
    """
    from repro.core import calibration as cal
    if endpoint == "N2":
        flops = cal.N2_OPENCL_FLOPS if workload == "ssd" else cal.N2_FLOPS
        tx_cost = cal.N2_SSD_TX_COST_PER_BYTE if workload == "ssd" else 0.0
        dev = ProcessingUnit("endpoint", "gpu", flops, cal.N2_FC_MEM_BW,
                             cal.N2_FIRING_OVERHEAD_S, tx_cost)
    elif endpoint == "N270":
        dev = ProcessingUnit("endpoint", "cpu", cal.N270_FLOPS,
                             cal.N270_FC_MEM_BW, cal.N270_FIRING_OVERHEAD_S)
    else:
        raise ValueError(f"unknown endpoint {endpoint}")
    server_flops = cal.I7_OPENCL_FLOPS if workload == "ssd" else cal.I7_FLOPS
    server = ProcessingUnit("server", "cpu", server_flops, cal.I7_FC_MEM_BW,
                            cal.I7_FIRING_OVERHEAD_S)
    key = (endpoint, connection, link_model)
    if workload == "ssd" and (endpoint, connection, "ssd_" + link_model) in cal.LINKS:
        key = (endpoint, connection, "ssd_" + link_model)
    bw, lat, overlap = cal.LINKS[key]
    pg = PlatformGraph(f"{endpoint}-i7-{connection}")
    pg.add_unit(dev).add_unit(server)
    pg.add_link(Link("endpoint", "server", bandwidth=bw, latency_s=lat,
                     overlap=overlap))
    return pg


def tpu_pod_platform(num_pods: int = 2, *, chips_per_pod: int = 256,
                     chip_flops: float = 197e12, ici_bw: float = 50e9,
                     dcn_bw: float = 25e9) -> PlatformGraph:
    """TPU analogue of the paper's endpoint/server split: each pod is one
    'processing unit' (inference stage); pods are linked by DCN. Used by
    the Explorer to reason about pod-boundary partition points."""
    pg = PlatformGraph(f"tpu-{num_pods}pods")
    for i in range(num_pods):
        name = "endpoint" if i == 0 else (f"server{i - 1}" if num_pods > 2 else "server")
        pg.add_unit(ProcessingUnit(name, "tpu", chip_flops * chips_per_pod))
    units = list(pg.units)
    for i in range(len(units) - 1):
        pg.add_link(Link(units[i], units[i + 1], bandwidth=dcn_bw, latency_s=1e-5))
    return pg
