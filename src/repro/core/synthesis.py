"""Code synthesis: graph + mapping -> executable staged program (Sec III.B-C).

The Edge-PRUNE compiler takes the application graph, actor behaviours, the
platform graph and a mapping file, and synthesizes a top-level application
per device; TX/RX FIFOs are inserted automatically wherever an edge crosses
the device boundary, so the application graph itself never changes.

This module is the JAX analogue:

* ``split(graph, mapping)`` — partition the actor set by processing unit
  and derive the boundary *channels* (the TX/RX FIFO pairs). Pure graph
  transformation, no jax.
* ``StagedProgram`` — an executable distributed program: one ``StageFn``
  per processing unit (a topologically-fused composition of that unit's
  actor fire functions, jit-compatible when the fire functions are pure
  JAX), plus channel metadata. ``run_local`` executes the stages in
  precedence order in-process (functionally identical to distributed
  execution; the channels become array hand-offs). On a TPU mesh the same
  channels lower to ``jax.lax.ppermute`` across the ``pod`` axis — see
  ``repro.launch.pipeline``.
* ``write_mapping_file`` / ``read_mapping_file`` — the paper's on-disk
  mapping-file workflow (the Explorer emits one pair per partition point).

Restriction (same as the paper's synthesis path): the synthesized *staged*
program assumes single-rate (HSDF) behaviour per iteration — every actor
fires once per graph iteration with atr == url == lrl == 1 on every port.
Multi-rate and variable-rate graphs are executed by the token-accurate
``Simulator``; DNN inference graphs (the paper's and ours) are single-rate.

A unit may appear *multiple times* along the dataflow: an
endpoint→server→endpoint mapping synthesizes into three stage *segments*
(maximal dependency-respecting runs of one unit), two of them on the
endpoint. Segments of the same unit share one physical busy clock in
``run_pipelined`` (they contend, never overlap), and cross-segment edges
within one unit hand tokens over for free — only genuinely cross-unit
channels are charged against the platform's links.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.clocks import UnitClocks
from repro.core.graph import Actor, Fifo, Graph
from repro.core.mapping import Mapping


@dataclass(frozen=True)
class Channel:
    """A synthesis-inserted TX/RX FIFO pair crossing a unit boundary."""

    name: str
    src_unit: str
    dst_unit: str
    src_actor: str
    src_port: str
    dst_actor: str
    dst_port: str
    token_shape: Tuple[int, ...]
    token_dtype: str
    token_bytes: int


@dataclass
class Stage:
    """One stage *segment*: a maximal dependency-respecting run of actors
    on one processing unit, in precedence order. ``key`` is the segment's
    unique name — the bare unit name for a unit's first segment (so every
    pre-existing mapping keeps its stage keys), ``unit#k`` for revisits."""

    unit: str
    actors: List[Actor]
    key: str = ""
    # Channels whose dst is in this stage (RX) / src is in this stage (TX).
    rx: List[Channel] = field(default_factory=list)
    tx: List[Channel] = field(default_factory=list)


def split(g: Graph, mapping: Mapping) -> Tuple[List[Stage], List[Channel]]:
    """Partition ``g`` by the mapping; derive boundary channels.

    Stages are segments, not whole units: walking the topo order, an
    actor joins its unit's latest segment when every producer it depends
    on lives in that segment or earlier, and opens a *new* segment of the
    same unit otherwise. A mapping that visits each unit once therefore
    splits exactly as before (one stage per unit); an
    endpoint→server→endpoint mapping yields three segments instead of
    fusing the endpoint's halves into one stage that would need the
    server's output before the server ran. Channels are emitted for every
    edge crossing a *segment* boundary — cross-unit ones carry the
    platform's link charge, same-unit ones (a unit handing tokens to its
    own later segment) are free. Cyclic unit dependencies (legal in the
    MoC via delay tokens) keep declaration order, as before.
    """
    order = g.topo_order()
    stages: List[Stage] = []
    seg_of: Dict[str, int] = {}         # actor name -> segment index
    last_seg: Dict[str, int] = {}       # unit -> its latest segment index
    seg_count: Dict[str, int] = {}      # unit -> segments opened so far
    for a in order:
        u = mapping.unit_of(a.name)
        # latest segment any producer of this actor lives in (back edges
        # from delay tokens resolve later; treat them as unconstraining)
        dep = max((seg_of.get(p.fifo.src.actor.name, -1)
                   for p in a.in_ports if p.fifo is not None), default=-1)
        si = last_seg.get(u, -1)
        if si >= 0 and si >= dep:
            stages[si].actors.append(a)
            seg_of[a.name] = si
        else:
            k = seg_count.get(u, 0)
            stages.append(Stage(unit=u, actors=[a],
                                key=u if k == 0 else f"{u}#{k}"))
            seg_count[u] = k + 1
            last_seg[u] = seg_of[a.name] = len(stages) - 1

    channels: List[Channel] = []
    for f in g.fifos.values():
        src, dst = f.src.actor.name, f.dst.actor.name
        if seg_of.get(src) == seg_of.get(dst):
            continue                    # intra-segment edge: env hand-off
        su = mapping.unit_of(src)
        du = mapping.unit_of(dst)
        ch = Channel(
            name=f"ch:{f.name}", src_unit=su, dst_unit=du,
            src_actor=src, src_port=f.src.name,
            dst_actor=dst, dst_port=f.dst.name,
            token_shape=f.src.token_shape, token_dtype=f.src.token_dtype,
            token_bytes=f.token_bytes)
        channels.append(ch)
        stages[seg_of[src]].tx.append(ch)
        stages[seg_of[dst]].rx.append(ch)
    return stages, channels


class StageFn:
    """Executable form of one stage: fuses the stage's actor firings.

    Calling convention::

        outputs = stage_fn(external_inputs, rx_tokens)

    ``external_inputs`` maps source-actor name -> token (for source actors
    in this stage); ``rx_tokens`` maps channel name -> token. The return
    is ``(tx_tokens, sink_outputs)``. The body is pure (all FIFO dynamics
    are resolved at synthesis time for the single-rate case), hence
    jit-compatible when actor fire functions are pure JAX.
    """

    def __init__(self, g: Graph, stage: Stage):
        self.g = g
        self.stage = stage
        self.unit = stage.unit
        self.key = stage.key or stage.unit
        self._member = {a.name for a in stage.actors}
        # Precompute wiring: for each actor input port, where does its
        # token come from (an intra-stage edge value or an RX channel)?
        self._rx_by_dst = {(c.dst_actor, c.dst_port): c for c in stage.rx}
        self._tx_by_src: Dict[Tuple[str, str], List[Channel]] = {}
        for c in stage.tx:
            self._tx_by_src.setdefault((c.src_actor, c.src_port), []).append(c)

    def __call__(self, external_inputs: Dict[str, Any],
                 rx_tokens: Dict[str, Any]
                 ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        # Value environment keyed by (actor, out_port).
        env: Dict[Tuple[str, str], Any] = {}
        tx_out: Dict[str, Any] = {}
        sink_out: Dict[str, Any] = {}
        for a in self.stage.actors:
            inputs: Dict[str, List[Any]] = {}
            for p in a.in_ports:
                if p.fifo is None:
                    continue
                key = (a.name, p.name)
                if key in self._rx_by_dst:
                    inputs[p.name] = [rx_tokens[self._rx_by_dst[key].name]]
                else:
                    src = p.fifo.src
                    inputs[p.name] = [env[(src.actor.name, src.name)]]
            if a.is_source and a.name in external_inputs:
                inputs["__feed__"] = [external_inputs[a.name]]
            rates = {p.name: 1 for p in a.in_ports + a.out_ports}
            outputs, _ = a.fire_fn(inputs, None, rates) if a.fire_fn else ({}, None)
            for p in a.out_ports:
                toks = outputs.get(p.name, [])
                if len(toks) != 1:
                    raise ValueError(
                        f"staged synthesis requires single-rate actors; "
                        f"{a.name}.{p.name} produced {len(toks)} tokens")
                env[(a.name, p.name)] = toks[0]
                for ch in self._tx_by_src.get((a.name, p.name), []):
                    tx_out[ch.name] = toks[0]
            if a.is_sink and isinstance(outputs, dict) and "result" in outputs:
                sink_out[a.name] = outputs["result"]
        return tx_out, sink_out


@dataclass
class StageExec:
    """One (frame, stage) execution in a pipelined schedule."""

    frame: int
    unit: str
    start_s: float
    finish_s: float


@dataclass
class PipelineSchedule:
    """Modeled timeline of pipelined multi-frame execution.

    ``makespan_s`` lets frame i+1 enter stage k-1 while frame i occupies
    stage k (per-unit clocks advance concurrently); ``sequential_s`` is
    the same stage/link costs with each frame draining completely before
    the next starts — the paper's non-pipelined baseline. Their ratio is
    the modeled pipelining speedup (Edge-PRUNE Sec III.B / Fig 6).
    """

    entries: List[StageExec] = field(default_factory=list)
    makespan_s: float = 0.0
    sequential_s: float = 0.0
    unit_busy_s: Dict[str, float] = field(default_factory=dict)
    # Per-frame ack instants: when frame i's last stage finished (the
    # point at which the failover controller may drop its checkpoint —
    # everything after it is replayable state, Edge-PRUNE follow-up).
    frame_done_s: List[float] = field(default_factory=list)

    @property
    def speedup(self) -> float:
        return self.sequential_s / self.makespan_s if self.makespan_s else 1.0


@dataclass
class StagedProgram:
    graph: Graph
    mapping: Mapping
    stages: List[Stage]
    channels: List[Channel]
    stage_fns: Dict[str, StageFn]

    def run_local(self, external_inputs: Dict[str, Any]) -> Dict[str, Any]:
        """Execute all stages in precedence order in-process. Functionally
        identical to distributed execution over TX/RX channels."""
        tokens: Dict[str, Any] = {}
        sinks: Dict[str, Any] = {}
        for st in self.stages:
            fn = self.stage_fns[st.key or st.unit]
            rx = {c.name: tokens[c.name] for c in st.rx}
            tx, sk = fn(external_inputs, rx)
            tokens.update(tx)
            sinks.update(sk)
        return sinks

    def run_pipelined(self, frames: List[Dict[str, Any]], *,
                      platform=None, arrivals: Optional[List[float]] = None
                      ) -> Tuple[List[Dict[str, Any]], PipelineSchedule]:
        """Execute ``frames`` (a list of ``external_inputs``) through the
        stages as a pipeline: stage k of frame i overlaps stage k-1 of
        frame i+1 on the modeled clocks.

        Outputs are token-identical to ``run_local`` per frame (stage
        functions are pure); what pipelining changes is the *modeled*
        timeline, computed against ``platform`` (a ``PlatformModel``) with
        per-unit busy clocks and per-channel link charges. Non-overlapping
        links (calibration's additive Ethernet behaviour) also block the
        sending unit for the transfer duration; overlapping links only
        delay token availability at the receiver.

        Each frame has an *ack point*: the modeled instant its final stage
        finished, recorded in ``PipelineSchedule.frame_done_s`` — the
        timestamps the resilience subsystem compares against a failure
        instant to decide which checkpointed frames are committed and
        which must replay.
        """
        if arrivals is not None and len(arrivals) != len(frames):
            raise ValueError(f"arrivals has {len(arrivals)} entries for "
                             f"{len(frames)} frames")
        arrivals = arrivals or [0.0] * len(frames)
        stage_s = [platform.stage_time_s(st.unit, st.actors)
                   if platform else 0.0 for st in self.stages]
        # clocks are per PHYSICAL unit: two segments of the same unit
        # (an endpoint→server→endpoint mapping) contend for one clock
        unit_clock = UnitClocks()
        sched = PipelineSchedule()
        sinks_per_frame: List[Dict[str, Any]] = []
        seq_clock = 0.0   # sequential baseline: one frame at a time
        for fi, frame in enumerate(frames):
            tokens: Dict[str, Any] = {}
            tok_ready: Dict[str, float] = {}
            sinks: Dict[str, Any] = {}
            frame_cost = 0.0
            frame_done = 0.0
            for si, st in enumerate(self.stages):
                ready = arrivals[fi]
                for c in st.rx:
                    ready = max(ready, tok_ready[c.name])
                start = unit_clock.start(st.unit, ready)
                finish = start + stage_s[si]
                frame_cost += stage_s[si]
                rx = {c.name: tokens[c.name] for c in st.rx}
                tx, sk = self.stage_fns[st.key or st.unit](frame, rx)
                tokens.update(tx)
                sinks.update(sk)
                for c in st.tx:
                    block_s = delay_s = 0.0
                    if platform is not None and c.src_unit != c.dst_unit:
                        _, _, block_s, delay_s = platform.boundary_charge_s(
                            c.src_unit, c.dst_unit, c.token_bytes)
                    tok_ready[c.name] = finish + delay_s
                    frame_cost += delay_s
                    finish += block_s
                unit_clock.set(st.unit, finish)
                sched.unit_busy_s[st.unit] = (
                    sched.unit_busy_s.get(st.unit, 0.0) + finish - start)
                sched.entries.append(StageExec(fi, st.unit, start, finish))
                sched.makespan_s = max(sched.makespan_s,
                                       *tok_ready.values(), finish)
                frame_done = max(frame_done, finish)
            seq_clock = max(seq_clock, arrivals[fi]) + frame_cost
            sched.frame_done_s.append(frame_done)
            sinks_per_frame.append(sinks)
        sched.sequential_s = seq_clock
        return sinks_per_frame, sched

    def comm_bytes_per_iteration(self) -> int:
        """Bytes that actually cross a device boundary per iteration —
        same-unit cross-segment hand-offs are in-memory and free."""
        return sum(c.token_bytes for c in self.channels
                   if c.src_unit != c.dst_unit)


def synthesize(g: Graph, mapping: Mapping) -> StagedProgram:
    """The Edge-PRUNE 'compiler': graph + mapping -> staged program."""
    stages, channels = split(g, mapping)
    fns = {st.key or st.unit: StageFn(g, st) for st in stages}
    return StagedProgram(g, mapping, stages, channels, fns)


def compile_local_step(g: Graph) -> Callable[[Dict[str, Any]], Dict[str, Any]]:
    """Single-unit special case: one callable running a whole iteration."""
    mapping = Mapping("local", {n: "local" for n in g.actors})
    prog = synthesize(g, mapping)
    return prog.run_local


# ---------------------------------------------------------------------------
# Mapping files on disk (the Explorer's output format, Sec III.C)
# ---------------------------------------------------------------------------

def write_mapping_file(path: str, mapping: Mapping, *, local_unit: str) -> None:
    """Write one platform-specific mapping file: every actor marked either
    'local' or 'remote' relative to ``local_unit`` — mirroring the paper's
    per-device mapping files."""
    data = {
        "mapping": mapping.name,
        "local_unit": local_unit,
        "actors": {a: ("local" if u == local_unit else "remote")
                   for a, u in mapping.assignment.items()},
        "units": mapping.assignment,
    }
    with open(path, "w") as fh:
        json.dump(data, fh, indent=2, sort_keys=True)


def read_mapping_file(path: str) -> Mapping:
    with open(path) as fh:
        data = json.load(fh)
    return Mapping(data["mapping"], data["units"])
