"""VR-PRUNE dataflow model of computation + Edge-PRUNE toolchain.

Public API::

    from repro.core import (
        Graph, Actor, Port, Fifo, ActorType, PortDir, Dpg,
        analyze, repetition_vector,
        Simulator, Mapping, PlatformGraph, PlatformModel,
        synthesize, StagedProgram, Explorer,
    )
"""
from repro.core.graph import (Actor, ActorType, Dpg, Fifo, Graph, Port,
                              PortDir, fifo, parent)
from repro.core.analyzer import AnalysisReport, analyze, repetition_vector
from repro.core.simulator import SimResult, Simulator
from repro.core.mapping import (Link, Mapping, PlatformGraph, PlatformModel,
                                ProcessingUnit, paper_platform,
                                tpu_pod_platform)
from repro.core.synthesis import (Channel, PipelineSchedule, Stage, StagedProgram,
                                  StageExec, StageFn, compile_local_step,
                                  read_mapping_file, synthesize,
                                  write_mapping_file)
from repro.core.explorer import ExplorationResult, Explorer, PartitionRecord

__all__ = [
    "Actor", "ActorType", "Dpg", "Fifo", "Graph", "Port", "PortDir",
    "fifo", "parent",
    "AnalysisReport", "analyze", "repetition_vector",
    "SimResult", "Simulator",
    "Link", "Mapping", "PlatformGraph", "PlatformModel", "ProcessingUnit",
    "paper_platform", "tpu_pod_platform",
    "Channel", "PipelineSchedule", "Stage", "StagedProgram", "StageExec",
    "StageFn", "compile_local_step",
    "read_mapping_file", "synthesize", "write_mapping_file",
    "ExplorationResult", "Explorer", "PartitionRecord",
]
