"""Per-unit modeled clocks — the one scheduling recurrence everything shares.

Every modeled timeline in this repo (the token-accurate ``Simulator``,
``StagedProgram.run_pipelined``, the serving stack's multi-unit
``ExecutionCore``) advances the same way: a piece of work on unit ``u``
starts when its inputs are ready AND the unit is free, and occupies the
unit until it finishes::

    start  = max(ready_s, clock[u])
    finish = start + cost_s
    clock[u] = finish

``UnitClocks`` is that recurrence as an object, so the three consumers
stop re-implementing it (and so their accounting — busy seconds per
unit, makespan — agrees by construction). Units exist lazily: a unit's
clock is 0.0 until the first charge touches it.
"""
from __future__ import annotations

from typing import Dict, Tuple

__all__ = ["UnitClocks"]


class UnitClocks:
    """Concurrent per-unit busy clocks over one modeled timeline."""

    def __init__(self) -> None:
        self._clock: Dict[str, float] = {}
        self._busy: Dict[str, float] = {}

    def now(self, unit: str) -> float:
        """The instant ``unit`` becomes free (0.0 if never charged)."""
        return self._clock.get(unit, 0.0)

    def start(self, unit: str, ready_s: float) -> float:
        """When work whose inputs land at ``ready_s`` could start."""
        return max(ready_s, self._clock.get(unit, 0.0))

    def set(self, unit: str, finish_s: float) -> None:
        """Advance ``unit``'s clock to ``finish_s`` (never backwards).
        For callers that compute the finish themselves (the Simulator
        folds link blocking into it); busy time is NOT accumulated —
        pair with ``busy_add`` when the caller tracks busy seconds."""
        if finish_s > self._clock.get(unit, 0.0):
            self._clock[unit] = finish_s

    def busy_add(self, unit: str, dur_s: float) -> None:
        self._busy[unit] = self._busy.get(unit, 0.0) + dur_s

    def charge(self, unit: str, ready_s: float,
               cost_s: float) -> Tuple[float, float]:
        """Occupy ``unit`` for ``cost_s`` starting no earlier than
        ``ready_s``: returns ``(start_s, finish_s)`` and advances the
        clock and the unit's busy total."""
        start = max(ready_s, self._clock.get(unit, 0.0))
        finish = start + cost_s
        self._clock[unit] = finish
        self._busy[unit] = self._busy.get(unit, 0.0) + cost_s
        return start, finish

    @property
    def makespan_s(self) -> float:
        """Latest clock across all units (0.0 when nothing ran)."""
        return max(self._clock.values(), default=0.0)

    @property
    def busy_s(self) -> Dict[str, float]:
        """Busy seconds per unit accumulated through ``charge``/
        ``busy_add`` (a copy; safe to mutate)."""
        return dict(self._busy)

    def clocks(self) -> Dict[str, float]:
        return dict(self._clock)
