"""Graph analyzer: VR-PRUNE design-rule and consistency checks (Sec III.C).

The paper's Analyzer checks the application graph against the VR-PRUNE
design rules and patterns so that DPGs are compile-time analyzable for
*consistency*: absence of deadlock and buffer overflow. This module
implements:

1. structural rules (port wiring, symmetric token-rate requirement on the
   static limits, DPG composition: 1 CA + 2 DAs + DPAs/SPAs, dynamic actor
   types only inside DPGs);
2. SDF-style *balance equations* over the static-rate skeleton to compute
   the repetition vector (consistency ⇒ bounded buffers);
3. bounded-buffer verification for a computed periodic schedule;
4. deadlock detection: every directed cycle must carry enough initial
   delay tokens to fire once.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from fractions import Fraction
from typing import Dict, List, Optional

from repro.core.graph import Actor, ActorType, Fifo, Graph


@dataclass
class AnalysisReport:
    ok: bool
    errors: List[str] = field(default_factory=list)
    warnings: List[str] = field(default_factory=list)
    repetition_vector: Optional[Dict[str, int]] = None
    max_buffer_occupancy: Optional[Dict[str, int]] = None

    def raise_on_error(self) -> "AnalysisReport":
        if not self.ok:
            raise ValueError("graph analysis failed:\n  " + "\n  ".join(self.errors))
        return self


def _check_structure(g: Graph, errors: List[str], warnings: List[str]) -> None:
    # Every port connected; every fifo endpoints attached.
    for a in g.actors.values():
        for p in a.in_ports + a.out_ports:
            if p.fifo is None and not (a.is_source and not a.in_ports):
                # Dangling OUT ports on sinks / IN ports on sources are the
                # only holes a valid app graph may not have.
                errors.append(f"dangling port {a.name}.{p.name}")
    # Dynamic actor types must live inside a DPG.
    for a in g.actors.values():
        if a.actor_type in (ActorType.DA, ActorType.DPA, ActorType.CA) and a.dpg is None:
            errors.append(
                f"{a.actor_type.value.upper()} actor {a.name} is outside any DPG "
                f"(VR-PRUNE rule: DAs, DPAs and CAs may only appear within DPGs)")
    # DPG composition rule: one CA, two DAs.
    for dpg in g.dpgs.values():
        members = [g.actors[m] for m in dpg.members]
        cas = [a for a in members if a.actor_type == ActorType.CA]
        das = [a for a in members if a.actor_type == ActorType.DA]
        if len(cas) != 1:
            errors.append(f"DPG {dpg.name}: must contain exactly 1 CA, found {len(cas)}")
        elif cas[0].name != dpg.ca:
            errors.append(f"DPG {dpg.name}: declared CA {dpg.ca} != actual {cas[0].name}")
        if len(das) != 2:
            errors.append(f"DPG {dpg.name}: must contain exactly 2 DAs, found {len(das)}")
        else:
            if {dpg.entry_da, dpg.exit_da} != {d.name for d in das}:
                errors.append(f"DPG {dpg.name}: entry/exit DA declaration mismatch")
        for a in members:
            if a.actor_type not in (ActorType.CA, ActorType.DA, ActorType.DPA, ActorType.SPA):
                errors.append(f"DPG {dpg.name}: illegal member type {a.actor_type}")
            if a.dpg != dpg.name:
                errors.append(f"DPG {dpg.name}: member {a.name} tagged with dpg={a.dpg}")
        # Variable-rate ports of boundary DAs must face *into* the DPG: the
        # external faces keep static rates so the enclosing graph stays SDF.
        member_set = set(dpg.members)
        for da_name in (dpg.entry_da, dpg.exit_da):
            if da_name not in g.actors:
                errors.append(f"DPG {dpg.name}: unknown DA {da_name}")
                continue
            da = g.actors[da_name]
            for p in da.in_ports + da.out_ports:
                if p.fifo is None:
                    continue
                other = (p.fifo.dst.actor if p is p.fifo.src else p.fifo.src.actor)
                crosses = other.name not in member_set
                if crosses and not p.is_static_rate:
                    errors.append(
                        f"DPG {dpg.name}: DA {da.name} port {p.name} crosses the "
                        f"DPG boundary but has a variable rate ({p.lrl}..{p.url}); "
                        f"boundary-facing ports must be static-rate")
    # Symmetric token-rate requirement — static limits must agree per edge
    # (atr symmetry is enforced at run time by the simulator/runtime).
    for f in g.fifos.values():
        if (f.src.lrl, f.src.url) != (f.dst.lrl, f.dst.url):
            # Rates may legitimately differ in SDF (multi-rate); the
            # *symmetric token rate requirement* applies to variable-rate
            # (DPG-internal) edges where atr(src)==atr(dst) must hold.
            src_dyn = not f.src.is_static_rate
            dst_dyn = not f.dst.is_static_rate
            if src_dyn or dst_dyn:
                errors.append(
                    f"edge {f.name}: variable-rate endpoints must carry identical "
                    f"rate limits (symmetric token rate requirement), got "
                    f"src=({f.src.lrl},{f.src.url}) dst=({f.dst.lrl},{f.dst.url})")


def repetition_vector(g: Graph) -> Dict[str, int]:
    """Solve the SDF balance equations over the static-rate skeleton.

    For each edge ``a --(prod r_a)--> (cons r_b)-- b`` consistency requires
    ``q[a] * r_a == q[b] * r_b``. Variable-rate edges are balanced at their
    upper rate limit (worst case for buffer sizing), which is sound because
    the symmetric token rate requirement forces atr(src)==atr(dst) — a
    variable-rate edge is *always* balanced token-for-token at run time.
    """
    q: Dict[str, Fraction] = {}
    adj: Dict[str, List[Fifo]] = {n: [] for n in g.actors}
    for f in g.fifos.values():
        adj[f.src.actor.name].append(f)
        adj[f.dst.actor.name].append(f)

    for start in g.actors:
        if start in q:
            continue
        q[start] = Fraction(1)
        stack = [start]
        while stack:
            n = stack.pop()
            for f in adj[n]:
                a, b = f.src.actor.name, f.dst.actor.name
                ra = max(f.src.url, 1)
                rb = max(f.dst.url, 1)
                if a in q and b not in q:
                    q[b] = q[a] * ra / rb
                    stack.append(b)
                elif b in q and a not in q:
                    q[a] = q[b] * rb / ra
                    stack.append(a)
                elif a in q and b in q:
                    if q[a] * ra != q[b] * rb:
                        raise ValueError(
                            f"graph {g.name} is inconsistent at edge {f.name}: "
                            f"{q[a]}*{ra} != {q[b]}*{rb} — no bounded-memory "
                            f"periodic schedule exists")
    # Scale to smallest integer vector.
    from math import lcm
    denom = 1
    for v in q.values():
        denom = lcm(denom, v.denominator)
    iq = {n: int(v * denom) for n, v in q.items()}
    from math import gcd
    gg = 0
    for v in iq.values():
        gg = gcd(gg, v)
    return {n: v // max(gg, 1) for n, v in iq.items()}


def check_deadlock(g: Graph, errors: List[str]) -> None:
    """Every directed cycle must contain initial delay tokens."""
    # Collapse to actor-level digraph; find SCCs (Tarjan); any SCC with >1
    # node or a self-loop must have at least one delay-carrying edge.
    index = 0
    idx: Dict[str, int] = {}
    low: Dict[str, int] = {}
    onstack: Dict[str, bool] = {}
    stack: List[str] = []
    sccs: List[List[str]] = []
    succ: Dict[str, List[str]] = {n: [] for n in g.actors}
    for f in g.fifos.values():
        succ[f.src.actor.name].append(f.dst.actor.name)

    def strongconnect(v: str) -> None:
        nonlocal index
        work = [(v, iter(succ[v]))]
        idx[v] = low[v] = index
        index += 1
        stack.append(v)
        onstack[v] = True
        while work:
            node, it = work[-1]
            advanced = False
            for w in it:
                if w not in idx:
                    idx[w] = low[w] = index
                    index += 1
                    stack.append(w)
                    onstack[w] = True
                    work.append((w, iter(succ[w])))
                    advanced = True
                    break
                elif onstack.get(w):
                    low[node] = min(low[node], idx[w])
            if advanced:
                continue
            work.pop()
            if work:
                low[work[-1][0]] = min(low[work[-1][0]], low[node])
            if low[node] == idx[node]:
                comp = []
                while True:
                    w = stack.pop()
                    onstack[w] = False
                    comp.append(w)
                    if w == node:
                        break
                sccs.append(comp)

    for n in g.actors:
        if n not in idx:
            strongconnect(n)

    for comp in sccs:
        cset = set(comp)
        internal = [f for f in g.fifos.values()
                    if f.src.actor.name in cset and f.dst.actor.name in cset]
        has_cycle = len(comp) > 1 or any(
            f.src.actor.name == f.dst.actor.name for f in internal)
        if has_cycle and not any(f.delay_tokens > 0 for f in internal):
            errors.append(
                f"deadlock: cycle through {sorted(cset)} carries no initial "
                f"delay tokens — no actor in the cycle can ever fire")


def check_buffer_bounds(g: Graph, rep: Dict[str, int],
                        errors: List[str]) -> Dict[str, int]:
    """Simulate one periodic iteration symbolically (token *counts* only,
    worst-case rates) and verify no FIFO exceeds its declared capacity."""
    remaining = dict(rep)
    occupancy = {f.name: f.delay_tokens for f in g.fifos.values()}
    peak = dict(occupancy)
    progress = True
    while any(v > 0 for v in remaining.values()) and progress:
        progress = False
        for a in g.topo_order():
            if remaining[a.name] <= 0:
                continue
            fires = remaining[a.name]
            for _ in range(fires):
                if not all(occupancy[p.fifo.name] >= p.url
                           for p in a.in_ports if p.fifo is not None):
                    break
                for p in a.in_ports:
                    if p.fifo is not None:
                        occupancy[p.fifo.name] -= p.url
                for p in a.out_ports:
                    if p.fifo is not None:
                        occupancy[p.fifo.name] += p.url
                        peak[p.fifo.name] = max(peak[p.fifo.name],
                                                occupancy[p.fifo.name])
                remaining[a.name] -= 1
                progress = True
    for f in g.fifos.values():
        if peak[f.name] > f.capacity:
            errors.append(
                f"buffer overflow: fifo {f.name} peaks at {peak[f.name]} tokens "
                f"but capacity is {f.capacity}")
    return peak


def analyze(g: Graph) -> AnalysisReport:
    """Run the full VR-PRUNE consistency analysis."""
    errors: List[str] = []
    warnings: List[str] = []
    _check_structure(g, errors, warnings)
    rep = None
    peak = None
    if not errors:
        try:
            rep = repetition_vector(g)
        except ValueError as e:
            errors.append(str(e))
        check_deadlock(g, errors)
        if rep is not None and not errors:
            peak = check_buffer_bounds(g, rep, errors)
    return AnalysisReport(ok=not errors, errors=errors, warnings=warnings,
                          repetition_vector=rep, max_buffer_occupancy=peak)
