"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Runs the real training loop on whatever devices exist (a debug mesh on
this CPU; the production mesh under the dry-run device flag). The same
train_step the multi-pod dry-run lowers is executed here — the launcher
and the dry-run share every code path except device count.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_debug_mesh
from repro.models import transformer as T
from repro.runtime import checkpoint, data, optim
from repro.runtime.trainstep import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    print(f"# arch={cfg.name} layers={cfg.n_layers} d={cfg.d_model} "
          f"params~{cfg.param_count()/1e6:.1f}M")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = optim.init(params)
    oc = optim.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                           total_steps=args.steps)
    step = jax.jit(make_train_step(cfg, oc, microbatches=args.microbatches),
                   donate_argnums=(0, 1))
    gen = data.lm_batches(args.batch, args.seq, cfg.vocab_size)
    t0 = time.time()
    for i, batch in zip(range(args.steps), gen):
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        if cfg.arch_type == "vlm":
            jb["embeds"] = jnp.zeros((args.batch, cfg.frontend_tokens,
                                      cfg.frontend_dim))
            jb["labels"] = jnp.concatenate(
                [jnp.full((args.batch, cfg.frontend_tokens), -1,
                          jnp.int32), jb["labels"]], axis=1)
        elif cfg.arch_type == "audio":
            jb["embeds"] = jnp.zeros((args.batch, args.seq, cfg.frontend_dim))
        params, opt, m = step(params, opt, jb)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} "
                  f"lr {float(m['lr']):.2e} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    if args.ckpt:
        checkpoint.save(args.ckpt, params,
                        meta={"arch": cfg.name, "steps": args.steps})
        print(f"# checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
