"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Spins up the batched ServeEngine over a (smoke-sized on CPU) model and
runs a synthetic request workload; ``--partition pp`` additionally serves
through the Edge-PRUNE partitioned actor graph at the given partition
point, reporting the boundary traffic — the paper's collaborative-
inference scenario with an LLM as the workload.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core import Mapping
from repro.models import transformer as T
from repro.runtime.serving import (PartitionedServeEngine, Request,
                                   ServeEngine)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--partition", type=int, default=None,
                    help="also run Edge-PRUNE partitioned inference with "
                         "this many actors on the 'endpoint' unit")
    ap.add_argument("--mode", default="static-bucket",
                    choices=("static-bucket", "continuous"),
                    help="request scheduler: static same-length buckets or "
                         "continuous batching over KV slots")
    ap.add_argument("--slots", type=int, default=8,
                    help="decode batch width in continuous mode")
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke() if args.smoke else get_config(args.arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    reqs = []
    for i in range(args.requests):
        r = Request(i, rng.randint(0, cfg.vocab_size,
                                   args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new)
        if cfg.arch_type == "vlm":
            r.embeds = rng.randn(cfg.frontend_tokens,
                                 cfg.frontend_dim).astype(np.float32)
        elif cfg.arch_type == "audio":
            r.embeds = rng.randn(args.prompt_len,
                                 cfg.frontend_dim).astype(np.float32)
        reqs.append(r)
    eng = ServeEngine(cfg, params,
                      max_len=args.prompt_len + args.max_new + 8,
                      mode=args.mode, max_slots=args.slots)
    outs = eng.generate(reqs)
    tput = sum(len(o.tokens) for o in outs) / sum(o.decode_s for o in outs)
    for o in outs[:4]:
        print(f"req {o.id}: prefill {o.prefill_s*1e3:.1f} ms, "
              f"{len(o.tokens)} tokens, first: {o.tokens[:8]}")
    print(f"# aggregate decode throughput ~{tput:.1f} tok/s")

    if args.partition is not None and cfg.arch_type not in ("vlm", "audio"):
        g = T.to_actor_graph(cfg, params, batch=1, seq=args.prompt_len)
        names = list(g.actors)
        pp = max(1, min(args.partition, len(names)))
        mapping = Mapping("cli", {n: ("endpoint" if i < pp else "server")
                                  for i, n in enumerate(names)})
        pse = PartitionedServeEngine(cfg, params, mapping, batch=1,
                                     seq=args.prompt_len)
        logits = pse.infer(reqs[0].prompt[None])
        print(f"# partitioned inference @pp={pp}: boundary "
              f"{pse.comm_bytes()} B, argmax {int(np.argmax(logits[0,-1]))}")


if __name__ == "__main__":
    main()
