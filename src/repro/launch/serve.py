"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Spins up the policy-based ``Engine`` over a (smoke-sized on CPU) model
and runs a synthetic request workload; ``--partition pp`` additionally
serves through the Edge-PRUNE partitioned actor graph at the given
partition point, reporting the boundary traffic — the paper's
collaborative-inference scenario with an LLM as the workload.

``--policy`` picks the admission policy: ``batch`` is the seed
static-bucket executor (closed batches, no arrivals); ``fifo`` /
``priority`` / ``edf`` stream through the continuous scheduler against
the real clock — each request is admitted at its arrival instant and
its completion is printed the moment it finishes. The legacy ``--mode
static-bucket|continuous`` spelling still works and maps onto
``--policy batch|fifo``.

``--trace <jsonl>`` replays a recorded request trace instead of the
synthetic workload; one JSON object per line::

    {"arrival_s": 0.00, "prompt": [17, 3, 99], "max_new": 8}
    {"arrival_s": 0.02, "prompt_len": 32, "max_new": 16, "priority": 2,
     "deadline_s": 0.5}

``prompt`` gives explicit token ids; ``prompt_len`` asks for that many
random tokens (deterministic under the driver's seed). ``priority`` and
``deadline_s`` feed the priority/EDF admission policies. Arrivals are
seconds from serve start; out-of-order lines are allowed.
"""
from __future__ import annotations

import argparse
import json
from typing import List, Tuple

import jax
import numpy as np

from repro.configs import get_config
from repro.core import Mapping
from repro.models import transformer as T
from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.serving import PartitionedServeEngine, Request


def load_trace(path: str, cfg,
               rng: np.random.RandomState) -> Tuple[List[Request], List[float]]:
    """Parse a JSONL request trace into (requests, arrival offsets).
    Frontend architectures (vlm/audio) get deterministic synthetic
    ``embeds`` per request, like the synthetic workload path — traces
    record arrival/prompt/max-new (+ scheduling fields), not frontend
    tensors."""
    reqs: List[Request] = []
    arrivals: List[float] = []
    with open(path) as fh:
        for i, line in enumerate(fh):
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            if "prompt" in d:
                prompt = np.asarray(d["prompt"], np.int32)
            else:
                prompt = rng.randint(0, cfg.vocab_size,
                                     int(d.get("prompt_len", 32))
                                     ).astype(np.int32)
            r = Request(i, prompt, max_new_tokens=int(d.get("max_new", 16)),
                        eos=d.get("eos"),
                        priority=int(d.get("priority", 0)),
                        deadline_s=d.get("deadline_s"))
            if cfg.arch_type == "vlm":
                r.embeds = rng.randn(cfg.frontend_tokens,
                                     cfg.frontend_dim).astype(np.float32)
            elif cfg.arch_type == "audio":
                r.embeds = rng.randn(len(prompt),
                                     cfg.frontend_dim).astype(np.float32)
            reqs.append(r)
            arrivals.append(float(d.get("arrival_s", 0.0)))
    if not reqs:
        raise ValueError(f"trace {path} contains no requests")
    return reqs, arrivals


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--partition", type=int, default=None,
                    help="also run Edge-PRUNE partitioned inference with "
                         "this many actors on the 'endpoint' unit")
    # shared engine-policy flags (one registration with serving_bench.py,
    # load_bench.py, runtime/server.py — the surface can't drift)
    EngineConfig.add_cli_args(ap)
    ap.add_argument("--mode", default=None,
                    choices=("static-bucket", "continuous"),
                    help="legacy spelling of --policy: static-bucket=batch, "
                         "continuous=fifo")
    ap.add_argument("--serve", action="store_true",
                    help="instead of running the synthetic workload, start "
                         "the HTTP front end (repro.runtime.server) over "
                         "this engine and block")
    ap.add_argument("--port", type=int, default=8800,
                    help="--serve listen port")
    ap.add_argument("--trace", default=None,
                    help="JSONL request trace to replay against the real "
                         "clock (continuous policies; see module docstring)")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload RNG seed (synthetic prompts and "
                         "prompt_len trace lines)")
    args = ap.parse_args()

    policy = args.policy
    if policy is None and args.mode is not None:
        policy = "batch" if args.mode == "static-bucket" else "fifo"
    if policy is None:
        policy = "batch"
    paged = args.paged or args.prefix_cache
    if policy == "batch" and (paged or args.prefill_chunk or args.trace
                              or args.serve or args.enforce_deadlines):
        policy = "fifo"
        print("# --paged/--prefix-cache/--prefill-chunk/--trace/--serve/"
              "--enforce-deadlines imply a continuous admission policy "
              "(fifo)")

    cfg = get_config(args.arch).smoke() if args.smoke else get_config(args.arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(args.seed)
    arrivals = None
    if args.trace is not None:
        reqs, arrivals = load_trace(args.trace, cfg, rng)
        max_len = max(len(r.prompt) + r.max_new_tokens for r in reqs) + 8
    else:
        reqs = []
        # with --prefix-cache the synthetic workload models the shared-
        # preamble traffic the cache exists for: every prompt opens with
        # the same first half
        shared = rng.randint(0, cfg.vocab_size,
                             args.prompt_len // 2).astype(np.int32) \
            if args.prefix_cache else None
        for i in range(args.requests):
            prompt = rng.randint(0, cfg.vocab_size,
                                 args.prompt_len).astype(np.int32)
            if shared is not None:
                prompt[:len(shared)] = shared
            r = Request(i, prompt, max_new_tokens=args.max_new)
            if cfg.arch_type == "vlm":
                r.embeds = rng.randn(cfg.frontend_tokens,
                                     cfg.frontend_dim).astype(np.float32)
            elif cfg.arch_type == "audio":
                r.embeds = rng.randn(args.prompt_len,
                                     cfg.frontend_dim).astype(np.float32)
            reqs.append(r)
        max_len = args.prompt_len + args.max_new + 8
    eng = Engine(cfg, params,
                 EngineConfig.from_args(args, max_len=max_len,
                                        admission=policy))

    if args.serve:
        # HTTP front end over this engine/model; blocks until Ctrl-C.
        import time as _time

        from repro.runtime.server import EngineServer, ServerConfig
        with EngineServer(eng, ServerConfig(port=args.port)) as srv:
            print(f"# serving {cfg.name} on {srv.url} (policy={policy}, "
                  f"layout={eng.config.kv_layout}); POST /generate, "
                  f"GET /health/ready, GET /status", flush=True)
            try:
                while True:
                    _time.sleep(3600)
            except KeyboardInterrupt:
                return

    if policy != "batch":
        # Streaming serve: completions print as they finish, admission
        # follows arrival instants on the real clock.
        def stream(c) -> None:
            print(f"t={c.finish_s:8.3f}s req {c.id}: ttft "
                  f"{c.ttft_s * 1e3:7.1f} ms, latency "
                  f"{c.latency_s * 1e3:7.1f} ms, {len(c.tokens)} tokens, "
                  f"{c.finish_reason}, first: {c.tokens[:8]}")
        outs = eng.generate(reqs, arrivals=arrivals, on_completion=stream)
        span = max(o.finish_s for o in outs) - min(o.arrival_s for o in outs)
        toks = sum(len(o.tokens) for o in outs)
        lat = [o.latency_s for o in outs]
        st = eng.stats()
        print(f"# served {len(outs)} requests / {toks} tokens in "
              f"{span:.3f} s wall ({toks / max(span, 1e-9):.1f} tok/s); "
              f"mean latency {np.mean(lat) * 1e3:.1f} ms, p95 "
              f"{np.percentile(lat, 95) * 1e3:.1f} ms; "
              f"{st['preemptions']} preemptions, "
              f"{st['slot_failures']} slot failures")
        if paged:
            ks = eng.kv_stats()
            print(f"# paged KV: pool {ks['paged_kv_pool_bytes'] / 1e6:.2f} "
                  f"MB, high-water {ks['paged_kv_hwm_bytes'] / 1e6:.2f} MB "
                  f"({ks['paged_kv_hwm_blocks']:.0f} blocks, watermark "
                  f"{args.watermark}) vs slotted reservation "
                  f"{ks['slotted_kv_reserved_bytes'] / 1e6:.2f} MB")
        if args.prefix_cache:
            print(f"# prefix cache: {st['prefix_hits']} admissions matched "
                  f"a resident chain; {st['prefill_tokens_saved']} of "
                  f"{st['prefill_tokens_total']} prompt tokens skipped "
                  f"prefill")
    else:
        outs = eng.generate(reqs)
        tput = sum(len(o.tokens) for o in outs) / sum(o.decode_s for o in outs)
        for o in outs[:4]:
            print(f"req {o.id}: prefill {o.prefill_s*1e3:.1f} ms, "
                  f"{len(o.tokens)} tokens, {o.finish_reason}, "
                  f"first: {o.tokens[:8]}")
        print(f"# aggregate decode throughput ~{tput:.1f} tok/s")

    if args.partition is not None and cfg.arch_type not in ("vlm", "audio"):
        g = T.to_actor_graph(cfg, params, batch=1, seq=args.prompt_len)
        names = list(g.actors)
        pp = max(1, min(args.partition, len(names)))
        mapping = Mapping("cli", {n: ("endpoint" if i < pp else "server")
                                  for i, n in enumerate(names)})
        pse = PartitionedServeEngine(cfg, params, mapping, batch=1,
                                     seq=args.prompt_len)
        logits = pse.infer(reqs[0].prompt[None])
        print(f"# partitioned inference @pp={pp}: boundary "
              f"{pse.comm_bytes()} B, argmax {int(np.argmax(logits[0,-1]))}")


if __name__ == "__main__":
    main()
