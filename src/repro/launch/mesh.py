"""Production mesh construction. A FUNCTION, not a module-level constant —
importing this module never touches jax device state.

Single pod: (data=16, model=16) = 256 chips of TPU v5e.
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the "pod" axis is the
DCN boundary — Edge-PRUNE's endpoint/server split mapped onto TPU: batch
data-parallelism crosses pods, FSDP("data") + TP("model") stay inside the
ICI domain.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = 512 if multi_pod else 256
    devices = jax.devices()
    if len(devices) == ndev:
        return jax.make_mesh(shape, axes)
    if len(devices) < ndev:
        raise RuntimeError(
            f"need {ndev} devices for the production mesh, found "
            f"{len(devices)}; run under XLA_FLAGS="
            f"--xla_force_host_platform_device_count=512 (see dryrun.py)")
    return jax.make_mesh(shape, axes, devices=devices[:ndev])


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many real devices exist (tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[:data * model])


# TPU v5e hardware constants for the roofline terms (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link (~per-chip sustained)
DCN_BW = 25e9                   # bytes/s per pod-boundary (aggregate/chip grp)
CHIP_HBM_BYTES = 16 * 2**30
