"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo
against 512 placeholder host devices, and extract the roofline terms.

MUST be run as its own process (``python -m repro.launch.dryrun ...``):
the device-count flag below has to be set before jax initializes. Smoke
tests and benchmarks deliberately do NOT import this module.
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
from functools import partial   # noqa: E402
from typing import Dict, Optional   # noqa: E402

import jax           # noqa: E402
import numpy as np   # noqa: E402

from repro.configs import ARCH_IDS, get_config       # noqa: E402
from repro.configs.shapes import (SHAPES, decode_context, input_specs,  # noqa: E402
                                  shape_applicable)
from repro.launch import mesh as mesh_lib            # noqa: E402
from repro.models import transformer as T            # noqa: E402
from repro.runtime import optim                      # noqa: E402
from repro.runtime.trainstep import (make_prefill_step, make_serve_step,  # noqa: E402
                                     make_train_step)
from repro.sharding import (batch_shardings, cache_shardings,  # noqa: E402
                            params_shardings, replicated)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")


# ---------------------------------------------------------------------------
# HLO collective-bytes extraction
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result bytes of every collective op, weighting ops inside
    while-loop bodies by their (statically known) trip count.

    HLO layout: computations are blocks ``%name (...) -> ... {`` ... ``}``.
    A while op referencing body=%name with a known trip count shows up as
    a comment or can be bounded by the induction variable compare; jax
    scans lower with known trip counts, and XLA's HLO text annotates the
    loop backend config. We conservatively read the trip count from the
    scan length: callers pass it via the ``trip_counts`` mapping instead —
    see ``_analyze``: the while body name is matched to the loop's
    upper bound parsed from the ``constant`` compared in the condition.
    """
    # split into computations
    comps: Dict[str, list] = {}
    cur = None
    comp_hdr = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
    for line in hlo_text.splitlines():
        m = comp_hdr.match(line)
        if m:
            cur = m.group(1)
            comps[cur] = []
        elif line.strip() == "}":
            cur = None
        elif cur is not None:
            comps[cur].append(line)

    # map while-body computation -> trip count (parse condition computations)
    # condition bodies compare the induction var against a constant:
    #   %constant.N = s32[] constant(TRIP)
    cond_const: Dict[str, int] = {}
    for name, lines in comps.items():
        consts = []
        has_lt = False
        for ln in lines:
            mc = re.search(r"s32\[\]\s+constant\((\d+)\)", ln)
            if mc:
                consts.append(int(mc.group(1)))
            if "direction=LT" in ln or "compare" in ln:
                has_lt = True
        if has_lt and consts:
            cond_const[name] = max(consts)

    # find while ops: body=%B, condition=%C
    body_trip: Dict[str, int] = {}
    for name, lines in comps.items():
        for ln in lines:
            mw = re.search(r"while\(.*?\).*condition=%?([\w\.\-]+),\s*"
                           r"body=%?([\w\.\-]+)", ln)
            if mw:
                c, b = mw.group(1), mw.group(2)
                body_trip[b] = cond_const.get(c, 1)

    # parent map: body computation -> computation containing its while op
    parent: Dict[str, str] = {}
    for name, lines in comps.items():
        for ln in lines:
            mw = re.search(r"body=%?([\w\.\-]+)", ln)
            if mw and "while(" in ln:
                parent[mw.group(1)] = name
            # weight computations called from within a loop body too
            mc = re.search(r"(?:to_apply|calls)=%?([\w\.\-]+)", ln)
            if mc:
                parent.setdefault(mc.group(1), name)

    def comp_weight(name: str) -> int:
        # product of trip counts of ALL enclosing while bodies (nested
        # grad-accumulation loop x layer scan), walking the parent chain.
        w, cur, hops = 1, name, 0
        while cur is not None and hops < 32:
            w *= body_trip.get(cur, 1)
            cur = parent.get(cur)
            hops += 1
        return w

    out = {k: 0.0 for k in _COLL_KINDS}
    out["count"] = 0
    for name, lines in comps.items():
        w = comp_weight(name)
        for ln in lines:
            for kind in _COLL_KINDS:
                if re.search(rf"\)?\s{kind}(-start)?\(", ln):
                    lhs = ln.split(" = ", 1)
                    if len(lhs) == 2:
                        out[kind] += w * _shape_bytes(lhs[1].split(kind)[0])
                        out["count"] += w
                    break
    out["total"] = sum(out[k] for k in _COLL_KINDS)
    return out


# ---------------------------------------------------------------------------
# combo lowering
# ---------------------------------------------------------------------------

def abstract_params(cfg):
    return jax.eval_shape(partial(T.init_params, cfg),
                          jax.random.PRNGKey(0))


def lower_combo(arch: str, shape: str, *, multi_pod: bool = False,
                cfg_override=None, microbatches: int = 1) -> Dict:
    cfg = cfg_override or get_config(arch)
    s = SHAPES[shape]
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    params_abs = abstract_params(cfg)
    p_shard = params_shardings(params_abs, mesh)
    specs = input_specs(cfg, shape)
    b_shard = batch_shardings(specs, mesh)
    t0 = time.time()

    with mesh:
        if s.kind == "train":
            opt_abs = jax.eval_shape(optim.init, params_abs)
            o_shard = {"m": p_shard, "v": p_shard,
                       "step": replicated(opt_abs["step"], mesh)}
            step = make_train_step(cfg, optim.AdamWConfig(), mesh,
                                   microbatches=microbatches)
            jitted = jax.jit(step,
                             in_shardings=(p_shard, o_shard, b_shard),
                             out_shardings=(p_shard, o_shard, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, specs)
        elif s.kind == "prefill":
            step = make_prefill_step(cfg, max_len=s.seq_len, mesh=mesh)
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(params_abs, specs)
        else:  # decode
            ctx = decode_context(cfg, shape)
            cache_abs = jax.eval_shape(
                partial(T.init_cache, cfg, ctx["batch"], ctx["max_len"],
                        src_len=ctx["src_len"]))
            c_shard = cache_shardings(cache_abs, mesh)
            step = make_serve_step(cfg, mesh=mesh)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, c_shard, b_shard["token"],
                              b_shard["cache_len"]),
                out_shardings=(None, c_shard, b_shard["cache_len"]),
                donate_argnums=(1,))
            lowered = jitted.lower(params_abs, cache_abs, specs["token"],
                                   specs["cache_len"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    if microbatches > 1:
        # XLA's HloCostAnalysis multiplies ONE level of while-loop bodies
        # by the trip count but not nested loops: under gradient
        # accumulation the outer microbatch loop is unaccounted. Nearly
        # all flops/bytes live inside it, so scale by the trip count
        # (verified: mb=4 reports exactly 1/4 of the mb=1 flops).
        flops *= microbatches
        bytes_acc *= microbatches
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "alias_bytes": getattr(ma, "alias_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        mem = {"error": str(e)}

    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    # analytic per-device weight bytes (what the mesh actually stores)
    def leaf_device_bytes(leaf, sh):
        n = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        return n // int(np.prod([_axsize(mesh, a) for a in sh.spec]))

    def _axsize(mesh, a):
        if a is None:
            return 1
        if isinstance(a, tuple):
            return int(np.prod([mesh.shape[x] for x in a]))
        return mesh.shape[a]

    pleaves = jax.tree.leaves(params_abs)
    sleaves = jax.tree.leaves(p_shard)
    param_dev_bytes = sum(leaf_device_bytes(l, s)
                          for l, s in zip(pleaves, sleaves))

    n = cfg.param_count()
    n_active = cfg.active_param_count()
    tokens = s.global_batch * (s.seq_len if s.kind == "train" else
                               (s.seq_len if s.kind == "prefill" else 1))
    mult = 6 if s.kind == "train" else 2
    model_flops = mult * n_active * tokens

    # NOTE: compiled.cost_analysis() and the HLO text describe the SPMD
    # *per-device* program (verified empirically: sharding a matmul over N
    # devices divides reported flops by N). The roofline terms below are
    # therefore "per-chip quantity / per-chip rate", which equals the
    # spec's global/(chips*rate) formulation.
    res = {
        "arch": arch, "shape": shape, "microbatches": microbatches,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "hlo_flops_per_device": flops,
        "hlo_flops_global": flops * n_chips,
        "hlo_bytes_per_device": bytes_acc,
        "collective_bytes_per_device": coll["total"], "collectives": coll,
        "memory": mem, "param_bytes_per_device": param_dev_bytes,
        "param_count": n, "active_param_count": n_active,
        "model_flops": model_flops,
        "useful_flops_ratio": model_flops / (flops * n_chips) if flops else None,
        "t_compute_s": flops / mesh_lib.PEAK_FLOPS_BF16,
        "t_memory_s": bytes_acc / mesh_lib.HBM_BW,
        "t_collective_s": coll["total"] / mesh_lib.ICI_BW,
        "hlo_kb": len(hlo) // 1024,
    }
    terms = {k: res[k] for k in ("t_compute_s", "t_memory_s",
                                 "t_collective_s")}
    res["bottleneck"] = max(terms, key=terms.get)
    return res


def run_one(arch: str, shape: str, multi_pod: bool, outdir: str,
            force: bool = False, microbatches: int = 1) -> Optional[Dict]:
    if not shape_applicable(get_config(arch), shape):
        return None
    mesh_tag = "multipod" if multi_pod else "pod"
    path = os.path.join(outdir, f"{arch}__{shape}__{mesh_tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as fh:
            return json.load(fh)
    res = lower_combo(arch, shape, multi_pod=multi_pod,
                      microbatches=microbatches)
    os.makedirs(outdir, exist_ok=True)
    with open(path, "w") as fh:
        json.dump(res, fh, indent=1)
    return res


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES),
                    help="input shape (default: all)")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--outdir", default=os.path.normpath(RESULTS_DIR))
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
                if not shape_applicable(get_config(arch), shape):
                    print(f"SKIP {tag} (long-context not applicable)")
                    continue
                try:
                    t0 = time.time()
                    r = run_one(arch, shape, mp, args.outdir, args.force,
                                args.microbatches)
                    print(f"OK   {tag}: flops/dev={r['hlo_flops_per_device']:.3e} "
                          f"coll/dev={r['collective_bytes_per_device']:.3e} "
                          f"temp={r['memory'].get('temp_bytes')} "
                          f"bottleneck={r['bottleneck']} "
                          f"[{time.time()-t0:.1f}s]")
                except Exception as e:
                    failures.append((tag, repr(e)))
                    print(f"FAIL {tag}: {e!r}")
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")


if __name__ == "__main__":
    main()
