"""Pallas TPU RG-LRU linear-recurrence kernel.

Computes y_t = a_t * y_{t-1} + b_t over the sequence dim. TPU adaptation
of RecurrentGemma's GPU linear-scan kernel: the grid is
(batch, feature-blocks, seq-blocks) with the seq dimension innermost;
the hidden state h (one (bd,) vector per feature block) is carried in
VMEM scratch across seq blocks, and each block runs a fori_loop over its
rows — elementwise VPU work on 128-lane vectors, no MXU. The block shape
trade-off: larger bs amortizes grid overhead, larger bd raises VPU
utilization; (bs, bd) must fit VMEM alongside a, b and y tiles.

Unlike the associative-scan lowering (log-depth, 2x flops), the kernel
does the work-optimal sequential scan per block while still exposing
batch x feature parallelism across TPU cores.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h0_ref, y_ref, h_ref, *, bs: int):
    isq = pl.program_id(2)

    @pl.when(isq == 0)
    def _init():
        h_ref[...] = h0_ref[0]

    def step(t, h):
        h = a_ref[0, t] * h + b_ref[0, t]
        y_ref[0, t] = h
        return h

    h_ref[...] = jax.lax.fori_loop(0, bs, step, h_ref[...])


def rglru_scan_pallas(a: jax.Array, b: jax.Array, h0: jax.Array, *,
                      bs: int = 256, bd: int = 512,
                      interpret: bool = False) -> jax.Array:
    """a, b: (B, S, D) f32 decay/input; h0: (B, D). Returns y (B, S, D)."""
    B, S, D = a.shape
    bs = min(bs, S)
    while S % bs:
        bs //= 2
    bd = min(bd, D)
    while D % bd:
        bd //= 2
    ns, nd = S // bs, D // bd

    grid = (B, nd, ns)   # seq innermost: sequential state carry
    kernel = functools.partial(_rglru_kernel, bs=bs)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, bd), lambda ib, id_, is_: (ib, is_, id_)),
            pl.BlockSpec((1, bs, bd), lambda ib, id_, is_: (ib, is_, id_)),
            pl.BlockSpec((1, bd), lambda ib, id_, is_: (ib, id_)),
        ],
        out_specs=pl.BlockSpec((1, bs, bd),
                               lambda ib, id_, is_: (ib, is_, id_)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), a.dtype),
        scratch_shapes=[pltpu.VMEM((bd,), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
