"""Jitted public wrapper for the RG-LRU scan kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.rglru_scan.kernel import rglru_scan_pallas


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@partial(jax.jit, static_argnames=("bs", "bd"))
def rglru_scan(a, b, h0, *, bs: int = 256, bd: int = 512):
    return rglru_scan_pallas(a, b, h0, bs=bs, bd=bd, interpret=_on_cpu())
