"""Pure-jnp oracle for the RG-LRU scan: plain lax.scan over time."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_seq_ref(a, b, h0):
    """Sequential reference. a, b: (B, S, D); h0: (B, D)."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h
    _, ys = jax.lax.scan(step, h0, (a.transpose(1, 0, 2), b.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2)
