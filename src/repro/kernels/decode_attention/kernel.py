"""Pallas TPU decode-attention kernel (flash-decode style).

One new token per sequence attends over a (B, S, Hk, D) KV cache with a
per-sequence valid length. Grid: (B x Hk, kv-blocks); the kv dimension is
innermost/sequential, carrying the online-softmax state for the g query
heads of the kv head in VMEM scratch. The per-sequence ``lengths`` array
is a scalar-prefetch operand — Pallas TPU loads it into SMEM before the
kernel body runs, so block masking is branch-free.

This is the memory-bound kernel of serving: per step it streams the
whole cache once (arithmetic intensity ~= g), so the roofline term is
bytes(cache)/HBM_bw — the Pallas win over naive XLA decode is avoiding
the (B, H, S) logits round-trip to HBM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(lengths_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, bk: int, nk: int, scale: float,
                   hk: int):
    bh = pl.program_id(0)
    ik = pl.program_id(1)
    b = bh // hk

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths_ref[b]
    live = ik * bk < length

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # (g, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)         # (bk, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def _paged_decode_kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, bs: int, nb: int,
                         scale: float, hk: int):
    """Block-table decode attention. Identical online-softmax body to
    ``_decode_kernel``; the difference is entirely in the BlockSpec index
    maps, which chase ``tables_ref`` (scalar-prefetched to SMEM) so each
    kv step DMAs one *physical* pool block instead of the next contiguous
    cache slice — dead blocks are never streamed."""
    bh = pl.program_id(0)
    ip = pl.program_id(1)
    b = bh // hk

    @pl.when(ip == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths_ref[b]
    live = ip * bs < length

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # (g, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)         # (bs, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = ip * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ip == nb - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def paged_decode_attention_pallas(q: jax.Array, k_pool: jax.Array,
                                  v_pool: jax.Array, block_tables: jax.Array,
                                  lengths: jax.Array, *, scale=None,
                                  interpret: bool = False) -> jax.Array:
    """q: (B, H, D); pools: (N, bs, Hk, D); block_tables: (B, nb) int32
    physical block per logical page; lengths: (B,) valid rows. Returns
    (B, H, D).

    The kv grid dimension walks logical pages 0..nb-1; the k/v BlockSpec
    index maps read the prefetched table to pick the physical block, so
    the DMA stream follows the page chain. Pages at or past a sequence's
    length are skipped via ``pl.when`` (their table entries point at the
    null block and are never read). On TPU the pool's block_size should
    be a multiple of the sublane tile (8 for fp32, 16 for bf16)."""
    b, h, d = q.shape
    n, bs, hk, _ = k_pool.shape
    nb = block_tables.shape[1]
    g = h // hk
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    qg = q.reshape(b, hk, g, d)
    grid = (b * hk, nb)
    kernel = functools.partial(_paged_decode_kernel, bs=bs, nb=nb,
                               scale=scale, hk=hk)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, d),
                             lambda bh, ip, tbl, lens:
                             (bh // hk, bh % hk, 0, 0)),
                pl.BlockSpec((1, bs, 1, d),
                             lambda bh, ip, tbl, lens:
                             (tbl[bh // hk, ip], 0, bh % hk, 0)),
                pl.BlockSpec((1, bs, 1, d),
                             lambda bh, ip, tbl, lens:
                             (tbl[bh // hk, ip], 0, bh % hk, 0)),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, g, d),
                lambda bh, ip, tbl, lens: (bh // hk, bh % hk, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g,), jnp.float32),
                pltpu.VMEM((g,), jnp.float32),
                pltpu.VMEM((g, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hk, g, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      qg, k_pool, v_pool)
    return out.reshape(b, h, d)


def decode_attention_pallas(q: jax.Array, k_cache: jax.Array,
                            v_cache: jax.Array, lengths: jax.Array, *,
                            bk: int = 512, scale=None,
                            interpret: bool = False) -> jax.Array:
    """q: (B, H, D); caches: (B, S, Hk, D); lengths: (B,) valid entries.
    Returns (B, H, D)."""
    b, h, d = q.shape
    _, s, hk, _ = k_cache.shape
    g = h // hk
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    bk = min(bk, s)
    while s % bk:
        bk //= 2
    nk = s // bk

    qg = q.reshape(b, hk, g, d)
    grid = (b * hk, nk)
    kernel = functools.partial(_decode_kernel, bk=bk, nk=nk, scale=scale,
                               hk=hk)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, d),
                             lambda bh, ik, lens: (bh // hk, bh % hk, 0, 0)),
                pl.BlockSpec((1, bk, 1, d),
                             lambda bh, ik, lens: (bh // hk, ik, bh % hk, 0)),
                pl.BlockSpec((1, bk, 1, d),
                             lambda bh, ik, lens: (bh // hk, ik, bh % hk, 0)),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, g, d), lambda bh, ik, lens: (bh // hk, bh % hk, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g,), jnp.float32),
                pltpu.VMEM((g,), jnp.float32),
                pltpu.VMEM((g, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hk, g, d), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k_cache, v_cache)
    return out.reshape(b, h, d)
