"""Jitted public wrapper for the decode-attention kernel (interpret mode
on CPU, compiled Pallas on TPU)."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.decode_attention.kernel import (
    decode_attention_pallas, paged_decode_attention_pallas)


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@partial(jax.jit, static_argnames=("bk",))
def decode_attention(q, k_cache, v_cache, lengths, *, bk: int = 512):
    return decode_attention_pallas(q, k_cache, v_cache, lengths, bk=bk,
                                   interpret=_on_cpu())


@jax.jit
def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths):
    return paged_decode_attention_pallas(q, k_pool, v_pool, block_tables,
                                         lengths, interpret=_on_cpu())
