"""Pure-jnp oracle for decode attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def paged_decode_attention_ref(q, k_pool, v_pool, block_tables, lengths, *,
                               scale=None):
    """Oracle for block-table decode attention: gather each sequence's
    pages into a contiguous view, then run the dense oracle.

    q: (B, H, D); pools: (N, bs, Hk, D); block_tables: (B, nb) physical
    block per logical page; lengths: (B,) valid rows (rows past a
    sequence's length — including whole null/stale pages — are masked)."""
    b, nb = block_tables.shape
    _, bs, hk, d = k_pool.shape
    k = k_pool[block_tables].reshape(b, nb * bs, hk, d)
    v = v_pool[block_tables].reshape(b, nb * bs, hk, d)
    return decode_attention_ref(q, k, v, lengths, scale=scale)


def decode_attention_ref(q, k_cache, v_cache, lengths, *, scale=None):
    """q: (B, H, D); caches: (B, S, Hk, D); lengths: (B,)."""
    b, h, d = q.shape
    _, s, hk, _ = k_cache.shape
    g = h // hk
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, hk, g, d).astype(jnp.float32)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg,
                        k_cache.astype(jnp.float32)) * scale
    mask = jnp.arange(s)[None] < lengths[:, None]
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, h, d).astype(q.dtype)
