"""Jitted public wrapper for the flash-attention kernel.

On TPU this dispatches to the compiled Pallas kernel; on CPU (this
container) it runs the kernel body in interpret mode, which executes the
exact same tiling logic in Python for correctness validation.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@partial(jax.jit, static_argnames=("causal", "window", "bq", "bk"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 256, bk: int = 256):
    b, sq, h, d = q.shape
    sk = k.shape[1]
    bq = min(bq, sq)
    bk = min(bk, sk)
    while sq % bq:
        bq //= 2
    while sk % bk:
        bk //= 2
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  bq=max(bq, 1), bk=max(bk, 1),
                                  interpret=_on_cpu())
