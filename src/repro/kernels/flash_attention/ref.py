"""Pure-jnp oracle for flash attention: naive full-matrix softmax.
O(S^2) memory — tests only."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  scale=None) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Sk, Hk, D) with Hk | H (GQA)."""
    b, sq, h, d = q.shape
    _, sk, hk, _ = k.shape
    g = h // hk
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, sq, hk, g, d).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32)) * scale
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qp >= kp
    if window > 0:
        mask &= qp - kp < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, d).astype(q.dtype)
