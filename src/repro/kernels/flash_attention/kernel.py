"""Pallas TPU flash-attention (prefill) kernel.

TPU adaptation of the FlashAttention tiling: the grid is
(batch x kv-head, q-blocks, kv-blocks) with the kv-block dimension
innermost — TPU grids execute sequentially per core, so the running
online-softmax state (m, l, acc) lives in VMEM scratch and is carried
across kv iterations without HBM round-trips. Block shapes are chosen so
one (g*bq, d) q-tile, one (bk, d) kv-tile and the (g*bq, bk) score tile
fit VMEM together, with the matmul dims aligned to the 128-lane MXU.

GQA layout: q is passed as (B, Hk, g, Sq, D) — all g query heads of one
kv head share a grid step, so k/v tiles are loaded once per group (g x
bandwidth saving vs. per-q-head grids, the reason GQA exists).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, causal: bool, window: int, bq: int, bk: int,
               nk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # block-level causal/window skip: only compute when the tile overlaps
    # the mask support
    q_lo = iq * bq
    q_hi = q_lo + bq - 1
    k_lo = ik * bk
    k_hi = k_lo + bk - 1
    live = True
    if causal:
        live = k_lo <= q_hi
    if window > 0:
        live = jnp.logical_and(live, q_lo - k_hi < window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].reshape(-1, q_ref.shape[-1])     # (g*bq, d)
        k = k_ref[0, :, 0, :]                            # (bk, d)
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q.astype(jnp.float32), k.astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (g*bq, bk)
        qpos = q_lo + jax.lax.broadcasted_iota(
            jnp.int32, (q.shape[0], bk), 0) % bq
        # rows are g-major: row = g_idx * bq + q_idx -> q position uses % bq
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (q.shape[0], bk), 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window > 0:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = out.reshape(o_ref.shape[2], o_ref.shape[3],
                                  o_ref.shape[4]).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           bq: int = 256, bk: int = 256,
                           scale=None, interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Sk, Hk, D). Returns (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    _, sk, hk, _ = k.shape
    assert h % hk == 0
    g = h // hk
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    nq, nk = sq // bq, sk // bk

    # (B, Hk, g, Sq, D) so one grid step covers all g q-heads of a kv head
    qg = q.reshape(b, sq, hk, g, d).transpose(0, 2, 3, 1, 4)

    grid = (b * hk, nq, nk)
    kernel = functools.partial(_fa_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, bq, d),
                         lambda bh, iq, ik: (bh // hk, bh % hk, 0, iq, 0)),
            pl.BlockSpec((1, bk, 1, d),
                         lambda bh, iq, ik: (bh // hk, ik, bh % hk, 0)),
            pl.BlockSpec((1, bk, 1, d),
                         lambda bh, iq, ik: (bh // hk, ik, bh % hk, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, g, bq, d),
            lambda bh, iq, ik: (bh // hk, bh % hk, 0, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hk, g, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g * bq,), jnp.float32),
            pltpu.VMEM((g * bq,), jnp.float32),
            pltpu.VMEM((g * bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d)
