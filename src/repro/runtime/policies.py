"""Serving policies: admission order, preemption victims, sampling.

The ``Engine``/``ContinuousScheduler`` split is policy vs mechanism: the
scheduler owns the decode loop, KV layout surgery and failure handling
(mechanism), while *which* request is admitted next, *who* gets
preempted when the paged pool runs dry, and *how* logits become tokens
are pluggable objects defined here — each testable in isolation with
plain Python (no JAX, no model) by feeding it ticket-shaped records.

Admission policies are priority orders, not queues: the scheduler keeps
the waiting set and repeatedly admits ``min(waiting, key=policy.key)``.
A smaller key means sooner. Every key ends with the submission sequence
number, so ties break FIFO and the order is total (deterministic).
Because greedy decoding is per-request deterministic regardless of what
else shares the batch, *any* admission order emits tokens identical to
the static-bucket path — policies change waiting time, never content.

``BatchAdmission`` is the odd one out: it declares the static-bucket
execution mode (the seed path — group requests by prompt length, compile
per bucket, decode each bucket to completion). The ``Engine`` facade
routes to the bucket executor when it sees this policy, so the legacy
``mode="static-bucket"`` becomes just another admission policy instead
of a parallel API.
"""
from __future__ import annotations

import math
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


def sample_tokens(key: jax.Array, logits: jax.Array, *, greedy: bool,
                  temperature: float) -> Tuple[jax.Array, jax.Array]:
    """Shared sampling rule for every engine path — the continuous ==
    static token-identity contract depends on there being exactly one.
    Returns (tokens (B,) int32, next key)."""
    if greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), key
    key, sub = jax.random.split(key)
    return jax.random.categorical(
        sub, logits / temperature, axis=-1).astype(jnp.int32), key


class Sampler:
    """Owns the PRNG state for one engine. Greedy sampling never touches
    the key, so every greedy configuration is trivially reproducible;
    stochastic sampling splits the key per call, so the emitted stream
    depends on the order of sample calls (which is why the token-identity
    tests all run greedy)."""

    def __init__(self, *, greedy: bool = True, temperature: float = 1.0,
                 seed: int = 0):
        self.greedy = greedy
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)

    def __call__(self, logits: jax.Array) -> jax.Array:
        toks, self.key = sample_tokens(self.key, logits, greedy=self.greedy,
                                       temperature=self.temperature)
        return toks


# ---------------------------------------------------------------------------
# admission policies
# ---------------------------------------------------------------------------
# A ticket (scheduler._Ticket, or any duck-typed record in unit tests)
# exposes: .req (with .priority / .deadline_s), .arrival_s, .submit_seq.


def request_due_s(ticket) -> float:
    """Absolute due instant of a ticket on the engine clock (seconds
    from drain start): ``arrival_s + Request.deadline_s``, or +inf for
    background work without a deadline. One definition shared by EDF
    admission *ordering* and the scheduler's wall-clock deadline
    *enforcement* (``SchedulerConfig(enforce_deadlines=True)`` sheds a
    request whose due instant passes — before prefill, or mid-decode —
    completing it with ``finish_reason="timeout"``)."""
    d = ticket.req.deadline_s
    return ticket.arrival_s + d if d is not None else math.inf


class FifoAdmission:
    """Arrival order; ties (equal arrival instants, e.g. a closed-loop
    batch submitted at t=0) break by submission order. Failure/preemption
    victims re-sort to the head automatically: they were admitted once,
    so their (arrival_s, seq) precedes everything still waiting."""

    name = "fifo"

    def key(self, ticket) -> tuple:
        return (ticket.arrival_s, ticket.submit_seq)


class PriorityAdmission:
    """Highest ``Request.priority`` first; FIFO within a priority level.
    A late high-priority arrival jumps the queue at the next admission
    boundary — it never displaces an already-running request (that is
    the preemption policy's business, and only under pool pressure)."""

    name = "priority"

    def key(self, ticket) -> tuple:
        return (-ticket.req.priority, ticket.arrival_s, ticket.submit_seq)


class DeadlineAdmission:
    """Earliest deadline first. ``Request.deadline_s`` is seconds from
    the request's arrival; requests without a deadline sort last (they
    are background work). FIFO among equal deadlines."""

    name = "edf"

    def key(self, ticket) -> tuple:
        return (request_due_s(ticket), ticket.arrival_s, ticket.submit_seq)


class BatchAdmission:
    """The static-bucket mode as a policy: all requests are admitted as
    closed batches bucketed by prompt length (one compiled
    ``(batch, prompt_len)`` prefill/decode pair per bucket), each bucket
    decoded to completion before the next starts — exactly the seed
    ``ServeEngine`` path. The Engine routes to the bucket executor when
    configured with this policy; there is no admission queue, so
    ``arrivals=`` is rejected."""

    name = "batch"

    def buckets(self, items: Sequence[Any],
                prompt_of=lambda r: r.prompt) -> List[Tuple[int, List[Any]]]:
        """Group ``items`` (requests, or any carrier — ``prompt_of``
        extracts the prompt) by prompt length, shortest bucket first."""
        by_len: dict = {}
        for it in items:
            by_len.setdefault(len(prompt_of(it)), []).append(it)
        return sorted(by_len.items())


# ---------------------------------------------------------------------------
# preemption policies
# ---------------------------------------------------------------------------
# Candidates are the tickets currently holding KV blocks (active slots
# plus an in-flight chunked prefill), minus the slot whose growth needs
# the blocks. .pick returns the victim ticket.
#
# Under prefix sharing (``prefix_cache``), evicting a victim *releases
# its references* rather than freeing blocks outright: a block the
# victim shares with another live request stays resident (refcount > 0)
# and only the victim's private blocks return to the pool. A preemption
# may therefore reclaim fewer blocks than the victim's context length
# suggests; the scheduler keeps preempting until growth succeeds, which
# terminates because the last survivor's worst case is validated to fit
# the whole pool at submit time.


class EvictLatest:
    """Admission order wins: preempt the latest-admitted request, so the
    oldest work always makes progress (no livelock — the survivor set
    shrinks toward the single oldest request, whose worst case is
    validated to fit the pool at submit time)."""

    name = "evict-latest"

    def pick(self, candidates: List[Any]):
        return max(candidates, key=lambda t: t.admit_seq)


class LowestPriority:
    """Preempt the lowest-priority holder; among equals, the latest
    admitted. High-priority work keeps its KV blocks under pool pressure
    at the cost of restarting background requests."""

    name = "lowest-priority"

    def pick(self, candidates: List[Any]):
        return min(candidates, key=lambda t: (t.req.priority, -t.admit_seq))


# ---------------------------------------------------------------------------
# unit-placement policies
# ---------------------------------------------------------------------------
# Which prefill unit takes the next prompt burst, when the execution
# core runs dedicated prefill units (SchedulerConfig.prefill_units > 0).
# Candidates are executor-shaped records exposing .name and .busy_s
# (modeled busy seconds so far); .pick returns the chosen executor.
# Like admission, placement moves *time*, never content: tokens are
# bit-identical under any placement.


class RoundRobinPlacement:
    """Cycle through the prefill units in order — deterministic and
    oblivious to load, the baseline placement."""

    name = "round-robin"

    def __init__(self) -> None:
        self._next = 0

    def pick(self, executors: List[Any]):
        ex = executors[self._next % len(executors)]
        self._next += 1
        return ex


class LeastLoadedPlacement:
    """Send the burst to the prefill unit with the least modeled busy
    time so far; ties break by unit order. Balances heterogeneous prompt
    lengths better than round-robin."""

    name = "least-loaded"

    def pick(self, executors: List[Any]):
        return min(executors, key=lambda ex: ex.busy_s)


# ---------------------------------------------------------------------------
# escalation policies
# ---------------------------------------------------------------------------
# Whether a request submitted to a TieredEngine (runtime.escalation) is
# answered by the small local engine or escalated to the server tier.
# Policies receive an EscalationContext-shaped record exposing:
#   .req            the Request (priority / deadline_s / max_new_tokens)
#   .snapshot       lazy local-load view: queue_depth, active_slots and
#                   best-effort kv occupancy, read lock-free (submit()
#                   must not convoy behind the engine's drain lock)
#   .now_s          seconds on the tiered engine's clock
#   .confidence()   lazy local-model confidence in [0, 1] — the max
#                   softmax probability of the local model's next-token
#                   prediction (the LLM analogue of the shallow-head
#                   gate in examples/early_exit_offload.py). Computed at
#                   most once per request, and only if some policy asks.
# ``decide(ctx)`` returns a short reason string to escalate, or None to
# answer locally. The TieredEngine ORs its policy list: the first
# non-None reason wins and is recorded on the handle / in the trace.


class NeverEscalate:
    """Local-only: the endpoint answers everything itself (the paper's
    endpoint-alone baseline; also the privacy-maximal configuration —
    no request ever leaves the device)."""

    name = "never"

    def decide(self, ctx) -> Optional[str]:
        return None


class AlwaysEscalate:
    """Server-only: every request escalates (the always-offload baseline
    the paper's collaborative numbers are compared against)."""

    name = "always"

    def decide(self, ctx) -> Optional[str]:
        return "always"


class ConfidenceEscalation:
    """Escalate the hard residue: requests the local model is *unsure*
    about (next-token max softmax probability below ``threshold``) go to
    the server; confident requests exit early on-device — VR-PRUNE's
    CA gate (examples/early_exit_offload.py) applied to served traffic,
    and PAPERS.md's 2-step-pruning escalation criterion."""

    name = "confidence"

    def __init__(self, threshold: float = 0.35):
        self.threshold = threshold

    def decide(self, ctx) -> Optional[str]:
        if ctx.confidence() < self.threshold:
            return "low_confidence"
        return None


class DeadlineRiskEscalation:
    """Escalate when the local tier probably cannot meet the request's
    deadline: estimated local completion time (queue ahead + own decode,
    at ``sec_per_token`` a token) times ``safety`` exceeds the deadline.
    Deadline-free requests never trip this policy."""

    name = "deadline-risk"

    def __init__(self, sec_per_token: float = 5e-3, safety: float = 1.5):
        self.sec_per_token = sec_per_token
        self.safety = safety

    def estimate_local_s(self, ctx) -> float:
        """Queue-depth-scaled service estimate: every queued request is
        assumed as long as this one (the tiers share the workload mix)."""
        waiting = ctx.snapshot.get("queue_depth", 0) + 1
        return waiting * ctx.req.max_new_tokens * self.sec_per_token

    def decide(self, ctx) -> Optional[str]:
        if ctx.req.deadline_s is None:
            return None
        if self.estimate_local_s(ctx) * self.safety > ctx.req.deadline_s:
            return "deadline_risk"
        return None


class LocalOverloadEscalation:
    """Escalate on local pressure: the endpoint's admission queue is
    deeper than ``max_queue_depth``, or (paged KV) the pool high-water
    mark has climbed past ``kv_frac`` of capacity — the request would
    only deepen a backlog the small tier cannot drain."""

    name = "overload"

    def __init__(self, max_queue_depth: int = 2, kv_frac: float = 1.0):
        self.max_queue_depth = max_queue_depth
        self.kv_frac = kv_frac

    def decide(self, ctx) -> Optional[str]:
        if ctx.snapshot.get("queue_depth", 0) > self.max_queue_depth:
            return "local_overload"
        kv = ctx.snapshot.get("kv", {})
        pool = kv.get("paged_kv_pool_bytes", 0.0)
        if pool and kv.get("paged_kv_hwm_bytes", 0.0) >= self.kv_frac * pool:
            return "local_overload"
        return None


# ---------------------------------------------------------------------------
# victim-cache eviction: which reclaimable prefix block goes first
# ---------------------------------------------------------------------------
#
# The victim pool (scheduler/prefix_pool.VictimCache) sorts its blocks
# by ``policy.key(view)`` ascending and evicts from the front when an
# allocation comes up short. ``view`` is an EvictionView: per-block
# re-match count (``hits``, persistent across revive/re-admit cycles),
# monotonic admission stamp (``stamp``, one per released chain), page
# depth within the chain (``page``), and owning ``tenant``. Keys are
# pure value tuples so eviction order is deterministic.


class LruEviction:
    """Plain LRU: least recently admitted chain first; within a chain,
    deepest page first — so the chain *head* (the part a shorter match
    can still use) survives longest."""
    name = "lru"

    def key(self, view) -> Any:
        return (view.stamp, -view.page)


class WeightedLruEviction:
    """Recency weighted by proven reuse: a never-re-matched chain
    evicts before a once-matched one regardless of age (hits is the
    primary key), then LRU stamp, then deepest page first. The default:
    a tenant's hot system prompt outlives a burst of one-off prompts
    admitted after it."""
    name = "weighted-lru"

    def key(self, view) -> Any:
        return (view.hits, view.stamp, -view.page)


# ---------------------------------------------------------------------------
# factories (EngineConfig carries policy names or instances)
# ---------------------------------------------------------------------------

ADMISSION_POLICIES = {
    "fifo": FifoAdmission,
    "priority": PriorityAdmission,
    "edf": DeadlineAdmission,
    "deadline": DeadlineAdmission,
    "batch": BatchAdmission,
    "static-bucket": BatchAdmission,    # legacy mode name
}

PREEMPTION_POLICIES = {
    "evict-latest": EvictLatest,
    "lowest-priority": LowestPriority,
}

PLACEMENT_POLICIES = {
    "round-robin": RoundRobinPlacement,
    "least-loaded": LeastLoadedPlacement,
}

VICTIM_EVICTION_POLICIES = {
    "lru": LruEviction,
    "weighted-lru": WeightedLruEviction,
}

ESCALATION_POLICIES = {
    "never": NeverEscalate,
    "always": AlwaysEscalate,
    "confidence": ConfidenceEscalation,
    "deadline-risk": DeadlineRiskEscalation,
    "overload": LocalOverloadEscalation,
}


def make_admission(spec) -> Any:
    """Resolve an admission policy name or pass an instance through."""
    if isinstance(spec, str):
        try:
            return ADMISSION_POLICIES[spec]()
        except KeyError:
            raise ValueError(
                f"admission policy {spec!r} not in "
                f"{sorted(set(ADMISSION_POLICIES))}") from None
    return spec


def make_preemption(spec) -> Any:
    """Resolve a preemption policy name or pass an instance through."""
    if isinstance(spec, str):
        try:
            return PREEMPTION_POLICIES[spec]()
        except KeyError:
            raise ValueError(
                f"preemption policy {spec!r} not in "
                f"{sorted(PREEMPTION_POLICIES)}") from None
    return spec


def make_placement(spec) -> Any:
    """Resolve a unit-placement policy name or pass an instance through."""
    if isinstance(spec, str):
        try:
            return PLACEMENT_POLICIES[spec]()
        except KeyError:
            raise ValueError(
                f"placement policy {spec!r} not in "
                f"{sorted(PLACEMENT_POLICIES)}") from None
    return spec


def make_victim_eviction(spec) -> Any:
    """Resolve a victim-eviction policy name or pass an instance
    through."""
    if isinstance(spec, str):
        try:
            return VICTIM_EVICTION_POLICIES[spec]()
        except KeyError:
            raise ValueError(
                f"victim-eviction policy {spec!r} not in "
                f"{sorted(VICTIM_EVICTION_POLICIES)}") from None
    return spec


def make_escalation(spec) -> List[Any]:
    """Resolve an escalation policy specification into a policy *list*
    (the TieredEngine ORs them): a name, an instance, or a sequence of
    either."""
    if isinstance(spec, str) or not isinstance(spec, (list, tuple)):
        spec = [spec]
    out = []
    for s in spec:
        if isinstance(s, str):
            try:
                out.append(ESCALATION_POLICIES[s]())
            except KeyError:
                raise ValueError(
                    f"escalation policy {s!r} not in "
                    f"{sorted(ESCALATION_POLICIES)}") from None
        else:
            out.append(s)
    return out
