"""Durable escalation queue: on-disk journal, tier transports, and the
in-order replay protocol.

The load-bearing half of ``runtime.escalation`` (see that module for
the full hierarchical-serving story). Split out so the queue protocol
— journal durability, at-least-once in-order replay, ack-side de-dup,
fail-back accounting — is readable and testable on its own, with no
tiered-engine machinery in scope. ``runtime.escalation`` re-exports
every public name here; import from either.

* ``EscalationJournal`` — a bounded on-disk FIFO. Every escalated
  request is appended as a ``runtime.checkpoint``-serialized record
  (``.npz`` arrays + ``.meta.json`` sidecar) before anything is sent,
  so a crash or link cut loses nothing: a fresh journal over the same
  directory reconstructs the pending set purely from a directory scan.
* ``JournalReplayer`` — sends pending entries strictly in sequence
  order through a transport, acking (= deleting) each entry only after
  its completion has been surfaced. A ``LinkDown`` stops replay at the
  head of the line; delivery is therefore at-least-once and in-order,
  and the ``delivered`` seq set de-duplicates on ack so a resend after
  a lost acknowledgement surfaces exactly one completion.
* transports — ``InProcessTransport`` wraps a second ``Engine`` in the
  same process; ``HttpTransport`` posts to the HTTP front end's
  ``/escalate`` ingress route; ``FlakyTransport`` wraps either and
  injects link up/down from a ``resilience.FailureTrace``, raising
  ``LinkDown`` when the link is dead at send *or* at acknowledgement
  time (the server may have computed; the reply was lost — replay +
  de-dup make that safe).
"""
from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.runtime import checkpoint
from repro.runtime.engine import Engine
from repro.runtime.resilience import FailureTrace
from repro.runtime.scheduler import Completion, Request

__all__ = [
    "LinkDown", "TransportError", "JournalFull",
    "EscalationJournal", "JournalEntry", "JournalReplayer",
    "InProcessTransport", "HttpTransport", "FlakyTransport",
]


class LinkDown(RuntimeError):
    """The endpoint↔server link is (or went) down: the send did not
    complete, or its acknowledgement was lost. Retryable — the journal
    entry stays pending and replays when the link revives."""


class TransportError(RuntimeError):
    """Permanent per-request transport failure (e.g. the server refused
    the request as malformed). Not retryable: replay would loop."""


class JournalFull(OverflowError):
    """The bounded journal is at capacity; the caller must degrade
    (answer locally) instead of queueing without bound."""


# ---------------------------------------------------------------------------
# durable journal
# ---------------------------------------------------------------------------


@dataclass
class JournalEntry:
    """One pending escalated request, reconstructed from disk."""

    seq: int
    req: Request
    meta: Dict[str, Any]


class EscalationJournal:
    """Bounded on-disk FIFO of escalated requests.

    Each entry is two files under ``root`` — ``esc-<seq>.npz`` (the
    prompt, and embeds when present) written through
    ``checkpoint.save`` and its ``.meta.json`` sidecar carrying the
    scalar request fields — so ``pending()`` needs nothing but a
    directory scan: append / ack / crash-restart all converge on the
    same on-disk truth. A small ``journal.state.json`` persists the
    next sequence number so seqs stay monotone across restarts even
    when the journal drains empty (seq reuse would break ack de-dup).

    Thread-safe: one lock serializes append/ack/scan (submit threads
    append while the pump replays).
    """

    PREFIX = "esc-"

    def __init__(self, root: str, capacity: int = 256):
        if capacity < 1:
            raise ValueError("journal capacity must be >= 1")
        self.root = root
        self.capacity = capacity
        self._lock = threading.Lock()
        os.makedirs(root, exist_ok=True)
        pending = self._scan()
        persisted = 0
        state = os.path.join(root, "journal.state.json")
        if os.path.exists(state):
            with open(state) as fh:
                persisted = json.load(fh).get("next_seq", 0)
        self._next_seq = max(persisted,
                             (pending[-1] + 1) if pending else 0)

    # -- paths / scan -------------------------------------------------------

    def _base(self, seq: int) -> str:
        return os.path.join(self.root, f"{self.PREFIX}{seq:08d}")

    def _scan(self) -> List[int]:
        """Seqs of complete entries on disk, ascending. An entry is
        complete only when both files exist (a crash mid-append leaves
        at most one torn record, which is ignored and overwritten)."""
        seqs = []
        for name in os.listdir(self.root):
            if name.startswith(self.PREFIX) and name.endswith(".npz"):
                seq = int(name[len(self.PREFIX):-len(".npz")])
                if os.path.exists(self._base(seq) + ".meta.json"):
                    seqs.append(seq)
        return sorted(seqs)

    # -- FIFO surface -------------------------------------------------------

    @property
    def depth(self) -> int:
        with self._lock:
            return len(self._scan())

    def __len__(self) -> int:
        return self.depth

    def append(self, req: Request, *, arrival_s: float = 0.0,
               source: str = "endpoint") -> int:
        """Persist ``req`` and return its journal sequence number.
        Raises ``JournalFull`` at capacity — durability is bounded, the
        caller degrades to a local answer instead of queueing forever."""
        with self._lock:
            if len(self._scan()) >= self.capacity:
                raise JournalFull(
                    f"escalation journal full ({self.capacity} pending)")
            seq = self._next_seq
            self._next_seq += 1
            arrays = {"prompt": np.asarray(req.prompt, np.int32)}
            if req.embeds is not None:
                arrays["embeds"] = np.asarray(req.embeds)
            checkpoint.save(self._base(seq), arrays, meta={
                "seq": seq, "id": req.id,
                "max_new_tokens": req.max_new_tokens, "eos": req.eos,
                "priority": req.priority, "deadline_s": req.deadline_s,
                "max_restarts": req.max_restarts,
                "arrival_s": arrival_s, "source": source})
            with open(os.path.join(self.root, "journal.state.json"),
                      "w") as fh:
                json.dump({"next_seq": self._next_seq}, fh)
            return seq

    def ack(self, seq: int) -> None:
        """Remove an entry (idempotent): its completion was surfaced, or
        it was answered locally / shed — either way replay must skip it."""
        with self._lock:
            for suffix in (".npz", ".meta.json"):
                try:
                    os.remove(self._base(seq) + suffix)
                except FileNotFoundError:
                    pass

    def pending(self) -> List[JournalEntry]:
        """Every unacked entry in sequence order, rebuilt from disk —
        the crash-restart recovery path and the replay path are the
        same code."""
        with self._lock:
            out = []
            for seq in self._scan():
                meta = checkpoint.load_meta(self._base(seq))
                arrays = checkpoint.load_flat(self._base(seq) + ".npz")
                req = Request(
                    id=meta["id"], prompt=arrays["prompt"],
                    max_new_tokens=meta["max_new_tokens"], eos=meta["eos"],
                    embeds=arrays.get("embeds"),
                    priority=meta["priority"],
                    deadline_s=meta["deadline_s"],
                    max_restarts=meta["max_restarts"])
                out.append(JournalEntry(seq=seq, req=req, meta=meta))
            return out


# ---------------------------------------------------------------------------
# transports
# ---------------------------------------------------------------------------


class InProcessTransport:
    """Remote tier in the same process: sends block on a second
    ``Engine`` (typically the big server-tier model). Works against
    both background-drained and caller-pumped engines —
    ``RequestHandle.result`` pumps the latter itself."""

    def __init__(self, engine: Engine, *, tier: str = "server",
                 timeout_s: float = 120.0):
        self.engine = engine
        self.tier = tier
        self.timeout_s = timeout_s

    def healthy(self) -> bool:
        return True

    def send(self, req: Request, *, seq: Optional[int] = None) -> Completion:
        handle = self.engine.submit(req)
        if self.engine.running:
            return handle.result(self.timeout_s)
        return handle.result()


class HttpTransport:
    """Remote tier behind the HTTP front end: posts to the server's
    ``/escalate`` ingress route. Connection-level failures raise
    ``LinkDown`` (retryable — the journal holds the request); HTTP 4xx
    raises ``TransportError`` (permanent); 5xx/429 raise ``LinkDown``
    so backpressured servers are retried rather than dropped."""

    def __init__(self, url: str, *, tier: str = "server",
                 timeout_s: float = 120.0, route: str = "/escalate"):
        from urllib.parse import urlparse
        u = urlparse(url)
        self.host, self.port = u.hostname, u.port
        self.tier = tier
        self.timeout_s = timeout_s
        self.route = route

    def healthy(self) -> bool:
        import http.client
        try:
            conn = http.client.HTTPConnection(self.host, self.port,
                                              timeout=5)
            try:
                conn.request("GET", "/health/ready")
                return conn.getresponse().status == 200
            finally:
                conn.close()
        except OSError:
            return False

    def send(self, req: Request, *, seq: Optional[int] = None) -> Completion:
        import http.client
        body = {"prompt": [int(t) for t in req.prompt],
                "max_new_tokens": req.max_new_tokens, "eos": req.eos,
                "priority": req.priority, "deadline_s": req.deadline_s,
                "seq": seq, "source": "endpoint"}
        try:
            conn = http.client.HTTPConnection(self.host, self.port,
                                              timeout=self.timeout_s)
            try:
                conn.request("POST", self.route, json.dumps(body),
                             {"Content-Type": "application/json"})
                r = conn.getresponse()
                raw = r.read()
            finally:
                conn.close()
        except OSError as e:
            raise LinkDown(f"escalation link down: {e}") from e
        if 400 <= r.status < 500 and r.status != 429:
            raise TransportError(f"/escalate -> {r.status}: {raw!r}")
        if r.status != 200:
            raise LinkDown(f"/escalate -> {r.status}")
        obj = json.loads(raw)
        return Completion(
            id=req.id, tokens=list(obj["tokens"]),
            prefill_s=0.0, decode_s=0.0,
            finish_reason=obj["finish_reason"],
            restarts=obj.get("restarts", 0))


class FlakyTransport:
    """Failure-injected wrapper: consults a ``resilience.FailureTrace``
    for the endpoint↔server link on every send. Dead at send time →
    the request never leaves (``LinkDown``). Dead at *completion* time →
    the server computed but the acknowledgement was lost, which is also
    ``LinkDown``: the entry stays journaled and is re-sent on revival —
    the replayer's de-dup makes the duplicate harmless."""

    def __init__(self, inner: Any, trace: FailureTrace, *,
                 a: str = "endpoint", b: str = "server",
                 clock: Optional[Callable[[], float]] = None):
        self.inner = inner
        self.trace = trace
        self.a, self.b = a, b
        self._t0 = time.perf_counter()
        self._clock = clock

    @property
    def tier(self) -> str:
        return self.inner.tier

    def bind_clock(self, clock: Callable[[], float]) -> None:
        """Adopt the tiered engine's clock so trace timestamps line up
        with its arrival/deadline instants (``TieredEngine.start`` calls
        this automatically)."""
        self._clock = clock

    def now(self) -> float:
        return self._clock() if self._clock is not None \
            else time.perf_counter() - self._t0

    def _dead(self) -> bool:
        return self.trace.link_dead_at(self.a, self.b, self.now())

    def healthy(self) -> bool:
        return not self._dead() and self.inner.healthy()

    def send(self, req: Request, *, seq: Optional[int] = None) -> Completion:
        if self._dead():
            raise LinkDown(
                f"link {self.a}<->{self.b} down at t={self.now():.3f}s")
        c = self.inner.send(req, seq=seq)
        if self._dead():
            raise LinkDown(
                f"link {self.a}<->{self.b} died before ack "
                f"(t={self.now():.3f}s); completion dropped")
        return c


# ---------------------------------------------------------------------------
# replay protocol
# ---------------------------------------------------------------------------


class JournalReplayer:
    """In-order at-least-once delivery of journal entries with ack-side
    de-duplication.

    ``step()`` walks the pending set in sequence order and sends each
    entry through the transport; an entry is acked (removed from disk)
    only after its completion has been handed to ``on_complete``. A
    ``LinkDown`` stops the walk at the head of the line — nothing after
    the failed entry is *surfaced*, preserving order across failures.
    ``delivered`` records surfaced seqs so a duplicate completion (a
    resend whose first ack was lost) is acked without being surfaced
    twice. ``link_up`` flips on observed send outcomes and on
    ``probe()``; each down→up transition is one *fail-back*, counted in
    ``failbacks``.

    ``window`` pipelines: up to that many sends are dispatched
    concurrently (the server tier batches them across its slots —
    serial replay would waste its decode width), but completions are
    still surfaced strictly in sequence order, and a failure anywhere
    in the window leaves every later entry unsurfaced and pending (the
    server may have computed it — the resend after revival is the
    at-least-once half the de-dup exists for). ``window=1`` is the
    fully synchronous, thread-free protocol the hypothesis property
    suite drives one operation at a time.
    """

    def __init__(self, journal: EscalationJournal, transport: Any, *,
                 on_complete: Optional[
                     Callable[[JournalEntry, Completion], None]] = None,
                 on_permanent_error: Optional[
                     Callable[[JournalEntry, Exception], None]] = None,
                 window: int = 1):
        self.journal = journal
        self.transport = transport
        self.on_complete = on_complete or (lambda entry, c: None)
        self.on_permanent_error = on_permanent_error or (lambda entry, e: None)
        self.window = max(1, window)
        self.delivered: Set[int] = set()
        self.link_up = True
        self.failbacks = 0

    def _note_up(self) -> None:
        if not self.link_up:
            self.link_up = True
            self.failbacks += 1

    def _note_down(self) -> None:
        self.link_up = False

    def probe(self) -> bool:
        """Poll transport health (a cheap liveness check, not a send)
        and fold the answer into the link state — how a revival is
        noticed while the journal is empty."""
        if self.transport.healthy():
            self._note_up()
        else:
            self._note_down()
        return self.link_up

    def step(self, max_sends: Optional[int] = None) -> int:
        """Send pending entries in order; returns completions surfaced.
        Stops at the first ``LinkDown`` (head-of-line order guarantee)
        or after ``max_sends`` sends (pump fairness bound). With
        ``window > 1`` each round dispatches a window of concurrent
        sends, then surfaces the results in sequence order."""
        surfaced = 0
        sends = 0
        while True:
            batch: List[JournalEntry] = []
            for entry in self.journal.pending():
                if entry.seq in self.delivered:
                    self.journal.ack(entry.seq)     # gc a stale duplicate
                    continue
                if max_sends is not None \
                        and sends + len(batch) >= max_sends:
                    break
                batch.append(entry)
                if len(batch) >= self.window:
                    break
            if not batch:
                return surfaced
            sends += len(batch)
            if len(batch) == 1:                     # serial fast path
                results = [self._send_one(batch[0])]
            else:
                results = [None] * len(batch)

                def _send(i: int, e: JournalEntry) -> None:
                    results[i] = self._send_one(e)

                threads = [threading.Thread(target=_send, args=(i, e),
                                            daemon=True)
                           for i, e in enumerate(batch)]
                for t in threads:
                    t.start()
                for t in threads:
                    t.join()
            for entry, (kind, val) in zip(batch, results):
                if kind == "down":
                    # head-of-line: nothing at or after the failure is
                    # surfaced or acked this round — even window-mates
                    # that succeeded (the server computed them; the
                    # resend after revival is de-duplicated on ack)
                    self._note_down()
                    return surfaced
                if kind == "error":
                    self.delivered.add(entry.seq)
                    self.on_permanent_error(entry, val)
                    self.journal.ack(entry.seq)
                    continue
                self._note_up()
                self.delivered.add(entry.seq)
                self.on_complete(entry, val)
                self.journal.ack(entry.seq)
                surfaced += 1
            if max_sends is not None and sends >= max_sends:
                return surfaced

    def _send_one(self, entry: JournalEntry) -> Tuple[str, Any]:
        try:
            return ("ok", self.transport.send(entry.req, seq=entry.seq))
        except LinkDown as e:
            return ("down", e)
        except TransportError as e:
            return ("error", e)
