"""Pytree checkpointing to a single .npz (path-flattened), plus a sidecar
JSON with the step counter and config name. Restore rebuilds the exact
pytree structure from a template (e.g. ``jax.eval_shape(init_params)``).

``load_flat`` is the template-free inverse of ``save`` for consumers
that persist *plain dicts of arrays* rather than model pytrees — the
escalation journal (``runtime.escalation``) serializes each queued
request through ``save``/``load_flat`` so its on-disk records share the
checkpoint format."""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, tree: Any, *, meta: Optional[Dict] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **_flatten(tree))
    with open(path + ".meta.json", "w") as fh:
        json.dump(meta or {}, fh)


def restore(path: str, template: Any) -> Any:
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    leaves_paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    out = []
    for p, leaf in leaves_paths:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in p)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def load_flat(path: str) -> Dict[str, np.ndarray]:
    """Load a ``save``d file as the flat ``{path: array}`` dict it was
    written from, without a pytree template. The journal's record format:
    callers that saved a plain dict get the same dict back."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    return {k: data[k] for k in data.files}


def load_meta(path: str) -> Dict:
    with open(path + ".meta.json") as fh:
        return json.load(fh)
