"""Synthetic data pipeline: deterministic, infinite, host-side.

Two generators:
* ``lm_batches`` — zipf-distributed token stream with local bigram
  structure, so a real model shows decreasing loss (used by the training
  examples and integration tests).
* ``copy_task_batches`` — the classic learnability probe: the model must
  copy a prefix after a separator; loss -> ~0 proves the training loop
  optimizes end-to-end.
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np


def lm_batches(batch: int, seq: int, vocab: int, *, seed: int = 0
               ) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.RandomState(seed)
    # fixed random bigram transition table over a zipf-ish marginal
    marg = 1.0 / np.arange(1, vocab + 1) ** 1.1
    marg /= marg.sum()
    n_ctx = min(vocab, 512)
    trans = rng.dirichlet(0.05 * vocab * marg, size=n_ctx)
    while True:
        toks = np.empty((batch, seq + 1), np.int64)
        toks[:, 0] = rng.choice(vocab, size=batch, p=marg)
        for t in range(1, seq + 1):
            rows = trans[toks[:, t - 1] % n_ctx]
            cum = rows.cumsum(1)
            u = rng.rand(batch, 1)
            toks[:, t] = (u < cum).argmax(1)
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}


def copy_task_batches(batch: int, seq: int, vocab: int, *, seed: int = 0
                      ) -> Iterator[Dict[str, np.ndarray]]:
    assert seq % 2 == 0
    half = seq // 2
    sep = vocab - 1
    rng = np.random.RandomState(seed)
    while True:
        prefix = rng.randint(1, vocab - 1, size=(batch, half))
        toks = np.concatenate(
            [prefix, np.full((batch, 1), sep), prefix[:, :half - 1]], axis=1)
        labels = np.concatenate(
            [np.full((batch, half), -1), prefix], axis=1)
        yield {"tokens": toks.astype(np.int32),
               "labels": labels.astype(np.int32)}
