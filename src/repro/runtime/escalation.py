"""Hierarchical edge↔server serving: tiered engines with a durable
escalation queue.

Edge-PRUNE's collaborative-inference result (a low-resource endpoint
plus an edge server beats either alone) productionized for the serving
path: a ``TieredEngine`` fronts a small local (endpoint) ``Engine`` and
a remote (server) tier, and decides *per request* whether to answer
locally or escalate — the decision is a pluggable policy list
(``runtime.policies``: ``confidence`` gates on the local model's
next-token certainty exactly like the shallow-head CA in
``examples/early_exit_offload.py``; ``deadline-risk`` escalates work
the local queue cannot finish in time; ``overload`` escalates under
local queue/KV pressure; ``always``/``never`` are the paper's
always-offload and endpoint-alone baselines). The fraction of traffic
that ever leaves the device is therefore a run-time quantity — the
privacy metric of the partitioning papers — reported by
``benchmarks/escalation_bench.py`` and countable from ``/metrics``.

The load-bearing half is the **durable escalation queue**, implemented
in ``runtime.escalation_queue`` and re-exported here:

* ``EscalationJournal`` — a bounded on-disk FIFO. Every escalated
  request is appended as a ``runtime.checkpoint``-serialized record
  (``.npz`` arrays + ``.meta.json`` sidecar) before anything is sent,
  so a crash or link cut loses nothing: a fresh journal over the same
  directory reconstructs the pending set purely from a directory scan.
* ``JournalReplayer`` — sends pending entries strictly in sequence
  order through a transport, acking (= deleting) each entry only after
  its completion has been surfaced. A ``LinkDown`` stops replay at the
  head of the line; delivery is therefore at-least-once and in-order,
  and the ``delivered`` seq set de-duplicates on ack so a resend after
  a lost acknowledgement surfaces exactly one completion.
* transports — ``InProcessTransport`` wraps a second ``Engine`` in the
  same process; ``HttpTransport`` posts to the HTTP front end's
  ``/escalate`` ingress route; ``FlakyTransport`` wraps either and
  injects link up/down from a ``resilience.FailureTrace``, raising
  ``LinkDown`` when the link is dead at send *or* at acknowledgement
  time (the server may have computed; the reply was lost — replay +
  de-dup make that safe).

Degraded modes close the loop: while the link is down, a journaled
request whose deadline cannot wait is answered by the local engine with
``finish_reason="local_fallback"``; one whose deadline has already
passed is shed as ``"timeout"``. When the link revives, the journal
*fails back* — replays in order to the server tier — and the transition
is counted (``repro_failback_total``) and traced.

The ``TieredEngine`` duck-types the ``Engine`` surface the HTTP front
end drives (``submit``/``snapshot``/``metrics_text``/``trace_json``/
``start``/``shutdown``), so ``runtime.server.EngineServer`` can front a
tiered endpoint unchanged: ``/generate`` escalates transparently,
``/status`` reports the tier identity and escalation state, and
``/metrics`` exposes ``repro_escalated_total``,
``repro_local_fallback_total``, ``repro_failback_total`` and the
``repro_escalation_queue_depth`` gauge.
"""
from __future__ import annotations

import tempfile
import threading
import time
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.runtime.engine import Engine
from repro.runtime.escalation_queue import (  # noqa: F401  (re-export)
    EscalationJournal, FlakyTransport, HttpTransport, InProcessTransport,
    JournalEntry, JournalFull, JournalReplayer, LinkDown, TransportError)
from repro.runtime.policies import make_escalation
from repro.runtime.scheduler import (Completion, Request,
                                     validate_request_fits)

__all__ = [
    "LinkDown", "TransportError", "JournalFull",
    "EscalationJournal", "JournalEntry", "JournalReplayer",
    "InProcessTransport", "HttpTransport", "FlakyTransport",
    "EscalationContext", "TieredConfig", "TieredHandle", "TieredEngine",
]

# ---------------------------------------------------------------------------
# tiered engine
# ---------------------------------------------------------------------------


@dataclass
class EscalationContext:
    """What an escalation policy sees (see ``runtime.policies``).
    Both ``snapshot`` and ``confidence()`` are lazy and cached: each is
    computed only if some policy asks, and at most once per request —
    submit() sits on the caller's latency path, so the context must cost
    nothing for policies that never look."""

    req: Request
    now_s: float
    snapshot_fn: Optional[Callable[[], Dict[str, Any]]] = None
    confidence_fn: Optional[Callable[[], float]] = None
    _snap: Optional[Dict[str, Any]] = field(default=None, repr=False)
    _cached: Optional[float] = field(default=None, repr=False)

    @property
    def snapshot(self) -> Dict[str, Any]:
        if self._snap is None:
            self._snap = (self.snapshot_fn()
                          if self.snapshot_fn is not None else {})
        return self._snap

    def confidence(self) -> float:
        if self._cached is None:
            self._cached = (self.confidence_fn()
                            if self.confidence_fn is not None else 1.0)
        return self._cached


@dataclass
class TieredConfig:
    """Escalation knobs for one ``TieredEngine``."""

    # policy list (names from policies.ESCALATION_POLICIES or
    # instances); ORed — the first reason to escalate wins
    policies: Any = ("confidence",)
    # durable queue: directory (None = fresh tempdir) + capacity bound
    journal_dir: Optional[str] = None
    journal_capacity: int = 256
    # this engine's tier identity (reported in /status and snapshots)
    tier: str = "endpoint"
    # link down: a journaled request whose deadline slack falls below
    # this is answered locally as finish_reason="local_fallback"; one
    # whose deadline already passed is shed as "timeout". Requests
    # without deadlines wait for the link — that is what durable means.
    fallback_slack_s: float = 0.25
    # pump cadence while idle / link-down backoff
    poll_interval_s: float = 0.02
    # replay fairness: sends attempted per pump round
    max_sends_per_pump: int = 8
    # concurrent in-flight sends per replay round: the server tier
    # batches the window across its decode slots (1 = fully serial).
    # Completion *surfacing* stays in sequence order either way.
    replay_window: int = 4


class TieredHandle:
    """The caller's end of one tiered request. Mirrors the
    ``RequestHandle`` surface the HTTP front end uses (``stream()``,
    ``result()``, ``cancel()``, ``.completion``); adds the tier verdict:
    ``escalated`` (did it leave the device), ``reason`` (which policy
    fired), ``tier`` (who answered), ``seq`` (journal sequence when
    escalated)."""

    def __init__(self, engine: "TieredEngine", request: Request):
        self.request = request
        self.completion: Optional[Completion] = None
        self.escalated = False
        self.reason: Optional[str] = None
        self.tier: Optional[str] = None
        self.seq: Optional[int] = None
        self.arrival_s = 0.0
        self._engine = engine
        self._inner = None              # local RequestHandle, when local
        self._cancelled = False
        self._done = threading.Event()

    @property
    def done(self) -> bool:
        return self.completion is not None

    @property
    def finish_reason(self) -> Optional[str]:
        return self.completion.finish_reason if self.completion else None

    @property
    def tokens(self) -> List[int]:
        if self.completion is not None:
            return list(self.completion.tokens)
        inner = self._inner
        return list(inner.tokens) if inner is not None else []

    def cancel(self) -> None:
        """Cancel: a local request cancels through its engine handle; a
        journaled one is retired by the pump before its next send."""
        self._cancelled = True
        inner = self._inner
        if inner is not None:
            inner.cancel()

    def result(self, timeout: Optional[float] = None) -> Completion:
        inner = self._inner
        if inner is not None and self.completion is None:
            # finalize from the waiting thread: local completions must
            # not queue behind the pump, which can be blocked inside a
            # (serial, possibly slow) escalation send
            inner.result(timeout)
            self._engine._finalize_if_pending(self)
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"request {self.request.id} did not complete within "
                f"{timeout}s")
        return self.completion

    def stream(self) -> Iterator[int]:
        """Yield tokens as they exist. Locally-served requests stream
        live through the inner engine handle; escalated ones burst when
        the server's completion lands (the transport returns whole
        completions). Never returns before ``completion`` is set."""
        while True:
            inner = self._inner
            if inner is not None:
                for tok in inner.stream():
                    yield tok
                self._engine._finalize_if_pending(self)
                self._done.wait()
                # a fallback rewrite never changes tokens, only reason
                return
            if self._done.wait(0.05):
                for tok in self.completion.tokens:
                    yield tok
                return

    # engine-side
    def _complete(self, c: Completion, tier: str) -> None:
        if self.completion is not None:
            return
        self.tier = tier
        self.completion = c
        self._done.set()


class TieredEngine:
    """Policy-gated front over a local (endpoint) ``Engine`` and a
    remote (server) tier reached through a transport.

    ``submit()`` consults the escalation policies; local requests flow
    straight into the endpoint engine (token streams and greedy content
    are *bit-identical* to running that engine alone — escalation moves
    requests, never content), escalated ones are journaled durably and
    replayed in order to the server tier by a background pump, with
    deadline-aware local fallback while the link is down and fail-back
    on revival. Background-only: ``start()`` (which also starts the
    local engine's drain) before ``submit()``."""

    def __init__(self, local: Engine, transport: Any,
                 config: Optional[TieredConfig] = None):
        if local.batch_mode:
            raise ValueError(
                "the tiered engine pumps the local engine's background "
                "drain; batch admission has no step loop — use a "
                "continuous admission policy (fifo | priority | edf)")
        self.local = local
        self.transport = transport
        self.config = cfg = config or TieredConfig()
        self.policies = make_escalation(cfg.policies)
        root = cfg.journal_dir or tempfile.mkdtemp(prefix="esc-journal-")
        self.journal = EscalationJournal(root, cfg.journal_capacity)
        self.replayer = JournalReplayer(
            self.journal, transport,
            on_complete=self._on_delivered,
            on_permanent_error=self._on_permanent_error,
            window=cfg.replay_window)
        self.obs = local.obs            # one registry/tracer: /metrics and
        #                                 /trace stay single-source
        self._lock = threading.Lock()
        self._handles: Dict[int, TieredHandle] = {}
        self._local_pending: List[Tuple[TieredHandle, bool]] = []
        self._failbacks_seen = 0
        self._pump: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._work = threading.Event()
        self._t0 = time.perf_counter()
        self._probe_jits: Dict[int, Any] = {}
        r = self.obs.registry
        self._c_escalated = r.counter(
            "repro_escalated_total",
            help="requests answered by the server tier")
        self._c_fallback = r.counter(
            "repro_local_fallback_total",
            help="escalations answered locally because the link was down "
                 "and the deadline could not wait")
        self._c_failback = r.counter(
            "repro_failback_total",
            help="link revivals that resumed journal replay to the "
                 "server tier")
        self._c_sheds = r.counter(
            "repro_escalation_sheds_total",
            help="journaled requests shed as timeout while the link was "
                 "down")
        self._g_depth = r.gauge(
            "repro_escalation_queue_depth",
            help="escalated requests pending in the durable journal")
        from repro.runtime.observability import TIME_BUCKETS_S
        self._h_ttft = {
            "local": r.histogram(
                "repro_tier_local_ttft_seconds", TIME_BUCKETS_S,
                help="TTFT of requests answered on the endpoint tier"),
            transport.tier: r.histogram(
                f"repro_tier_{transport.tier}_ttft_seconds", TIME_BUCKETS_S,
                help=f"submit-to-completion wall latency of requests "
                     f"escalated to the {transport.tier} tier"),
        }

    # -- engine-surface plumbing (what EngineServer drives) -----------------

    batch_mode = False

    @property
    def tier(self) -> str:
        return self.config.tier

    @property
    def max_len(self) -> int:
        return self.local.max_len

    @property
    def cfg(self):
        return self.local.cfg

    @property
    def running(self) -> bool:
        t = self._pump
        return t is not None and t.is_alive()

    def now(self) -> float:
        """Seconds on the tiered engine's clock (since ``start()``) —
        the clock arrival stamps, deadlines, and the failure trace for
        a ``FlakyTransport`` all share."""
        return time.perf_counter() - self._t0

    def start(self) -> "TieredEngine":
        if self.running:
            return self
        self._t0 = time.perf_counter()
        if hasattr(self.transport, "bind_clock"):
            self.transport.bind_clock(self.now)
        self.local.start()
        self._stop.clear()
        self._pump = threading.Thread(
            target=self._pump_loop, name="tiered-pump", daemon=True)
        self._pump.start()
        return self

    def shutdown(self, wait: bool = True) -> None:
        self._stop.set()
        self._work.set()
        t = self._pump
        if wait and t is not None and t is not threading.current_thread():
            t.join()
        self._pump = None
        self.local.shutdown(wait=wait)

    def __enter__(self) -> "TieredEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- submission ---------------------------------------------------------

    def submit(self, req: Request, arrival_s: float = 0.0) -> TieredHandle:
        """Decide (escalate or answer locally) and enqueue. Thread-safe,
        like the engine surface it fronts."""
        if not self.running:
            raise RuntimeError(
                "TieredEngine is background-only: call start() first")
        validate_request_fits(self.local.cfg, req, self.local.max_len)
        handle = TieredHandle(self, req)
        handle.arrival_s = arrival_s or self.now()
        ctx = EscalationContext(
            req=req, now_s=handle.arrival_s,
            snapshot_fn=self._load_view,
            confidence_fn=lambda: self._confidence(req))
        reason = None
        for policy in self.policies:
            reason = policy.decide(ctx)
            if reason:
                break
        if reason is None:
            self._submit_local(handle, fallback=False)
            return handle
        handle.escalated = True
        handle.reason = reason
        try:
            seq = self.journal.append(req, arrival_s=handle.arrival_s,
                                      source=self.config.tier)
        except JournalFull:
            # bounded durability: degrade to a local answer rather than
            # queueing without bound (reported as local_fallback)
            self._submit_local(handle, fallback=True)
            return handle
        handle.seq = seq
        with self._lock:
            self._handles[seq] = handle
        if self.obs.enabled:
            self.obs.tracer.async_begin(
                "tiered", "escalation", "escalate", seq, handle.arrival_s,
                args={"request": req.id, "reason": reason})
        self._g_depth.set(self.journal.depth)
        self._work.set()
        return handle

    def _submit_local(self, handle: TieredHandle, *, fallback: bool) -> None:
        handle._inner = self.local.submit(handle.request)
        with self._lock:
            self._local_pending.append((handle, fallback))
        self._work.set()

    def _finalize_if_pending(self, handle: TieredHandle) -> None:
        """Finalize a locally-served handle whose engine completion is
        ready — callable from the waiting caller *or* the pump; whoever
        removes the pending entry under the lock does the work, so the
        race is idempotent."""
        if handle._inner is None or not handle._inner.done:
            return
        with self._lock:
            entry = next((p for p in self._local_pending
                          if p[0] is handle), None)
            if entry is None:
                return
            self._local_pending.remove(entry)
        self._finalize_local(handle, entry[1])

    def _load_view(self) -> Dict[str, Any]:
        """Cheap local-load view for escalation policies — deliberately
        NOT ``Engine.snapshot()``. The snapshot takes the engine lock,
        which the background drain holds across every scheduler step and
        reacquires immediately in a tight loop: a submit-path caller can
        convoy behind it for the length of the whole local backlog.
        Policies want a load *heuristic*, not a consistent snapshot, so
        this reads the counters lock-free (atomic int reads; at worst
        one step stale) and treats KV stats as best-effort."""
        s = self.local.scheduler
        if s is None:
            return {"queue_depth": 0, "active_slots": 0, "kv": {}}
        depth = max(0, s._waiting()) + len(self.local._inbox)
        view: Dict[str, Any] = {"queue_depth": depth,
                                "active_slots": len(s.active)}
        try:
            view["kv"] = s.kv_stats()
        except Exception:
            view["kv"] = {}         # racing a layout mutation: skip, don't block
        return view

    # -- confidence probe ---------------------------------------------------

    def _confidence(self, req: Request) -> float:
        """Local-model certainty about ``req``: max softmax probability
        of the next-token prediction after prefilling the prompt — the
        LLM analogue of the shallow-head gate in
        ``examples/early_exit_offload.py``. Jitted per prompt length."""
        import jax

        from repro.models import transformer as T
        L = len(req.prompt)
        fn = self._probe_jits.get(L)
        if fn is None:
            cfg, max_len = self.local.cfg, self.local.max_len

            def _probe(params, tokens):
                logits, _, _ = T.prefill(params, cfg, {"tokens": tokens},
                                         max_len=max_len)
                return jax.numpy.max(jax.nn.softmax(logits[0]))

            fn = self._probe_jits[L] = jax.jit(_probe)
        tokens = np.asarray(req.prompt, np.int32)[None, :]
        return float(fn(self.local.params, tokens))

    # -- pump ---------------------------------------------------------------

    def _pump_loop(self) -> None:
        while not self._stop.is_set():
            progressed = self._pump_once()
            if not progressed:
                self._work.wait(self.config.poll_interval_s)
                self._work.clear()

    def _pump_once(self) -> bool:
        did = False
        # 1. finalize local submissions whose engine handle completed
        #    (waiting callers race us through _finalize_if_pending; the
        #    pump sweep covers handles nobody is waiting on)
        with self._lock:
            pairs = list(self._local_pending)
        for handle, fallback in pairs:
            if handle._inner.done:
                before = handle.done
                self._finalize_if_pending(handle)
                did |= not before
        # 2. link state / fail-back detection
        up = self.replayer.probe()
        if self.replayer.failbacks > self._failbacks_seen:
            self._c_failback.inc(
                self.replayer.failbacks - self._failbacks_seen)
            self._failbacks_seen = self.replayer.failbacks
            if self.obs.enabled:
                self.obs.tracer.instant(
                    "tiered", "escalation", "failback", self.now(),
                    args={"pending": self.journal.depth})
        # 3. triage journaled requests: cancellations always; deadline
        #    pressure only while the link is down (when it is up, replay
        #    below is the fastest path to an answer)
        did |= self._triage(link_up=up)
        # 4. replay toward the server tier
        if up:
            did |= self.replayer.step(
                max_sends=self.config.max_sends_per_pump) > 0
        self._g_depth.set(self.journal.depth)
        return did

    def _triage(self, *, link_up: bool) -> bool:
        did = False
        now = self.now()
        for entry in self.journal.pending():
            if entry.seq in self.replayer.delivered:
                continue
            with self._lock:
                handle = self._handles.get(entry.seq)
            if handle is None:
                continue        # crash-restart orphan: replay-only
            if handle._cancelled:
                self._retire(entry.seq, handle, Completion(
                    entry.req.id, [], 0.0, 0.0, arrival_s=handle.arrival_s,
                    finish_reason="cancelled"), tier=self.config.tier)
                did = True
                continue
            if link_up or entry.req.deadline_s is None:
                continue
            due = handle.arrival_s + entry.req.deadline_s
            if due <= now:
                # escalated-timeout shed: consistent with the engine's
                # wall-clock deadline enforcement
                self._c_sheds.inc()
                self._retire(entry.seq, handle, Completion(
                    entry.req.id, [], 0.0, 0.0, arrival_s=handle.arrival_s,
                    finish_s=now, finish_reason="timeout"),
                    tier=self.config.tier)
                did = True
            elif due - now <= self.config.fallback_slack_s:
                # degraded local answering: the deadline can't wait for
                # the link — answer on-device, marked local_fallback
                self.replayer.delivered.add(entry.seq)
                self.journal.ack(entry.seq)
                with self._lock:
                    self._handles.pop(entry.seq, None)
                if self.obs.enabled:
                    self.obs.tracer.async_end(
                        "tiered", "escalation", entry.seq, now,
                        args={"outcome": "local_fallback"})
                self._submit_local(handle, fallback=True)
                did = True
        return did

    def _retire(self, seq: int, handle: TieredHandle, c: Completion, *,
                tier: str) -> None:
        """Complete a journaled request without sending it."""
        self.replayer.delivered.add(seq)
        self.journal.ack(seq)
        with self._lock:
            self._handles.pop(seq, None)
        if self.obs.enabled:
            self.obs.tracer.async_end(
                "tiered", "escalation", seq, self.now(),
                args={"outcome": c.finish_reason})
        handle._complete(c, tier)

    # -- completion paths ---------------------------------------------------

    def _finalize_local(self, handle: TieredHandle, fallback: bool) -> None:
        c = handle._inner.completion
        if fallback and c.finish_reason in ("eos", "length"):
            c = replace(c, finish_reason="local_fallback")
        if fallback:
            self._c_fallback.inc()
        if self.obs.enabled:
            self._h_ttft["local"].observe(max(c.ttft_s, 0.0))
        handle._complete(c, self.config.tier)

    def _on_delivered(self, entry: JournalEntry, c: Completion) -> None:
        with self._lock:
            handle = self._handles.pop(entry.seq, None)
        self._c_escalated.inc()
        now = self.now()
        if self.obs.enabled and handle is not None:
            # (handle None = crash-restart orphan: replayed for
            # durability, but no span was opened in this process)
            self.obs.tracer.async_end(
                "tiered", "escalation", entry.seq, now,
                args={"outcome": "escalated", "tier": self.transport.tier})
            self._h_ttft[self.transport.tier].observe(
                max(now - handle.arrival_s, 0.0))
        if handle is not None:
            handle._complete(c, self.transport.tier)

    def _on_permanent_error(self, entry: JournalEntry, e: Exception) -> None:
        with self._lock:
            handle = self._handles.pop(entry.seq, None)
        if self.obs.enabled and handle is not None:
            self.obs.tracer.async_end(
                "tiered", "escalation", entry.seq, self.now(),
                args={"outcome": "failed", "error": str(e)})
        if handle is not None:
            handle._complete(Completion(
                entry.req.id, [], 0.0, 0.0, arrival_s=handle.arrival_s,
                finish_reason="failed"), self.transport.tier)

    # -- introspection ------------------------------------------------------

    def escalation_stats(self) -> Dict[str, Any]:
        return {
            "queue_depth": self.journal.depth,
            "link_up": self.replayer.link_up,
            "escalated": int(self._c_escalated.value),
            "local_fallback": int(self._c_fallback.value),
            "failback": int(self._c_failback.value),
            "sheds": int(self._c_sheds.value),
            "tiers": ["local", self.transport.tier],
        }

    def snapshot(self) -> Dict[str, Any]:
        snap = self.local.snapshot()
        snap["tier"] = self.config.tier
        snap["escalation"] = self.escalation_stats()
        return snap

    def stats(self) -> Dict[str, int]:
        return self.local.stats()

    def kv_stats(self) -> Dict[str, float]:
        return self.local.kv_stats()

    def metrics_text(self,
                     extra_gauges: Optional[Dict[str, float]] = None) -> str:
        """One Prometheus exposition for the whole tier: the local
        engine's counters/gauges/histograms plus the escalation metrics
        (they share the registry, so this is the engine's own render
        with the queue-depth gauge freshly stamped)."""
        self._g_depth.set(self.journal.depth)
        return self.local.metrics_text(extra_gauges=extra_gauges)

    def trace_json(self) -> Dict[str, Any]:
        return self.local.trace_json()
