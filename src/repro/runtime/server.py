"""HTTP serving front end over the background-drained ``Engine``.

A deliberately dependency-free server (stdlib ``http.server`` only — the
container has no web framework) that exposes the wall-clock serving
surface built in ``runtime.engine``:

* ``POST /generate`` — submit one request. Body is JSON::

      {"prompt": [1, 2, 3], "max_new_tokens": 32,
       "eos": null, "priority": 0, "deadline_s": null, "stream": false}

  ``prompt`` is a list of token ids (the repro has no tokenizer — the
  model speaks ids). Non-streaming responses return one JSON object
  ``{"id", "tokens", "finish_reason", "ttft_s", "latency_s"}``;
  ``"stream": true`` switches to chunked transfer encoding with one
  NDJSON line per token (``{"token": 17}``) and a terminal line
  carrying the completion (``{"done": true, "finish_reason": ...}``),
  so time-to-first-byte tracks time-to-first-token.
* ``GET /health/live`` — process is up (200 always once listening).
* ``GET /health/ready`` — 200 after the warmup request has compiled
  the prefill/decode kernels, 503 before; load balancers gate on this.
* ``GET /status`` — queue depth, in-flight count, KV pool occupancy,
  lifecycle counters, and (observability on) histogram summaries — one
  consistent ``Engine.snapshot()`` taken under the engine's own lock.
* ``GET /metrics`` — Prometheus text exposition: lifecycle counters and
  occupancy gauges always; TTFT / inter-token / step-duration /
  queue-wait / per-phase histograms when the engine was built with
  ``EngineConfig(observability=True)``.
* ``GET /trace`` — the engine's Chrome trace-event JSON so far (loads
  in Perfetto / ``chrome://tracing``; empty-but-valid with
  observability off).
* ``POST /escalate`` — the tier-to-tier ingress: same request schema as
  ``/generate`` (plus optional ``seq``/``source`` echoed back) but
  always non-streaming, counted separately
  (``repro_escalations_received_total``). A ``runtime.escalation``
  ``HttpTransport`` on an endpoint posts its journal replays here; the
  response carries this server's ``tier`` so the endpoint can label
  per-tier latency. ``/status`` reports ``tier`` — from ``ServerConfig``
  for a plain engine, or the fronted ``TieredEngine``'s own identity —
  so topology is discoverable.

Backpressure: admission is bounded. At most ``max_inflight`` requests
may be open (queued + decoding) at once; a ``/generate`` beyond that is
refused with 429 + ``Retry-After`` instead of growing the queue without
bound — on an edge device the right failure mode is to shed at the
front door, not to OOM. Per-request wall-clock deadlines compose with
this: with ``enforce_deadlines`` on, an admitted-but-expired request
comes back with ``finish_reason="timeout"``.

``ThreadingHTTPServer`` gives one thread per connection; every handler
thread just blocks on its ``RequestHandle`` (condition-variable waits)
while the engine's single drain thread pumps the scheduler — the model
never runs concurrently with itself, so there is exactly one step loop
no matter how many clients connect.

Run it::

    PYTHONPATH=src python -m repro.runtime.server --tiny --port 8800

``--smoke`` starts the server, streams one request through the HTTP
surface, checks ``/health/ready`` and ``/status``, and exits — the CI
fast-lane liveness gate.
"""
from __future__ import annotations

import itertools
import json
import threading
import time
from dataclasses import dataclass
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, Optional

import numpy as np

from repro.runtime.engine import Engine

__all__ = ["ServerConfig", "EngineServer", "main"]


@dataclass
class ServerConfig:
    host: str = "127.0.0.1"
    port: int = 8800            # 0 = ephemeral (tests); read .port after start
    # admission bound: open requests (queued + decoding) before /generate
    # starts returning 429. Sized to a small multiple of the decode batch
    # so the queue stays short enough for deadlines to be meetable.
    max_inflight: int = 32
    retry_after_s: int = 1      # Retry-After hint on 429
    # per-request cap on max_new_tokens (a client can't pin a slot for
    # an unbounded decode); 0 disables the cap
    max_new_cap: int = 0
    warmup: bool = True         # run a compile request before reporting ready
    # this server's tier identity in a hierarchical (endpoint <-> server)
    # topology: reported in /status and echoed by /escalate. A fronted
    # TieredEngine's own tier takes precedence.
    tier: str = "server"


class _BadRequest(ValueError):
    """Client error -> 400 with the message in the JSON body."""


class EngineServer:
    """Own an ``Engine`` (background-drained) plus the HTTP listener.

    ``start()`` spawns the engine drain thread, runs the warmup request
    (so the first client never pays JIT compile latency and readiness
    actually means ready), then starts serving; ``close()`` tears both
    down. Usable as a context manager."""

    def __init__(self, engine: Engine, config: Optional[ServerConfig] = None):
        if engine.batch_mode:
            raise ValueError(
                "the HTTP server drives the background drain; batch "
                "admission has no step loop — use a continuous admission "
                "policy (fifo | priority | edf)")
        self.engine = engine
        self.config = config or ServerConfig()
        self.ready = threading.Event()
        self._ids = itertools.count()
        self._inflight = 0
        self._inflight_lock = threading.Lock()
        self._httpd: Optional[ThreadingHTTPServer] = None
        self._http_thread: Optional[threading.Thread] = None
        self.port = self.config.port
        self._escalations = engine.obs.registry.counter(
            "repro_escalations_received_total",
            help="requests ingested through /escalate (tier-to-tier "
                 "traffic, vs. client traffic on /generate)")

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "EngineServer":
        self.engine.start()
        if self.config.warmup:
            self._warmup()
        server = self

        class Handler(_Handler):
            srv = server

        self._httpd = ThreadingHTTPServer(
            (self.config.host, self.config.port), Handler)
        self._httpd.daemon_threads = True
        self.port = self._httpd.server_address[1]
        self._http_thread = threading.Thread(
            target=self._httpd.serve_forever, name="engine-http", daemon=True)
        self._http_thread.start()
        self.ready.set()
        return self

    def close(self) -> None:
        self.ready.clear()
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._httpd = None
        self.engine.shutdown()

    def __enter__(self) -> "EngineServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def url(self) -> str:
        return f"http://{self.config.host}:{self.port}"

    def _warmup(self) -> None:
        """One short greedy request through the live engine compiles the
        prefill/decode kernels before /health/ready reports 200."""
        from repro.runtime.scheduler import Request
        prompt = np.ones(min(8, self.engine.max_len - 2), np.int32)
        self.engine.submit(
            Request(id=next(self._ids), prompt=prompt,
                    max_new_tokens=2)).result()

    # -- request plumbing (called from handler threads) ---------------------

    def admit(self, body: Dict[str, Any]):
        """Validate + submit under the admission bound. Returns the
        ``RequestHandle`` or raises ``_BadRequest`` / ``_Overloaded``."""
        from repro.runtime.scheduler import Request

        prompt = body.get("prompt")
        if (not isinstance(prompt, list) or not prompt
                or not all(isinstance(t, int) for t in prompt)):
            raise _BadRequest("'prompt' must be a non-empty list of "
                              "token ids (ints)")
        max_new = body.get("max_new_tokens", 16)
        if not isinstance(max_new, int) or max_new < 1:
            raise _BadRequest("'max_new_tokens' must be a positive int")
        cap = self.config.max_new_cap
        if cap:
            max_new = min(max_new, cap)
        deadline_s = body.get("deadline_s")
        if deadline_s is not None \
                and not isinstance(deadline_s, (int, float)):
            raise _BadRequest("'deadline_s' must be a number (seconds)")
        eos = body.get("eos")
        if eos is not None and not isinstance(eos, int):
            raise _BadRequest("'eos' must be an int token id")
        priority = body.get("priority", 0)
        if not isinstance(priority, int):
            raise _BadRequest("'priority' must be an int")
        tenant = body.get("tenant", "")
        if not isinstance(tenant, str):
            raise _BadRequest("'tenant' must be a string (prefix-cache "
                              "namespace)")
        req = Request(
            id=next(self._ids),
            prompt=np.asarray(prompt, np.int32),
            max_new_tokens=max_new, eos=eos, priority=priority,
            deadline_s=float(deadline_s) if deadline_s is not None else None,
            tenant=tenant)
        with self._inflight_lock:
            if self._inflight >= self.config.max_inflight:
                raise _Overloaded(self.config.max_inflight)
            self._inflight += 1
        try:
            handle = self.engine.submit(req)
        except Exception:
            with self._inflight_lock:
                self._inflight -= 1
            raise
        return handle

    def release(self) -> None:
        with self._inflight_lock:
            self._inflight -= 1

    def status(self) -> Dict[str, Any]:
        st = self.engine.snapshot()     # engine state under the engine lock
        st.update(ready=self.ready.is_set(), inflight=self._inflight,
                  max_inflight=self.config.max_inflight,
                  escalations_received=int(self._escalations.value))
        # a TieredEngine snapshot already carries its own tier identity
        st.setdefault("tier", self.config.tier)
        return st

    def metrics_text(self) -> str:
        """Prometheus exposition: the engine's registry plus the
        server-side admission-bound gauges."""
        with self._inflight_lock:
            inflight = self._inflight
        return self.engine.metrics_text(extra_gauges={
            "repro_http_inflight": float(inflight),
            "repro_http_max_inflight": float(self.config.max_inflight)})


class _Overloaded(RuntimeError):
    """Admission bound hit -> 429."""

    def __init__(self, bound: int):
        super().__init__(f"admission queue full ({bound} requests in flight)")


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"       # keep-alive + chunked streaming
    srv: EngineServer = None            # bound per-server in start()

    # quiet: the default handler logs every request line to stderr
    def log_message(self, fmt, *args):  # noqa: D102
        pass

    # -- helpers ------------------------------------------------------------

    def _json(self, code: int, obj: Dict[str, Any],
              headers: Optional[Dict[str, str]] = None) -> None:
        data = json.dumps(obj).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(data)))
        for k, v in (headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(data)

    def _chunk(self, data: bytes) -> None:
        self.wfile.write(f"{len(data):X}\r\n".encode() + data + b"\r\n")

    # -- routes -------------------------------------------------------------

    def do_GET(self) -> None:
        if self.path == "/health/live":
            self._json(200, {"status": "live"})
        elif self.path == "/health/ready":
            if self.srv.ready.is_set():
                self._json(200, {"status": "ready"})
            else:
                self._json(503, {"status": "starting"})
        elif self.path == "/status":
            self._json(200, self.srv.status())
        elif self.path == "/metrics":
            data = self.srv.metrics_text().encode()
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4; charset=utf-8")
            self.send_header("Content-Length", str(len(data)))
            self.end_headers()
            self.wfile.write(data)
        elif self.path == "/trace":
            self._json(200, self.srv.engine.trace_json())
        else:
            self._json(404, {"error": f"no route {self.path!r}"})

    def do_POST(self) -> None:
        if self.path not in ("/generate", "/escalate"):
            self._json(404, {"error": f"no route {self.path!r}"})
            return
        escalate = self.path == "/escalate"
        try:
            n = int(self.headers.get("Content-Length", 0))
            body = json.loads(self.rfile.read(n) or b"{}")
            if not isinstance(body, dict):
                raise _BadRequest("body must be a JSON object")
        except (ValueError, json.JSONDecodeError) as e:
            self._json(400, {"error": f"bad JSON body: {e}"})
            return
        try:
            handle = self.srv.admit(body)
        except _BadRequest as e:
            self._json(400, {"error": str(e)})
            return
        except _Overloaded as e:
            self._json(429, {"error": str(e)},
                       {"Retry-After": str(self.srv.config.retry_after_s)})
            return
        if escalate:
            self.srv._escalations.inc()
        try:
            if body.get("stream") and not escalate:
                self._stream(handle)
            else:
                c = handle.result()
                out = _completion_json(c)
                if escalate:
                    # echo routing metadata so the endpoint's replayer
                    # can correlate and label the answering tier
                    out["tier"] = getattr(self.srv.engine, "tier",
                                          self.srv.config.tier)
                    if body.get("seq") is not None:
                        out["seq"] = body["seq"]
                self._json(200, out)
        except (BrokenPipeError, ConnectionResetError):
            handle.cancel()     # client went away: free the slot
        finally:
            self.srv.release()

    def _stream(self, handle) -> None:
        self.send_response(200)
        self.send_header("Content-Type", "application/x-ndjson")
        self.send_header("Transfer-Encoding", "chunked")
        self.end_headers()
        for tok in handle.stream():
            self._chunk(json.dumps({"token": int(tok)}).encode() + b"\n")
            self.wfile.flush()
        final = dict(done=True, **_completion_json(handle.completion))
        self._chunk(json.dumps(final).encode() + b"\n")
        self._chunk(b"")        # terminal chunk
        self.wfile.flush()


def _completion_json(c) -> Dict[str, Any]:
    return {
        "id": c.id,
        "tokens": [int(t) for t in c.tokens],
        "finish_reason": c.finish_reason,
        "ttft_s": c.ttft_s,
        "latency_s": c.latency_s,
        "restarts": c.restarts,
    }


# ---------------------------------------------------------------------------
# CLI: PYTHONPATH=src python -m repro.runtime.server --tiny [--smoke]
# ---------------------------------------------------------------------------


def _build_tiny_engine(args):
    """A ~1M-param demo model so the server runs anywhere (CI included)."""
    import jax

    from repro.models import transformer as T
    from repro.models.config import ModelConfig
    from repro.runtime.engine import EngineConfig

    cfg = ModelConfig(
        name="server-tiny", arch_type="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256, dtype="float32",
        param_dtype="float32", attn_chunk=16, remat=False)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    ec = EngineConfig.from_args(args, max_len=args.max_len,
                                admission=args.policy or "fifo")
    return Engine(cfg, params, ec)


def _smoke(url: str, trace_out: Optional[str] = None) -> None:
    """One streamed request + health/status/metrics/trace probes over
    real HTTP. ``trace_out`` additionally writes the schema-validated
    Chrome trace to disk (the CI fast-lane artifact)."""
    import http.client
    from urllib.parse import urlparse

    from repro.runtime.observability import (parse_prometheus,
                                             validate_chrome_trace)

    u = urlparse(url)
    conn = http.client.HTTPConnection(u.hostname, u.port, timeout=60)
    conn.request("GET", "/health/ready")
    r = conn.getresponse()
    assert r.status == 200, f"/health/ready -> {r.status}"
    r.read()
    body = json.dumps({"prompt": [1, 2, 3, 4], "max_new_tokens": 8,
                       "stream": True})
    conn.request("POST", "/generate", body,
                 {"Content-Type": "application/json"})
    r = conn.getresponse()
    assert r.status == 200, f"/generate -> {r.status}"
    lines = [json.loads(ln) for ln in r.read().splitlines() if ln.strip()]
    toks = [ln["token"] for ln in lines if "token" in ln]
    final = lines[-1]
    assert final.get("done") and final["tokens"] == toks, \
        f"stream mismatch: {lines}"
    conn.request("GET", "/status")
    r = conn.getresponse()
    assert r.status == 200, f"/status -> {r.status}"
    st = json.loads(r.read())
    assert st["ready"] and "kv" in st and "counters" in st, st
    conn.request("GET", "/metrics")
    r = conn.getresponse()
    assert r.status == 200, f"/metrics -> {r.status}"
    metrics = parse_prometheus(r.read().decode())
    assert metrics["counters"]["repro_admissions_total"] \
        == st["counters"]["admissions"], (metrics["counters"], st["counters"])
    if st["observability"]:
        assert metrics["histograms"]["repro_ttft_seconds"]["count"] >= 1, \
            metrics["histograms"]
    conn.request("GET", "/trace")
    r = conn.getresponse()
    assert r.status == 200, f"/trace -> {r.status}"
    trace = json.loads(r.read())
    n_events = validate_chrome_trace(trace)
    if st["observability"]:
        assert n_events > 0, "observability on but the trace is empty"
    if trace_out:
        with open(trace_out, "w") as fh:
            json.dump(trace, fh)
    conn.close()
    print(f"smoke OK: {len(toks)} tokens streamed, "
          f"finish_reason={final['finish_reason']}, "
          f"admissions={st['counters']['admissions']}, "
          f"sheds={st['counters']['sheds']}, "
          f"trace_events={n_events}"
          + (f" -> {trace_out}" if trace_out else ""))


def main(argv=None) -> None:
    import argparse

    from repro.runtime.engine import EngineConfig

    ap = argparse.ArgumentParser(
        description="HTTP serving front end over the repro Engine")
    EngineConfig.add_cli_args(ap)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8800,
                    help="listen port (0 = ephemeral)")
    ap.add_argument("--max-len", type=int, default=128,
                    help="KV rows per slot (prompt + generation budget)")
    ap.add_argument("--max-inflight", type=int, default=32,
                    help="open-request bound before /generate returns 429")
    ap.add_argument("--tiny", action="store_true",
                    help="serve a tiny randomly-initialized demo model")
    ap.add_argument("--smoke", action="store_true",
                    help="start, stream one request, probe health/status/"
                         "metrics/trace, exit (CI liveness gate; implies "
                         "--observability so the probes are meaningful)")
    ap.add_argument("--trace-out", default=None,
                    help="with --smoke: write the schema-validated Chrome "
                         "trace JSON here")
    args = ap.parse_args(argv)
    if args.smoke:
        args.observability = True
    if not args.tiny:
        ap.error("only --tiny is wired up in this repro (checkpoint "
                 "loading for the real configs is a later PR)")
    if (args.policy or "fifo") == "batch":
        ap.error("--policy batch is the closed-batch executor; the server "
                 "needs a continuous policy (fifo | priority | edf)")
    engine = _build_tiny_engine(args)
    sc = ServerConfig(host=args.host,
                      port=0 if args.smoke else args.port,
                      max_inflight=args.max_inflight)
    with EngineServer(engine, sc) as srv:
        print(f"serving on {srv.url} "
              f"(policy={engine.admission.name}, "
              f"layout={engine.config.kv_layout})", flush=True)
        if args.smoke:
            _smoke(srv.url, trace_out=args.trace_out)
            return
        try:
            while True:
                time.sleep(3600)
        except KeyboardInterrupt:
            pass


if __name__ == "__main__":
    main()
