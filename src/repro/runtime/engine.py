"""Unified serving facade: one ``Engine``, policy-configured.

Every serving configuration — the seed static-bucket path, continuous
batching over dense KV slots, the paged block-pool cache, chunked
prefill, priority / deadline scheduling — is the same ``Engine`` class
under a different ``EngineConfig``. The config names *policies*
(``runtime.policies``) instead of modes:

* ``admission`` — who is served next: ``"fifo"`` | ``"priority"`` |
  ``"edf"`` (earliest deadline first) run through the continuous
  scheduler; ``"batch"`` is the seed static-bucket executor (closed
  batches grouped by prompt length, one compile per bucket);
* ``kv_layout`` — ``"slotted"`` (dense per-slot rows) | ``"paged"``
  (shared block pool, admission ``watermark``, growth preemption, and
  optional ``prefix_cache`` sharing of common prompt-prefix blocks
  between requests with copy-on-write);
* ``preemption`` — who loses their blocks under pool pressure:
  ``"evict-latest"`` | ``"lowest-priority"``;
* the ``Sampler`` owns the PRNG state (greedy / temperature / seed).

Under greedy sampling every configuration emits identical tokens — the
policies move *waiting time*, never content — so the whole matrix is
checked against the static path in tests.

``submit()`` returns a ``RequestHandle``: the full request lifecycle —
``cancel()``, a per-token callback (``on_token``), a pull-based token
iterator (``stream()``), and the final ``Completion`` with its
``finish_reason`` (``"eos" | "length" | "cancelled" | "failed"``).

The legacy ``ServeEngine(mode=..., paged=...)`` kwarg surface lives on
as a deprecation shim in ``runtime.serving``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.policies import (BatchAdmission, Sampler, make_admission,
                                    make_preemption)
from repro.runtime.scheduler import (Completion, ContinuousScheduler, Request,
                                     SchedulerConfig, SlotFailure,
                                     validate_request_fits)

__all__ = ["Engine", "EngineConfig", "RequestHandle"]

KV_LAYOUTS = ("slotted", "paged")


@dataclass
class EngineConfig:
    """Structured engine configuration. Field-by-field replacement for
    the legacy ``ServeEngine`` kwarg soup (see README migration table):
    ``mode="static-bucket"`` is ``admission="batch"``, ``paged=True`` is
    ``kv_layout="paged"``; everything else keeps its name."""

    max_slots: int = 8          # decode batch width (continuous policies)
    max_len: int = 512          # KV rows per slot
    # cache shape: "slotted" dense rows | "paged" shared block pool
    kv_layout: str = "slotted"
    block_size: int = 16        # KV rows per paged block
    num_blocks: int = 0         # 0 = slotted parity + reserved null block
    # paged admission watermark: keep this many blocks free beyond the
    # prompt's need when admitting, as growth headroom for running
    # requests (damps growth-preemption thrash under oversubscription)
    watermark: int = 0
    prefill_chunk: int = 0      # chunked prefill (0 = one-shot)
    # prefix sharing (paged only): admission matches new prompts against
    # resident block chains and maps shared blocks into the request's
    # table copy-on-write, skipping prefill for the matched region
    prefix_cache: bool = False
    # policies: names resolved via runtime.policies, or instances
    admission: Any = "fifo"     # "fifo" | "priority" | "edf" | "batch"
    preemption: Any = "evict-latest"    # | "lowest-priority"
    greedy: bool = True
    temperature: float = 1.0
    seed: int = 0
    debug: bool = False         # step-boundary invariant asserts


class RequestHandle:
    """The caller's end of one submitted request.

    * ``tokens`` — every token streamed so far. Under greedy sampling a
      failure re-queue re-decodes the identical prefix and the handle
      dedups by index, so the stream is a stable prefix of the final
      ``Completion.tokens``; under stochastic sampling a re-queue
      *restarts* the stream (the PRNG advanced, the prefix can't replay
      bit-identically), so streaming consumers there should prefer
      ``result().tokens``;
    * ``on_token(cb)`` — per-token callback, fired the moment a token is
      emitted, before the engine moves on;
    * ``stream()`` — pull iterator: yields tokens as they are produced,
      driving ``Engine.step()`` under the hood while the request lives;
    * ``cancel()`` — after it returns, not one more token is emitted;
      the request completes with ``finish_reason="cancelled"`` (queued
      requests complete immediately with no tokens);
    * ``result()`` — drive the engine until this request finishes and
      return its ``Completion``.
    """

    def __init__(self, engine: "Engine", request: Request):
        self.request = request
        self.tokens: List[int] = []
        self.completion: Optional[Completion] = None
        self._engine = engine
        self._callbacks: List[Callable[[int], None]] = []
        self._cancelled = False
        self._ticket = None         # continuous path only

    @property
    def done(self) -> bool:
        return self.completion is not None

    @property
    def finish_reason(self) -> Optional[str]:
        return self.completion.finish_reason if self.completion else None

    def cancel(self) -> None:
        """Flag the request for cancellation. Safe to call from inside a
        token callback (the flag is checked before every emission) and
        idempotent; a no-op once the request has completed."""
        if self.completion is not None:
            return
        self._cancelled = True
        if self._ticket is not None:
            self._engine.scheduler.request_cancel(self._ticket)

    def on_token(self, cb: Callable[[int], None]) -> Callable[[int], None]:
        """Register a per-token callback; returns it (decorator-friendly)."""
        self._callbacks.append(cb)
        return cb

    def stream(self) -> Iterator[int]:
        """Yield tokens as the engine produces them. Single-threaded
        pull: exhausting the iterator advances the engine step by step
        (serving every other in-flight request along the way) until this
        request finishes. Batch admission runs whole buckets per step, so
        there the iterator yields each bucket's tokens in bursts."""
        i = 0
        while True:
            while i < len(self.tokens):
                yield self.tokens[i]
                i += 1
            if self.completion is not None:
                return
            self._engine.step()

    def result(self) -> Completion:
        """Drive the engine until this request completes."""
        while self.completion is None:
            self._engine.step()
        return self.completion

    # -- engine-side hooks --------------------------------------------------

    def _emit(self, index: int, tok: int) -> None:
        if index < len(self.tokens):
            return              # failure-requeue replay of a streamed prefix
        self.tokens.append(tok)
        for cb in self._callbacks:
            cb(tok)

    def _restart(self) -> None:
        """Failure re-queue under stochastic sampling: the re-decode
        resamples, so the streamed prefix is void — token callbacks fire
        again from index 0 for the new attempt."""
        self.tokens = []

    def _complete(self, c: Completion) -> None:
        self.completion = c


class Engine:
    """Policy-based serving engine over one model + parameter set.

    ``submit()`` / ``step()`` / ``run()`` is the lifecycle API;
    ``generate()`` is the batch convenience wrapper (submit everything,
    drain, return completions sorted by id). With a continuous admission
    policy requests flow through the ``ContinuousScheduler``; with
    ``admission="batch"`` the engine runs the seed static-bucket
    executor — same facade, same handles, same ``finish_reason``."""

    def __init__(self, cfg: ModelConfig, params: Any,
                 config: Optional[EngineConfig] = None, *,
                 failures: Optional[List[SlotFailure]] = None):
        self.cfg = cfg
        self.params = params
        self.config = c = config or EngineConfig()
        if c.kv_layout not in KV_LAYOUTS:
            raise ValueError(f"kv_layout {c.kv_layout!r} not in {KV_LAYOUTS}")
        if c.prefix_cache and c.kv_layout != "paged":
            raise ValueError(
                "prefix_cache shares paged KV blocks between requests; "
                "it needs kv_layout='paged'")
        self.admission = make_admission(c.admission)
        self.preemption = make_preemption(c.preemption)
        self.batch_mode = isinstance(self.admission, BatchAdmission)
        self.max_len = c.max_len
        if self.batch_mode:
            if c.kv_layout != "slotted" or c.prefill_chunk:
                raise ValueError(
                    "batch admission runs the static-bucket executor; the "
                    "paged KV layout / chunked prefill need a continuous "
                    "admission policy (fifo | priority | edf)")
            if failures:
                raise ValueError(
                    "SlotFailure injection needs the continuous scheduler "
                    "(the static-bucket executor has no decode slots)")
            self.scheduler = None
            self.sampler = Sampler(greedy=c.greedy, temperature=c.temperature,
                                   seed=c.seed)
            max_len = c.max_len
            self._prefill = jax.jit(
                lambda p, b: T.prefill(p, cfg, b, max_len=max_len))
            self._decode = jax.jit(
                lambda p, tok, cache, clen: T.decode_step(p, cfg, tok, cache,
                                                          clen))
            self._pending: List[RequestHandle] = []
        else:
            self.scheduler = ContinuousScheduler(
                cfg, params, SchedulerConfig(
                    max_slots=c.max_slots, max_len=c.max_len, greedy=c.greedy,
                    temperature=c.temperature, seed=c.seed,
                    paged=c.kv_layout == "paged", block_size=c.block_size,
                    num_blocks=c.num_blocks, watermark=c.watermark,
                    prefill_chunk=c.prefill_chunk,
                    prefix_cache=c.prefix_cache, debug=c.debug),
                failures=failures, admission=self.admission,
                preemption=self.preemption)
            self.sampler = self.scheduler.sampler

    # -- lifecycle API ------------------------------------------------------

    def submit(self, req: Request, arrival_s: float = 0.0) -> RequestHandle:
        """Register a request (admitted at ``arrival_s`` seconds from
        drain start under continuous policies) and return its handle."""
        handle = RequestHandle(self, req)
        if self.batch_mode:
            if arrival_s:
                raise ValueError(
                    "batch admission serves closed batches — arrivals need "
                    "a continuous admission policy (fifo | priority | edf)")
            validate_request_fits(self.cfg, req, self.max_len)
            self._pending.append(handle)
        else:
            handle._ticket = self.scheduler.submit(req, arrival_s)
            handle._ticket.handle = handle
        return handle

    def step(self) -> List[Completion]:
        """Advance the engine: one scheduler iteration (continuous), or
        a full drain of the pending buckets (batch admission — buckets
        are closed, there is no smaller step). Returns the completions
        this step produced."""
        if self.batch_mode:
            return self._run_static(None)
        if self.scheduler.done:
            return []
        return self.scheduler.step_once()

    def run(self, on_completion: Optional[Callable[[Completion], None]] = None
            ) -> List[Completion]:
        """Drain every submitted request; completions sorted by id.
        ``on_completion`` streams each completion the moment its request
        finishes."""
        if self.batch_mode:
            return self._run_static(on_completion)
        return self.scheduler.run(on_completion)

    def generate(self, requests: List[Request], *,
                 arrivals: Optional[List[float]] = None,
                 on_completion: Optional[Callable[[Completion], None]] = None
                 ) -> List[Completion]:
        """Batch convenience: submit ``requests`` (each at its
        ``arrivals`` instant — an open-loop workload) and drain."""
        if arrivals is not None:
            if self.batch_mode:
                raise ValueError(
                    "arrivals require a continuous admission policy — "
                    "batch admission has no admission queue")
            if len(arrivals) != len(requests):
                raise ValueError(
                    f"arrivals has {len(arrivals)} entries for "
                    f"{len(requests)} requests")
        for i, r in enumerate(requests):
            self.submit(r, arrivals[i] if arrivals else 0.0)
        return self.run(on_completion)

    # -- introspection ------------------------------------------------------

    def kv_stats(self) -> Dict[str, float]:
        if self.scheduler is None:
            raise ValueError("kv_stats needs a continuous admission policy "
                             "(batch admission has no persistent KV cache)")
        return self.scheduler.kv_stats()

    def stats(self) -> Dict[str, int]:
        if self.scheduler is None:
            raise ValueError("stats needs a continuous admission policy")
        return self.scheduler.stats()

    # -- static-bucket executor (BatchAdmission) ----------------------------

    def _run_static(self, on_completion) -> List[Completion]:
        out: List[Completion] = []
        handles, self._pending = self._pending, []
        for h in [h for h in handles if h._cancelled]:
            c = Completion(h.request.id, h.tokens, 0.0, 0.0,
                           finish_reason="cancelled")
            h._complete(c)
            out.append(c)
        live = [h for h in handles if not h._cancelled]
        for _, hs in self.admission.buckets(
                live, prompt_of=lambda h: h.request.prompt):
            out.extend(self._run_bucket(hs))
        if on_completion is not None:
            for c in out:
                on_completion(c)
        return sorted(out, key=lambda c: c.id)

    def _run_bucket(self, handles: List[RequestHandle]) -> List[Completion]:
        """The seed static path, verbatim mechanics: one (batch, plen)
        prefill + decode compile, greedy decode to completion — plus the
        lifecycle hooks (per-token emit, cancellation flag checked before
        every emission, eos-vs-length finish reasons)."""
        reqs = [h.request for h in handles]
        b = len(reqs)
        batch = {"tokens": jnp.asarray(np.stack([r.prompt for r in reqs]))}
        if reqs[0].embeds is not None:
            batch["embeds"] = jnp.asarray(np.stack([r.embeds for r in reqs]))
        t0 = time.perf_counter()
        logits, cache, clen = jax.block_until_ready(
            self._prefill(self.params, batch))
        t1 = time.perf_counter()
        max_new = max(r.max_new_tokens for r in reqs)
        toks = self.sampler(logits)
        done = np.zeros(b, bool)
        reasons = ["length"] * b
        for i, t in enumerate(np.asarray(toks)):
            if handles[i]._cancelled:
                done[i] = True
                reasons[i] = "cancelled"
            else:
                handles[i]._emit(0, int(t))
        for _ in range(max_new - 1):
            if done.all():
                break
            logits, cache, clen = self._decode(self.params, toks, cache, clen)
            toks = self.sampler(logits)
            for i, t in enumerate(np.asarray(toks)):
                if done[i]:
                    continue
                r = reqs[i]
                if len(handles[i].tokens) >= r.max_new_tokens:
                    # budget already spent: a length stop regardless of
                    # what this step sampled or whether a late cancel()
                    # raced in — the continuous path evicts at this point
                    # without sampling, and the reasons must agree
                    done[i] = True
                    continue
                if handles[i]._cancelled:
                    done[i] = True
                    reasons[i] = "cancelled"
                    continue
                if r.eos is not None and t == r.eos:
                    done[i] = True
                    reasons[i] = "eos"
                else:
                    handles[i]._emit(len(handles[i].tokens), int(t))
        jax.block_until_ready(toks)
        t2 = time.perf_counter()
        out = []
        for i, h in enumerate(handles):
            reason = reasons[i]
            if not done[i] and h._cancelled \
                    and len(h.tokens) < reqs[i].max_new_tokens:
                reason = "cancelled"
            c = Completion(reqs[i].id, h.tokens, t1 - t0, t2 - t1,
                           finish_reason=reason)
            h._complete(c)
            out.append(c)
        return out
