"""Unified serving facade: one ``Engine``, policy-configured.

Every serving configuration — the seed static-bucket path, continuous
batching over dense KV slots, the paged block-pool cache, chunked
prefill, priority / deadline scheduling — is the same ``Engine`` class
under a different ``EngineConfig``. The config names *policies*
(``runtime.policies``) instead of modes:

* ``admission`` — who is served next: ``"fifo"`` | ``"priority"`` |
  ``"edf"`` (earliest deadline first) run through the continuous
  scheduler; ``"batch"`` is the seed static-bucket executor (closed
  batches grouped by prompt length, one compile per bucket);
* ``kv_layout`` — ``"slotted"`` (dense per-slot rows) | ``"paged"``
  (shared block pool, admission ``watermark``, growth preemption, and
  optional ``prefix_cache`` sharing of common prompt-prefix blocks
  between requests with copy-on-write);
* ``preemption`` — who loses their blocks under pool pressure:
  ``"evict-latest"`` | ``"lowest-priority"``;
* the ``Sampler`` owns the PRNG state (greedy / temperature / seed).

Under greedy sampling every configuration emits identical tokens — the
policies move *waiting time*, never content — so the whole matrix is
checked against the static path in tests.

``submit()`` returns a ``RequestHandle``: the full request lifecycle —
``cancel()``, a per-token callback (``on_token``), a pull-based token
iterator (``stream()``), and the final ``Completion`` with its
``finish_reason``
(``"eos" | "length" | "cancelled" | "failed" | "timeout"``).

The engine serves callers on two clocks:

* **caller-pumped** (the original surface): ``run()`` / ``step()`` /
  ``RequestHandle.stream()`` advance the scheduler from the calling
  thread — single-threaded, deterministic, what the benches and the
  conformance tests drive;
* **background-drained** (the wall-clock serving surface):
  ``start()`` spawns a drain thread that pumps the scheduler whenever
  work exists, so callers *never* step the engine themselves —
  ``submit()``/``cancel()`` are thread-safe (one engine lock serializes
  them against the drain loop), handles block on condition variables
  instead of pumping, submissions are stamped with their wall-clock
  arrival instant, and the ``asubmit()``/``astream()`` coroutines give
  asyncio servers the same surface without blocking the event loop.
  ``runtime.server`` builds the HTTP front end on exactly this mode.

The legacy ``ServeEngine(mode=..., paged=...)`` kwarg surface lives on
as a deprecation shim in ``runtime.serving``; the stable public import
path for all of the above is the ``repro.serving`` package.
"""
from __future__ import annotations

import asyncio
import collections
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass
from typing import (Any, AsyncIterator, Callable, Dict, Iterator, List,
                    Optional, Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.observability import Observability
from repro.runtime.policies import (BatchAdmission, Sampler, make_admission,
                                    make_preemption)
from repro.runtime.scheduler import (COUNTER_KEYS, Completion,
                                     ContinuousScheduler, Request,
                                     SchedulerConfig, SlotFailure,
                                     validate_request_fits)

__all__ = ["Engine", "EngineConfig", "RequestHandle"]

KV_LAYOUTS = ("slotted", "paged")


@dataclass
class EngineConfig:
    """Structured engine configuration. Field-by-field replacement for
    the legacy ``ServeEngine`` kwarg soup (see README migration table):
    ``mode="static-bucket"`` is ``admission="batch"``, ``paged=True`` is
    ``kv_layout="paged"``; everything else keeps its name."""

    max_slots: int = 8          # decode batch width (continuous policies)
    max_len: int = 512          # KV rows per slot
    # cache shape: "slotted" dense rows | "paged" shared block pool
    kv_layout: str = "slotted"
    block_size: int = 16        # KV rows per paged block
    num_blocks: int = 0         # 0 = slotted parity + reserved null block
    # paged admission watermark: keep this many blocks free beyond the
    # prompt's need when admitting, as growth headroom for running
    # requests (damps growth-preemption thrash under oversubscription)
    watermark: int = 0
    prefill_chunk: int = 0      # chunked prefill (0 = one-shot)
    # prefix sharing (paged only): admission matches new prompts against
    # resident block chains and maps shared blocks into the request's
    # table copy-on-write, skipping prefill for the matched region
    prefix_cache: bool = False
    # victim cache (requires prefix_cache): released refcount-1 prefix
    # blocks park in a reclaimable pool (K/V resident, index alive), so
    # cold admissions hit completed requests' chains across drain
    # epochs; evicted (victim_eviction order) under allocation pressure.
    victim_cache: bool = False
    victim_eviction: Any = "weighted-lru"   # | "lru" (policies registry)
    # per-tenant victim-pool byte budgets ({Request.tenant: bytes}); an
    # over-budget tenant evicts only its own chains. Tenant namespaces
    # isolate the prefix index whenever prefix_cache is on.
    prefix_cache_tenants: Optional[Dict[str, int]] = None
    # policies: names resolved via runtime.policies, or instances
    admission: Any = "fifo"     # "fifo" | "priority" | "edf" | "batch"
    preemption: Any = "evict-latest"    # | "lowest-priority"
    # wall-clock deadline enforcement: shed requests whose
    # arrival_s + deadline_s instant passes (finish_reason="timeout")
    # instead of only ordering by deadline (EDF). Continuous only.
    enforce_deadlines: bool = False
    # multi-unit execution core (continuous only): model the drain on
    # `units` per-unit clocks, `prefill_units` of them dedicated to
    # prompt prefill (0 = colocated) and the rest pipelining decode
    # across `decode_stages` stage-partitioned groups. Token content is
    # identical for every topology; only the modeled timeline moves.
    units: int = 1
    prefill_units: int = 0
    decode_stages: int = 1
    placement: Any = "round-robin"      # | "least-loaded" (prefill units)
    greedy: bool = True
    temperature: float = 1.0
    seed: int = 0
    # wall-clock device-speed handicap: sleep this long after every
    # non-idle scheduler step — emulates a slower device (e.g. the edge
    # endpoint tier of a TieredEngine when both tiers share one host).
    # Content-neutral: tokens are bit-identical at any value.
    step_delay_s: float = 0.0
    debug: bool = False         # step-boundary invariant asserts
    # metrics + lifecycle tracing (runtime.observability): histograms,
    # per-request spans, per-step phase breakdown, /trace export. Off by
    # default — the disabled hot path pays one `is None` test per hook.
    observability: bool = False

    # -- shared CLI construction (launch/serve.py, serving_bench.py,
    #    load_bench.py, runtime/server.py all register the same flags,
    #    so the policy surface can't drift between entry points) -------

    @staticmethod
    def add_cli_args(ap) -> None:
        """Register the engine-policy flags on an argparse parser."""
        ap.add_argument("--policy", default=None,
                        choices=("batch", "fifo", "priority", "edf"),
                        help="admission policy: 'batch' = static buckets "
                             "(closed batch, the seed path); fifo/priority/"
                             "edf stream through the continuous scheduler")
        ap.add_argument("--preemption", default="evict-latest",
                        choices=("evict-latest", "lowest-priority"),
                        help="paged-pool preemption victim policy")
        ap.add_argument("--slots", type=int, default=8,
                        help="decode batch width (continuous policies)")
        ap.add_argument("--paged", action="store_true",
                        help="paged KV cache: global-attn K/V in a shared "
                             "block pool with per-slot block tables")
        ap.add_argument("--prefix-cache", action="store_true",
                        help="share paged KV blocks between requests with a "
                             "common prompt prefix (copy-on-write; implies "
                             "--paged): matched prompts skip prefill for "
                             "the resident region")
        ap.add_argument("--victim-cache", action="store_true",
                        help="retain completed requests' prefix chains in "
                             "a reclaimable victim pool (implies "
                             "--prefix-cache); evicted weighted-LRU only "
                             "under allocation pressure")
        ap.add_argument("--block-size", type=int, default=16,
                        help="KV rows per paged block")
        ap.add_argument("--num-blocks", type=int, default=0,
                        help="paged pool size in blocks (0 = parity with "
                             "the slotted cache + the reserved null block)")
        ap.add_argument("--watermark", type=int, default=0,
                        help="paged admission watermark: keep this many "
                             "blocks free beyond the prompt's need when "
                             "admitting (growth headroom; damps preemption "
                             "thrash)")
        ap.add_argument("--prefill-chunk", type=int, default=0,
                        help="admit prompts this many tokens at a time, "
                             "interleaved with decode steps (0 = one-shot "
                             "prefill)")
        ap.add_argument("--units", type=int, default=1,
                        help="modeled processing units for the execution "
                             "core (1 = the classic single-unit timeline)")
        ap.add_argument("--prefill-units", type=int, default=0,
                        help="units dedicated to prompt prefill "
                             "(prefill/decode disaggregation; 0 = "
                             "colocated with decode)")
        ap.add_argument("--decode-stages", type=int, default=1,
                        help="decode pipeline stages across the decode "
                             "units (stage-partitioned decode step)")
        ap.add_argument("--placement", default="round-robin",
                        choices=("round-robin", "least-loaded"),
                        help="prefill-unit placement policy")
        ap.add_argument("--enforce-deadlines", action="store_true",
                        help="shed requests whose wall-clock deadline_s "
                             "passes (finish_reason='timeout') instead of "
                             "only ordering by deadline")
        ap.add_argument("--observability", action="store_true",
                        help="record lifecycle spans + latency histograms "
                             "(served at /metrics and /trace; exported by "
                             "the benches via --trace-out)")

    @classmethod
    def from_args(cls, args, **overrides) -> "EngineConfig":
        """Build an ``EngineConfig`` from ``add_cli_args`` flags.
        ``overrides`` (e.g. ``max_len=...``, or a forced ``admission``)
        win over the parsed flags."""
        victim = getattr(args, "victim_cache", False)
        prefix = args.prefix_cache or victim
        paged = args.paged or prefix
        kw = dict(
            max_slots=args.slots,
            kv_layout="paged" if paged else "slotted",
            block_size=args.block_size, num_blocks=args.num_blocks,
            watermark=args.watermark, prefill_chunk=args.prefill_chunk,
            prefix_cache=prefix, victim_cache=victim,
            admission=args.policy or "fifo", preemption=args.preemption,
            enforce_deadlines=args.enforce_deadlines,
            units=getattr(args, "units", 1),
            prefill_units=getattr(args, "prefill_units", 0),
            decode_stages=getattr(args, "decode_stages", 1),
            placement=getattr(args, "placement", "round-robin"),
            observability=getattr(args, "observability", False))
        kw.update(overrides)
        return cls(**kw)


class RequestHandle:
    """The caller's end of one submitted request.

    * ``tokens`` — every token streamed so far. Under greedy sampling a
      failure re-queue re-decodes the identical prefix and the handle
      dedups by index, so the stream is a stable prefix of the final
      ``Completion.tokens``; under stochastic sampling a re-queue
      *restarts* the stream (the PRNG advanced, the prefix can't replay
      bit-identically), so streaming consumers there should prefer
      ``result().tokens``;
    * ``on_token(cb)`` — per-token callback, fired the moment a token is
      emitted, before the engine moves on;
    * ``stream()`` — pull iterator: yields tokens as they are produced,
      driving ``Engine.step()`` under the hood while the request lives;
    * ``cancel()`` — after it returns, not one more token is emitted;
      the request completes with ``finish_reason="cancelled"`` (queued
      requests complete immediately with no tokens);
    * ``result()`` — block until this request finishes and return its
      ``Completion`` (caller-pumped engines are driven step by step;
      background-drained engines are waited on).

    With a background drain thread running (``Engine.start()``) every
    accessor is thread-safe: tokens/completion are published under a
    condition variable, ``stream()``/``result()`` wait instead of
    pumping, and ``aresult()``/``astream()`` expose the same waits as
    coroutines for asyncio callers.
    """

    def __init__(self, engine: "Engine", request: Request):
        self.request = request
        self.tokens: List[int] = []
        self.completion: Optional[Completion] = None
        self._engine = engine
        self._callbacks: List[Callable[[int], None]] = []
        self._cancelled = False
        self._ticket = None         # continuous path only
        self._cond = threading.Condition()
        self._done_evt = threading.Event()

    @property
    def done(self) -> bool:
        return self.completion is not None

    @property
    def finish_reason(self) -> Optional[str]:
        return self.completion.finish_reason if self.completion else None

    def cancel(self) -> None:
        """Flag the request for cancellation. Safe to call from inside a
        token callback (the flag is checked before every emission), from
        any thread while the engine drains in the background, and
        idempotent; a no-op once the request has completed."""
        if self.completion is not None:
            return
        self._cancelled = True
        if self._ticket is not None:
            with self._engine._entry_lock():
                self._engine.scheduler.request_cancel(self._ticket)

    def on_token(self, cb: Callable[[int], None]) -> Callable[[int], None]:
        """Register a per-token callback; returns it (decorator-friendly)."""
        self._callbacks.append(cb)
        return cb

    def _wait_progress(self, start: int, timeout: Optional[float] = None
                       ) -> Tuple[List[int], bool]:
        """Block until more than ``start`` tokens exist or the request
        completed; returns (tokens past ``start``, done). Against a
        background-drained engine this is a condition wait; against a
        caller-pumped one it advances the engine a step instead."""
        if self._engine.running:
            def ready():
                return len(self.tokens) > start or self.completion is not None
            with self._cond:
                if timeout is not None:
                    self._cond.wait_for(ready, timeout)
                else:
                    # bounded waits so a shutdown() mid-request degrades
                    # to caller-pumping on the next call, not a hang
                    while not ready() and self._engine.running:
                        self._cond.wait(0.1)
                return list(self.tokens[start:]), self.completion is not None
        if len(self.tokens) <= start and self.completion is None:
            self._engine.step()
        return list(self.tokens[start:]), self.completion is not None

    def stream(self) -> Iterator[int]:
        """Yield tokens as the engine produces them, until this request
        finishes. Caller-pumped engines are advanced step by step
        (serving every other in-flight request along the way); with a
        background drain thread the iterator just waits for tokens.
        Batch admission runs whole buckets per step, so there the
        iterator yields each bucket's tokens in bursts."""
        i = 0
        while True:
            toks, done = self._wait_progress(i)
            for t in toks:
                yield t
            i += len(toks)
            if done and i >= len(self.tokens):
                return

    def result(self, timeout: Optional[float] = None) -> Completion:
        """Block until this request completes and return its
        ``Completion`` (driving the engine if nothing else does).
        ``timeout`` (background mode) raises ``TimeoutError`` rather
        than waiting forever on a stopped engine."""
        if self._engine.running:
            if timeout is not None:
                if not self._done_evt.wait(timeout):
                    raise TimeoutError(
                        f"request {self.request.id} did not complete within "
                        f"{timeout}s")
                return self.completion
            while self._engine.running and not self._done_evt.wait(0.1):
                pass                # engine stopped mid-wait -> pump below
            if self.completion is not None:
                return self.completion
        while self.completion is None:
            self._engine.step()
        return self.completion

    async def aresult(self) -> Completion:
        """Asyncio variant of ``result()``: waits off the event loop."""
        return await asyncio.get_running_loop().run_in_executor(
            None, self.result)

    async def astream(self) -> AsyncIterator[int]:
        """Asyncio variant of ``stream()``: yields tokens as they are
        produced without blocking the event loop."""
        loop = asyncio.get_running_loop()
        i = 0
        while True:
            toks, done = await loop.run_in_executor(
                None, lambda: self._wait_progress(i, timeout=0.1))
            for t in toks:
                yield t
            i += len(toks)
            if done and i >= len(self.tokens):
                return

    # -- engine-side hooks --------------------------------------------------

    def _emit(self, index: int, tok: int) -> None:
        with self._cond:
            if index < len(self.tokens):
                return          # failure-requeue replay of a streamed prefix
            self.tokens.append(tok)
            self._cond.notify_all()
        for cb in self._callbacks:
            cb(tok)

    def _restart(self) -> None:
        """Failure re-queue under stochastic sampling: the re-decode
        resamples, so the streamed prefix is void — token callbacks fire
        again from index 0 for the new attempt."""
        with self._cond:
            self.tokens = []

    def _complete(self, c: Completion) -> None:
        with self._cond:
            self.completion = c
            self._cond.notify_all()
        self._done_evt.set()


class Engine:
    """Policy-based serving engine over one model + parameter set.

    ``submit()`` / ``step()`` / ``run()`` is the caller-pumped lifecycle
    API; ``generate()`` is the batch convenience wrapper (submit
    everything, drain, return completions sorted by id). With a
    continuous admission policy requests flow through the
    ``ContinuousScheduler``; with ``admission="batch"`` the engine runs
    the seed static-bucket executor — same facade, same handles, same
    ``finish_reason``.

    ``start()`` switches the engine to background-drained mode: a drain
    thread pumps the scheduler whenever work exists, ``submit()`` /
    ``cancel()`` become thread-safe (serialized by one engine lock) and
    stamp wall-clock arrival instants, and handles wait on condition
    variables instead of stepping. ``asubmit()``/``astream()`` wrap the
    same surface for asyncio callers. ``shutdown()`` (or exiting the
    engine's ``with`` block) stops the thread."""

    def __init__(self, cfg: ModelConfig, params: Any,
                 config: Optional[EngineConfig] = None, *,
                 failures: Optional[List[SlotFailure]] = None):
        self.cfg = cfg
        self.params = params
        self.config = c = config or EngineConfig()
        if c.kv_layout not in KV_LAYOUTS:
            raise ValueError(f"kv_layout {c.kv_layout!r} not in {KV_LAYOUTS}")
        if c.prefix_cache and c.kv_layout != "paged":
            raise ValueError(
                "prefix_cache shares paged KV blocks between requests; "
                "it needs kv_layout='paged'")
        if (c.victim_cache or c.prefix_cache_tenants) and not c.prefix_cache:
            raise ValueError(
                "victim_cache / prefix_cache_tenants extend the prefix "
                "cache; they need prefix_cache=True")
        self.admission = make_admission(c.admission)
        self.preemption = make_preemption(c.preemption)
        self.batch_mode = isinstance(self.admission, BatchAdmission)
        self.max_len = c.max_len
        # one lock serializes submit/cancel/step against the drain
        # thread; re-entrant so a cancel() fired from inside a token
        # callback (already under the lock, inside a step) doesn't
        # deadlock. Lock order is engine._lock -> handle._cond, never
        # the inverse: handles wait on _cond without the engine lock.
        self._lock = threading.RLock()
        # anti-convoy turnstile: the drain loop releases _lock between
        # steps but reacquires it immediately, and under the GIL a
        # submit/snapshot caller can lose that race for the length of
        # the whole backlog. Callers enter through _gate; the drain
        # loop passes through it (acquire+release) once per iteration,
        # so a waiter holding _gate is guaranteed the very next
        # critical section — worst-case wait is one scheduler step.
        self._gate = threading.Lock()
        # lock-free submission handoff: while the background drain runs,
        # submit() appends here (deque ops are atomic) and the drain
        # ingests at its next step boundary. A step can be long (an
        # admission burst of prefills, a fresh compile) and submit sits
        # on the caller's latency path — it must never wait one out.
        self._inbox: "collections.deque" = collections.deque()
        self._drain_thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._work = threading.Event()      # set on submit, wakes the drain
        # the registry/tracer pair always exists (so /metrics renders for
        # every policy); the scheduler only *records* into it when the
        # knob is on — counters are mirrored from stats() at snapshot
        # time either way, histograms/spans need enabled=True
        self.obs = Observability(enabled=c.observability)
        if self.batch_mode:
            if c.kv_layout != "slotted" or c.prefill_chunk:
                raise ValueError(
                    "batch admission runs the static-bucket executor; the "
                    "paged KV layout / chunked prefill need a continuous "
                    "admission policy (fifo | priority | edf)")
            if failures:
                raise ValueError(
                    "SlotFailure injection needs the continuous scheduler "
                    "(the static-bucket executor has no decode slots)")
            if c.enforce_deadlines:
                raise ValueError(
                    "enforce_deadlines sheds on a wall clock the "
                    "static-bucket executor doesn't run; it needs a "
                    "continuous admission policy (fifo | priority | edf)")
            if c.units != 1 or c.prefill_units or c.decode_stages != 1:
                raise ValueError(
                    "the multi-unit execution core charges the continuous "
                    "scheduler's steps; batch admission runs closed "
                    "buckets — use a continuous admission policy "
                    "(fifo | priority | edf)")
            self.scheduler = None
            self.sampler = Sampler(greedy=c.greedy, temperature=c.temperature,
                                   seed=c.seed)
            max_len = c.max_len
            self._prefill = jax.jit(
                lambda p, b: T.prefill(p, cfg, b, max_len=max_len))
            self._decode = jax.jit(
                lambda p, tok, cache, clen: T.decode_step(p, cfg, tok, cache,
                                                          clen))
            self._pending: List[RequestHandle] = []
        else:
            self.scheduler = ContinuousScheduler(
                cfg, params, SchedulerConfig(
                    max_slots=c.max_slots, max_len=c.max_len, greedy=c.greedy,
                    temperature=c.temperature, seed=c.seed,
                    paged=c.kv_layout == "paged", block_size=c.block_size,
                    num_blocks=c.num_blocks, watermark=c.watermark,
                    prefill_chunk=c.prefill_chunk,
                    prefix_cache=c.prefix_cache,
                    victim_cache=c.victim_cache,
                    victim_eviction=c.victim_eviction,
                    prefix_cache_tenants=c.prefix_cache_tenants,
                    enforce_deadlines=c.enforce_deadlines,
                    units=c.units, prefill_units=c.prefill_units,
                    decode_stages=c.decode_stages, placement=c.placement,
                    step_delay_s=c.step_delay_s, debug=c.debug),
                failures=failures, admission=self.admission,
                preemption=self.preemption,
                obs=self.obs if c.observability else None)
            self.sampler = self.scheduler.sampler

    # -- background drain ---------------------------------------------------

    @property
    def running(self) -> bool:
        """True while the background drain thread is alive."""
        t = self._drain_thread
        return t is not None and t.is_alive()

    def start(self) -> "Engine":
        """Spawn the background drain thread (continuous policies only).
        After this, callers never pump: ``submit()`` wakes the drain,
        handles wait for their tokens. Idempotent; returns self so
        ``with Engine(...).start() as eng:`` reads naturally."""
        if self.batch_mode:
            raise ValueError(
                "background draining steps the continuous scheduler; batch "
                "admission runs closed buckets — call run() instead")
        if self.running:
            return self
        self._stop.clear()
        self._drain_thread = threading.Thread(
            target=self._drain_loop, name="engine-drain", daemon=True)
        self._drain_thread.start()
        return self

    def shutdown(self, wait: bool = True) -> None:
        """Stop the drain thread. In-flight requests stay resident in
        the scheduler and resume on the next ``start()`` / ``run()``;
        ``wait=True`` joins the thread before returning."""
        self._stop.set()
        self._work.set()                    # unblock an idle drain loop
        t = self._drain_thread
        if wait and t is not None and t is not threading.current_thread():
            t.join()
        self._drain_thread = None

    def __enter__(self) -> "Engine":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    @contextmanager
    def _entry_lock(self):
        """Take the engine lock fairly: non-drain threads pass through
        the turnstile first, so the drain loop cannot starve them (see
        ``_gate``). The drain thread itself skips the gate — a cancel()
        or submit() fired from inside a token callback already holds
        ``_lock`` re-entrantly, and parking it on the gate while a
        caller waits for ``_lock`` would deadlock both."""
        if threading.current_thread() is self._drain_thread:
            with self._lock:
                yield
            return
        with self._gate:
            with self._lock:
                yield

    def _ingest_inbox(self) -> None:
        """Move handed-off submissions into the scheduler (caller holds
        ``_lock``). Stamps wall-clock arrivals from the *submit* instant
        — ingestion lag must not shift a request's arrival time — and
        honours a cancel() that raced the handoff."""
        while self._inbox:
            handle, req, arrival_s, t_sub = self._inbox.popleft()
            s = self.scheduler
            if not arrival_s and s._t0 is not None and not s.done:
                arrival_s = max(0.0, t_sub - s._t0)
            handle._ticket = s.submit(req, arrival_s)
            handle._ticket.handle = handle
            if handle._cancelled:
                s.request_cancel(handle._ticket)

    def _drain_loop(self) -> None:
        while not self._stop.is_set():
            # turnstile pass: if a snapshot/cancel caller is parked in
            # _entry_lock, block here until it has taken (and released)
            # the engine lock — fairness over throughput
            self._gate.acquire()
            self._gate.release()
            with self._lock:
                self._ingest_inbox()
                idle = self.scheduler.done
                if not idle:
                    self._work.clear()
                    self.scheduler.step_once()
            if idle:
                # nothing live: sleep until a submit wakes us (the
                # timeout keeps shutdown() prompt even if the set races)
                self._work.wait(timeout=0.05)
                self._work.clear()

    # -- lifecycle API ------------------------------------------------------

    def submit(self, req: Request, arrival_s: float = 0.0) -> RequestHandle:
        """Register a request (admitted at ``arrival_s`` seconds from
        drain start under continuous policies) and return its handle.
        Thread-safe; while the background drain runs, ``arrival_s=0``
        submissions are stamped with the wall-clock *now* on the
        scheduler's clock, so waiting-time metrics and deadlines measure
        real elapsed time, not time since the server booted."""
        handle = RequestHandle(self, req)
        if self.batch_mode:
            if arrival_s:
                raise ValueError(
                    "batch admission serves closed batches — arrivals need "
                    "a continuous admission policy (fifo | priority | edf)")
            validate_request_fits(self.cfg, req, self.max_len)
            self._pending.append(handle)
            return handle
        if self.running:
            # lock-free handoff: validate here (errors must surface on
            # the caller, not kill the drain thread), then hand the
            # request to the drain loop — submit never waits out a
            # scheduler step (admission bursts and fresh compiles can
            # hold the engine lock for a long time)
            validate_request_fits(self.cfg, req, self.max_len)
            self.scheduler.layout.validate(req)
            self._inbox.append((handle, req, arrival_s, time.perf_counter()))
            self._work.set()
            return handle
        with self._entry_lock():
            self._ingest_inbox()        # shutdown raced an earlier handoff
            handle._ticket = self.scheduler.submit(req, arrival_s)
            handle._ticket.handle = handle
        self._work.set()
        return handle

    def step(self) -> List[Completion]:
        """Advance the engine: one scheduler iteration (continuous), or
        a full drain of the pending buckets (batch admission — buckets
        are closed, there is no smaller step). Returns the completions
        this step produced. Not available while the background drain
        owns the scheduler — wait on handles instead."""
        if self.batch_mode:
            return self._run_static(None)
        if self.running and threading.current_thread() is not self._drain_thread:
            raise RuntimeError(
                "the background drain thread owns the step loop; wait on "
                "RequestHandle.result()/stream() or shutdown() first")
        with self._entry_lock():
            self._ingest_inbox()        # handoffs left by a shutdown()
            if self.scheduler.done:
                return []
            return self.scheduler.step_once()

    def run(self, on_completion: Optional[Callable[[Completion], None]] = None
            ) -> List[Completion]:
        """Drain every submitted request; completions sorted by id.
        ``on_completion`` streams each completion the moment its request
        finishes. Not available while the background drain runs."""
        if self.batch_mode:
            return self._run_static(on_completion)
        if self.running:
            raise RuntimeError(
                "the background drain thread owns the step loop; wait on "
                "RequestHandle.result()/stream() or shutdown() first")
        with self._lock:
            self._ingest_inbox()        # handoffs left by a shutdown()
        return self.scheduler.run(on_completion)

    # -- asyncio surface ----------------------------------------------------

    async def asubmit(self, req: Request) -> RequestHandle:
        """Asyncio submit: runs the (lock-taking, possibly briefly
        contended) submission off the event loop. Requires the
        background drain (``start()``) — an asyncio caller has no way
        to pump a caller-driven engine without blocking the loop."""
        if not self.running:
            raise RuntimeError("asubmit() needs the background drain "
                               "thread — call Engine.start() first")
        return await asyncio.get_running_loop().run_in_executor(
            None, self.submit, req)

    async def astream(self, req: Request) -> AsyncIterator[int]:
        """Submit + stream in one call: yields this request's tokens as
        they are produced, without blocking the event loop."""
        handle = await self.asubmit(req)
        async for tok in handle.astream():
            yield tok

    def generate(self, requests: List[Request], *,
                 arrivals: Optional[List[float]] = None,
                 on_completion: Optional[Callable[[Completion], None]] = None
                 ) -> List[Completion]:
        """Batch convenience: submit ``requests`` (each at its
        ``arrivals`` instant — an open-loop workload) and drain."""
        if arrivals is not None:
            if self.batch_mode:
                raise ValueError(
                    "arrivals require a continuous admission policy — "
                    "batch admission has no admission queue")
            if len(arrivals) != len(requests):
                raise ValueError(
                    f"arrivals has {len(arrivals)} entries for "
                    f"{len(requests)} requests")
        for i, r in enumerate(requests):
            self.submit(r, arrivals[i] if arrivals else 0.0)
        return self.run(on_completion)

    # -- introspection ------------------------------------------------------

    def kv_stats(self) -> Dict[str, float]:
        """Layout KV occupancy. Batch admission has no persistent cache,
        so it reports an empty (but typed) dict rather than raising —
        /status and /metrics must work for every policy."""
        if self.scheduler is None:
            return {}
        return self.scheduler.kv_stats()

    def stats(self) -> Dict[str, int]:
        """Lifecycle event counters. Batch admission reports all-zero
        counters (no continuous scheduler events) rather than raising."""
        if self.scheduler is None:
            return dict.fromkeys(COUNTER_KEYS, 0)
        return self.scheduler.stats()

    def snapshot(self) -> Dict[str, Any]:
        """One consistent view of the engine under its own lock: queue
        depth, active slots, KV occupancy, lifecycle counters, and (when
        observability is on) histogram summaries. The only sanctioned
        way for other threads — the HTTP server above all — to read
        engine state."""
        with self._entry_lock():
            if self.scheduler is None:
                snap: Dict[str, Any] = {
                    "queue_depth": len(self._pending),
                    "active_slots": 0,
                    "kv": {},
                    "counters": dict.fromkeys(COUNTER_KEYS, 0),
                }
            else:
                s = self.scheduler
                snap = {
                    "queue_depth": s._waiting() + len(self._inbox),
                    "active_slots": len(s.active),
                    "kv": s.kv_stats(),
                    "counters": s.stats(),
                    "units": s.unit_stats(),
                }
                if getattr(s.layout, "prefix_cache", False):
                    pc = s.layout.prefix_cache_stats()
                    pc["prefill_tokens_saved"] = s.prefill_tokens_saved
                    pc["bytes_saved"] = (s.prefill_tokens_saved
                                         * T.kv_row_bytes(self.cfg))
                    snap["prefix_cache"] = pc
        snap["observability"] = self.config.observability
        snap["metrics"] = self.obs.snapshot()
        return snap

    # -- prefix-cache persistence (victim cache across restarts) ------------

    def save_prefix_cache(self, path: str) -> int:
        """Serialize the resident prefix index + victim pool to a
        ``runtime.checkpoint`` artifact (see scheduler.prefix_pool for
        the chain format). Returns the number of chains saved."""
        from repro.runtime.scheduler.prefix_pool import save_victim_cache
        with self._entry_lock():
            return save_victim_cache(path, self._cache_layout(), self.cfg)

    def restore_prefix_cache(self, path: str) -> int:
        """Load a ``save_prefix_cache`` artifact into this engine's
        pool and victim cache (tenants, LRU stamps, hit counts): a
        restarted engine starts warm. Returns blocks restored."""
        from repro.runtime.scheduler.prefix_pool import restore_victim_cache
        with self._entry_lock():
            return restore_victim_cache(path, self._cache_layout(), self.cfg)

    def _cache_layout(self):
        if self.scheduler is None:
            raise ValueError("prefix-cache persistence needs a "
                             "continuous scheduler (admission != 'batch')")
        return self.scheduler.layout

    def metrics_text(self,
                     extra_gauges: Optional[Dict[str, float]] = None) -> str:
        """Prometheus text exposition. Counters are mirrored from the
        scheduler's event log into the registry here (monotone ``sync``,
        so it composes with live increments), gauges are stamped with
        the snapshot values, and whatever histograms the scheduler
        recorded ride along."""
        snap = self.snapshot()
        reg = self.obs.registry
        for k, v in snap["counters"].items():
            name = f"repro_{k}" if k.endswith("_total") else f"repro_{k}_total"
            reg.counter(name, help=f"engine lifecycle counter: {k}").sync(v)
        reg.gauge("repro_queue_depth",
                  help="requests waiting for a slot").set(snap["queue_depth"])
        reg.gauge("repro_active_slots",
                  help="slots decoding right now").set(snap["active_slots"])
        for k, v in snap["kv"].items():
            reg.gauge(f"repro_{k}", help="KV layout stat").set(v)
        for k, v in (extra_gauges or {}).items():
            reg.gauge(k).set(v)
        return reg.render()

    def trace_json(self) -> Dict[str, Any]:
        """Chrome trace-event snapshot (empty but valid when
        observability is off)."""
        return self.obs.tracer.chrome_trace()

    # -- static-bucket executor (BatchAdmission) ----------------------------

    def _run_static(self, on_completion) -> List[Completion]:
        out: List[Completion] = []
        handles, self._pending = self._pending, []
        for h in [h for h in handles if h._cancelled]:
            c = Completion(h.request.id, h.tokens, 0.0, 0.0,
                           finish_reason="cancelled")
            h._complete(c)
            out.append(c)
        live = [h for h in handles if not h._cancelled]
        for _, hs in self.admission.buckets(
                live, prompt_of=lambda h: h.request.prompt):
            out.extend(self._run_bucket(hs))
        if on_completion is not None:
            for c in out:
                on_completion(c)
        return sorted(out, key=lambda c: c.id)

    def _run_bucket(self, handles: List[RequestHandle]) -> List[Completion]:
        """The seed static path, verbatim mechanics: one (batch, plen)
        prefill + decode compile, greedy decode to completion — plus the
        lifecycle hooks (per-token emit, cancellation flag checked before
        every emission, eos-vs-length finish reasons)."""
        reqs = [h.request for h in handles]
        b = len(reqs)
        batch = {"tokens": jnp.asarray(np.stack([r.prompt for r in reqs]))}
        if reqs[0].embeds is not None:
            batch["embeds"] = jnp.asarray(np.stack([r.embeds for r in reqs]))
        t0 = time.perf_counter()
        logits, cache, clen = jax.block_until_ready(
            self._prefill(self.params, batch))
        t1 = time.perf_counter()
        max_new = max(r.max_new_tokens for r in reqs)
        toks = self.sampler(logits)
        done = np.zeros(b, bool)
        reasons = ["length"] * b
        for i, t in enumerate(np.asarray(toks)):
            if handles[i]._cancelled:
                done[i] = True
                reasons[i] = "cancelled"
            else:
                handles[i]._emit(0, int(t))
        for _ in range(max_new - 1):
            if done.all():
                break
            logits, cache, clen = self._decode(self.params, toks, cache, clen)
            toks = self.sampler(logits)
            for i, t in enumerate(np.asarray(toks)):
                if done[i]:
                    continue
                r = reqs[i]
                if len(handles[i].tokens) >= r.max_new_tokens:
                    # budget already spent: a length stop regardless of
                    # what this step sampled or whether a late cancel()
                    # raced in — the continuous path evicts at this point
                    # without sampling, and the reasons must agree
                    done[i] = True
                    continue
                if handles[i]._cancelled:
                    done[i] = True
                    reasons[i] = "cancelled"
                    continue
                if r.eos is not None and t == r.eos:
                    done[i] = True
                    reasons[i] = "eos"
                else:
                    handles[i]._emit(len(handles[i].tokens), int(t))
        jax.block_until_ready(toks)
        t2 = time.perf_counter()
        out = []
        for i, h in enumerate(handles):
            reason = reasons[i]
            if not done[i] and h._cancelled \
                    and len(h.tokens) < reqs[i].max_new_tokens:
                reason = "cancelled"
            c = Completion(reqs[i].id, h.tokens, t1 - t0, t2 - t1,
                           finish_reason=reason)
            h._complete(c)
            out.append(c)
        return out
