"""Legacy serving surface: the deprecated ``ServeEngine`` shim plus the
Edge-PRUNE partitioned engine.

The serving API moved to ``repro.runtime.engine.Engine``: one facade
configured by a structured ``EngineConfig`` naming pluggable policies
(admission order, KV layout, preemption, sampler) instead of a
``mode=...`` kwarg soup. ``ServeEngine`` remains as a thin deprecation
shim so existing call sites keep working unchanged:

* ``ServeEngine(mode="static-bucket")`` → ``EngineConfig(admission="batch")``
* ``ServeEngine(mode="continuous")``    → ``EngineConfig(admission="fifo")``
* ``ServeEngine(paged=True, ...)``      → ``EngineConfig(kv_layout="paged")``
* ``prefill_chunk`` / ``max_slots`` / sampling kwargs keep their names.

The shim reproduces the legacy mode-conditional ``ValueError``s (so
callers relying on them see identical behavior) and emits a
``DeprecationWarning`` on construction. It will be removed once the
examples and benches have no remaining legacy call sites.

``PartitionedServeEngine`` — the paper's collaborative-inference path
(prefill through a synthesized StagedProgram) — is not deprecated; it
lives here unchanged.
"""
from __future__ import annotations

import warnings
from typing import Any, List, Optional

import jax
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.scheduler import (Completion, Request, SlotFailure,
                                     sample_tokens)

__all__ = ["Request", "Completion", "ServeEngine", "PartitionedServeEngine",
           "SlotFailure", "Engine", "EngineConfig"]

MODES = ("static-bucket", "continuous")


class ServeEngine:
    """Deprecated: the pre-policy engine facade. Construct an
    ``Engine`` with an ``EngineConfig`` instead (see module docstring
    for the kwarg mapping). The shim keeps byte-for-byte output parity:
    it builds the same Engine the new API would."""

    def __init__(self, cfg: ModelConfig, params: Any, *,
                 max_len: int = 512, greedy: bool = True,
                 temperature: float = 1.0, seed: int = 0,
                 mode: str = "static-bucket", max_slots: int = 8,
                 paged: bool = False, block_size: int = 16,
                 num_blocks: int = 0, prefill_chunk: int = 0,
                 watermark: int = 0):
        warnings.warn(
            "ServeEngine is deprecated; use repro.runtime.engine.Engine "
            "with EngineConfig (mode='static-bucket' -> admission='batch', "
            "mode='continuous' -> admission='fifo', paged=True -> "
            "kv_layout='paged'). See README 'Serving architecture'.",
            DeprecationWarning, stacklevel=2)
        if mode not in MODES:
            raise ValueError(f"mode {mode!r} not in {MODES}")
        if mode != "continuous" and (paged or prefill_chunk):
            raise ValueError("paged KV cache / chunked prefill require "
                             "mode='continuous'")
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.greedy = greedy
        self.temperature = temperature
        self.mode = mode
        self.engine = Engine(cfg, params, EngineConfig(
            max_slots=max_slots, max_len=max_len, greedy=greedy,
            temperature=temperature, seed=seed,
            kv_layout="paged" if paged else "slotted",
            block_size=block_size, num_blocks=num_blocks,
            watermark=watermark, prefill_chunk=prefill_chunk,
            admission="batch" if mode == "static-bucket" else "fifo"))
        self.scheduler = self.engine.scheduler

    def generate(self, requests: List[Request], *,
                 arrivals: Optional[List[float]] = None,
                 on_completion=None) -> List[Completion]:
        """Serve ``requests`` to completion (legacy signature; delegates
        to ``Engine.generate``). The legacy mode-conditional errors are
        preserved verbatim."""
        if self.mode != "continuous":
            if arrivals is not None:
                raise ValueError("arrivals requires mode='continuous' — the "
                                 "static-bucket path has no admission queue")
            if on_completion is not None:
                raise ValueError("on_completion requires mode='continuous' — "
                                 "the static path completes buckets, not a "
                                 "stream")
            return self.engine.generate(requests)
        return self.engine.generate(requests, arrivals=arrivals,
                                    on_completion=on_completion)


# ---------------------------------------------------------------------------
# Edge-PRUNE collaborative-inference serving (the paper's technique)
# ---------------------------------------------------------------------------

class PartitionedServeEngine:
    """Serves prefill through a VR-PRUNE StagedProgram: the model's actor
    graph split by a mapping (endpoint/server or pod0/pod1), TX/RX channels
    auto-inserted at the boundary — Edge-PRUNE Sec III.B applied to LLMs.

    A unit may appear in several pipeline segments (endpoint → server →
    endpoint offload mappings): ``synthesize`` opens a new stage per
    revisit, ``run_pipelined`` keys its clocks by *physical* unit so the
    revisits contend for it, and ``comm_bytes`` counts only channels
    that actually cross units."""

    def __init__(self, cfg: ModelConfig, params: Any, mapping, *,
                 batch: int = 1, seq: int = 8, group_size: int = 1):
        from repro.core.synthesis import synthesize
        self.cfg = cfg
        self.graph = T.to_actor_graph(cfg, params, batch=batch, seq=seq,
                                      group_size=group_size)
        self.program = synthesize(self.graph, mapping)

    def infer(self, tokens: np.ndarray) -> jax.Array:
        sinks = self.program.run_local({"Input": jax.numpy.asarray(tokens)})
        return sinks["Head"]

    def infer_pipelined(self, token_frames: List[np.ndarray], *,
                        platform=None, arrivals: Optional[List[float]] = None):
        """Serve a stream of frames through the staged pipeline: stage k
        of frame i overlaps stage k-1 of frame i+1 on the modeled
        per-unit clocks. Returns (logits per frame, PipelineSchedule)."""
        frames = [{"Input": jax.numpy.asarray(t)} for t in token_frames]
        sinks, sched = self.program.run_pipelined(frames, platform=platform,
                                                  arrivals=arrivals)
        return [s["Head"] for s in sinks], sched

    def comm_bytes(self) -> int:
        return self.program.comm_bytes_per_iteration()
