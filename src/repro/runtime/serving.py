"""Batched serving engine: prefill + decode loop over a request batch.

The engine compiles two functions per (batch, prompt_len) bucket —
``prefill`` and ``decode_step`` — and greedily decodes until every
request hits its max_new_tokens or emits ``eos``. Requests are grouped
into same-length buckets (left-truncation to the bucket length); this is
the standard static-bucket serving pattern and is exactly what the
decode_32k / long_500k dry-run shapes lower.

The engine also demonstrates the Edge-PRUNE integration: a ``ServeEngine``
can be constructed over a *partitioned* model (an actor graph + mapping),
in which case prefill executes stage-by-stage through the synthesized
StagedProgram — the collaborative-inference path of the paper.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig


@dataclass
class Request:
    id: int
    prompt: np.ndarray                      # (S,) int32
    max_new_tokens: int = 16
    eos: Optional[int] = None
    embeds: Optional[np.ndarray] = None     # VLM/audio frontend output


@dataclass
class Completion:
    id: int
    tokens: List[int]
    prefill_s: float
    decode_s: float


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: Any, *,
                 max_len: int = 512, greedy: bool = True,
                 temperature: float = 1.0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.greedy = greedy
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(
            lambda p, b: T.prefill(p, cfg, b, max_len=max_len))
        self._decode = jax.jit(
            lambda p, tok, cache, clen: T.decode_step(p, cfg, tok, cache, clen))

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(
            sub, logits / self.temperature, axis=-1).astype(jnp.int32)

    def generate(self, requests: List[Request]) -> List[Completion]:
        out: List[Completion] = []
        # bucket by prompt length
        buckets: Dict[int, List[Request]] = {}
        for r in requests:
            buckets.setdefault(len(r.prompt), []).append(r)
        for plen, reqs in sorted(buckets.items()):
            out.extend(self._run_bucket(plen, reqs))
        return sorted(out, key=lambda c: c.id)

    def _run_bucket(self, plen: int, reqs: List[Request]) -> List[Completion]:
        b = len(reqs)
        batch = {"tokens": jnp.asarray(np.stack([r.prompt for r in reqs]))}
        if reqs[0].embeds is not None:
            batch["embeds"] = jnp.asarray(np.stack([r.embeds for r in reqs]))
        t0 = time.perf_counter()
        logits, cache, clen = jax.block_until_ready(
            self._prefill(self.params, batch))
        t1 = time.perf_counter()
        max_new = max(r.max_new_tokens for r in reqs)
        toks = self._sample(logits)
        emitted = [[int(t)] for t in np.asarray(toks)]
        done = np.zeros(b, bool)
        for _ in range(max_new - 1):
            logits, cache, clen = self._decode(self.params, toks, cache, clen)
            toks = self._sample(logits)
            for i, t in enumerate(np.asarray(toks)):
                if not done[i]:
                    if reqs[i].eos is not None and t == reqs[i].eos:
                        done[i] = True
                    elif len(emitted[i]) < reqs[i].max_new_tokens:
                        emitted[i].append(int(t))
                    else:
                        done[i] = True
            if done.all():
                break
        jax.block_until_ready(toks)
        t2 = time.perf_counter()
        return [Completion(r.id, emitted[i], t1 - t0, t2 - t1)
                for i, r in enumerate(reqs)]


# ---------------------------------------------------------------------------
# Edge-PRUNE collaborative-inference serving (the paper's technique)
# ---------------------------------------------------------------------------

class PartitionedServeEngine:
    """Serves prefill through a VR-PRUNE StagedProgram: the model's actor
    graph split by a mapping (endpoint/server or pod0/pod1), TX/RX channels
    auto-inserted at the boundary — Edge-PRUNE Sec III.B applied to LLMs."""

    def __init__(self, cfg: ModelConfig, params: Any, mapping, *,
                 batch: int = 1, seq: int = 8, group_size: int = 1):
        from repro.core.synthesis import synthesize
        self.cfg = cfg
        self.graph = T.to_actor_graph(cfg, params, batch=batch, seq=seq,
                                      group_size=group_size)
        self.program = synthesize(self.graph, mapping)

    def infer(self, tokens: np.ndarray) -> jax.Array:
        sinks = self.program.run_local({"Input": jnp.asarray(tokens)})
        return sinks["Head"]

    def comm_bytes(self) -> int:
        return self.program.comm_bytes_per_iteration()
