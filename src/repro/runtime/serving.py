"""Batched serving engine: static-bucket and continuous-batching modes.

``mode="static-bucket"`` (the seed path) compiles two functions per
(batch, prompt_len) bucket — ``prefill`` and ``decode_step`` — and
greedily decodes each bucket until every request hits its max_new_tokens
or emits ``eos``. Kept as the baseline: it is exactly what the
decode_32k / long_500k dry-run shapes lower, but every new bucket shape
recompiles and short requests wait for the longest in their bucket.

``mode="continuous"`` delegates to ``runtime.scheduler.
ContinuousScheduler``: one decode function compiled once at a fixed slot
count, slot-based KV cache reuse, and per-step admission/eviction —
requests join and leave the running batch between decode steps. Under
greedy sampling both modes emit identical tokens. ``paged=True`` swaps
the dense per-slot KV rows for the block-pool layout (``block_size`` /
``num_blocks``), and ``prefill_chunk=C`` admits prompts C tokens at a
time interleaved with decode steps — both still token-identical.

The engine also demonstrates the Edge-PRUNE integration: a ``ServeEngine``
can be constructed over a *partitioned* model (an actor graph + mapping),
in which case prefill executes stage-by-stage through the synthesized
StagedProgram — the collaborative-inference path of the paper.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.scheduler import (Completion, ContinuousScheduler, Request,
                                     SchedulerConfig, SlotFailure,
                                     sample_tokens, validate_request_fits)

__all__ = ["Request", "Completion", "ServeEngine", "PartitionedServeEngine",
           "SlotFailure"]

MODES = ("static-bucket", "continuous")


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: Any, *,
                 max_len: int = 512, greedy: bool = True,
                 temperature: float = 1.0, seed: int = 0,
                 mode: str = "static-bucket", max_slots: int = 8,
                 paged: bool = False, block_size: int = 16,
                 num_blocks: int = 0, prefill_chunk: int = 0):
        if mode not in MODES:
            raise ValueError(f"mode {mode!r} not in {MODES}")
        if mode != "continuous" and (paged or prefill_chunk):
            raise ValueError("paged KV cache / chunked prefill require "
                             "mode='continuous'")
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.greedy = greedy
        self.temperature = temperature
        self.mode = mode
        if mode == "continuous":
            # sampling state lives in the scheduler; keeping a second key
            # here would be a dead config path
            self.scheduler = ContinuousScheduler(
                cfg, params, SchedulerConfig(
                    max_slots=max_slots, max_len=max_len, greedy=greedy,
                    temperature=temperature, seed=seed, paged=paged,
                    block_size=block_size, num_blocks=num_blocks,
                    prefill_chunk=prefill_chunk))
        else:
            self.scheduler = None
            self.key = jax.random.PRNGKey(seed)
            self._prefill = jax.jit(
                lambda p, b: T.prefill(p, cfg, b, max_len=max_len))
            self._decode = jax.jit(
                lambda p, tok, cache, clen: T.decode_step(p, cfg, tok, cache,
                                                          clen))

    def _sample(self, logits: jax.Array) -> jax.Array:
        toks, self.key = sample_tokens(self.key, logits, greedy=self.greedy,
                                       temperature=self.temperature)
        return toks

    def generate(self, requests: List[Request], *,
                 arrivals: Optional[List[float]] = None,
                 on_completion=None) -> List[Completion]:
        """Serve ``requests`` to completion. ``arrivals`` (seconds from
        call time, continuous mode only) submits each request to the
        admission queue at its arrival instant — an open-loop workload;
        the static path serves everything as one closed batch.
        ``on_completion`` (continuous only) streams each completion the
        moment its request finishes."""
        if self.mode == "continuous":
            if arrivals is not None and len(arrivals) != len(requests):
                raise ValueError(
                    f"arrivals has {len(arrivals)} entries for "
                    f"{len(requests)} requests")
            for i, r in enumerate(requests):
                self.scheduler.submit(r, arrivals[i] if arrivals else 0.0)
            return self.scheduler.run(on_completion)
        if arrivals is not None:
            raise ValueError("arrivals requires mode='continuous' — the "
                             "static-bucket path has no admission queue")
        if on_completion is not None:
            raise ValueError("on_completion requires mode='continuous' — "
                             "the static path completes buckets, not a "
                             "stream")
        for r in requests:
            validate_request_fits(self.cfg, r, self.max_len)
        out: List[Completion] = []
        # bucket by prompt length
        buckets: Dict[int, List[Request]] = {}
        for r in requests:
            buckets.setdefault(len(r.prompt), []).append(r)
        for plen, reqs in sorted(buckets.items()):
            out.extend(self._run_bucket(plen, reqs))
        return sorted(out, key=lambda c: c.id)

    def _run_bucket(self, plen: int, reqs: List[Request]) -> List[Completion]:
        b = len(reqs)
        batch = {"tokens": jnp.asarray(np.stack([r.prompt for r in reqs]))}
        if reqs[0].embeds is not None:
            batch["embeds"] = jnp.asarray(np.stack([r.embeds for r in reqs]))
        t0 = time.perf_counter()
        logits, cache, clen = jax.block_until_ready(
            self._prefill(self.params, batch))
        t1 = time.perf_counter()
        max_new = max(r.max_new_tokens for r in reqs)
        toks = self._sample(logits)
        emitted = [[int(t)] for t in np.asarray(toks)]
        done = np.zeros(b, bool)
        for _ in range(max_new - 1):
            logits, cache, clen = self._decode(self.params, toks, cache, clen)
            toks = self._sample(logits)
            for i, t in enumerate(np.asarray(toks)):
                if not done[i]:
                    if reqs[i].eos is not None and t == reqs[i].eos:
                        done[i] = True
                    elif len(emitted[i]) < reqs[i].max_new_tokens:
                        emitted[i].append(int(t))
                    else:
                        done[i] = True
            if done.all():
                break
        jax.block_until_ready(toks)
        t2 = time.perf_counter()
        return [Completion(r.id, emitted[i], t1 - t0, t2 - t1)
                for i, r in enumerate(reqs)]


# ---------------------------------------------------------------------------
# Edge-PRUNE collaborative-inference serving (the paper's technique)
# ---------------------------------------------------------------------------

class PartitionedServeEngine:
    """Serves prefill through a VR-PRUNE StagedProgram: the model's actor
    graph split by a mapping (endpoint/server or pod0/pod1), TX/RX channels
    auto-inserted at the boundary — Edge-PRUNE Sec III.B applied to LLMs."""

    def __init__(self, cfg: ModelConfig, params: Any, mapping, *,
                 batch: int = 1, seq: int = 8, group_size: int = 1):
        from repro.core.synthesis import synthesize
        self.cfg = cfg
        self.graph = T.to_actor_graph(cfg, params, batch=batch, seq=seq,
                                      group_size=group_size)
        self.program = synthesize(self.graph, mapping)

    def infer(self, tokens: np.ndarray) -> jax.Array:
        sinks = self.program.run_local({"Input": jnp.asarray(tokens)})
        return sinks["Head"]

    def infer_pipelined(self, token_frames: List[np.ndarray], *,
                        platform=None, arrivals: Optional[List[float]] = None):
        """Serve a stream of frames through the staged pipeline: stage k
        of frame i overlaps stage k-1 of frame i+1 on the modeled
        per-unit clocks. Returns (logits per frame, PipelineSchedule)."""
        frames = [{"Input": jnp.asarray(t)} for t in token_frames]
        sinks, sched = self.program.run_pipelined(frames, platform=platform,
                                                  arrivals=arrivals)
        return [s["Head"] for s in sinks], sched

    def comm_bytes(self) -> int:
        return self.program.comm_bytes_per_iteration()
