"""Resilience subsystem: failure injection, heartbeat failover, re-mapping.

The fault-tolerant Edge-PRUNE follow-up (arXiv 2206.08152) builds on the
framework's central property — the application graph never changes across
distributed scenarios, only the *mapping file* does (Sec III.B-C). When a
processing unit or link dies mid-inference, the runtime therefore does not
need to re-plan the application: it switches to an alternative mapping of
the same graph onto the surviving units and keeps serving.

This module provides the three pieces of that story:

* **Failure model** — ``FailureEvent`` / ``FailureTrace`` describe kills
  and revivals of ``ProcessingUnit``s and ``Link``s at modeled
  timestamps. The token-accurate ``Simulator`` consumes a trace directly
  (``Simulator.run(..., failures=trace)``): firings on a dead unit are
  delayed to its revival (or blocked forever), tokens that land at — or
  sit buffered on — a dead unit are lost, and lost frames are re-fired
  from the last consistent frame boundary. ``FailureInjector`` is the
  stateful runtime-side consumer that delivers events as modeled time
  advances (used by the failover controller and available to schedulers).
* **Detection** — ``HeartbeatMonitor`` models the liveness protocol: every
  unit beats every ``interval_s``; a unit whose beat has been missing for
  ``timeout_s`` (measured from its last successful beat) is declared dead.
  Detection latency is therefore part of every recovery-latency figure.
* **Failover controller** — ``FailoverController`` serves a stream of
  frames through a synthesized ``StagedProgram`` with per-frame ack
  points, holding at most ``checkpoint_frames`` unacknowledged frames in
  a bounded FIFO ``CheckpointBuffer``. On a detected failure it selects
  the first viable mapping from a ranked fallback list (precomputed via
  ``Explorer.rank_fallbacks`` or supplied), re-synthesizes the staged
  program on the surviving units, replays the unacknowledged frames, and
  records recovery latency. Because stage functions are pure and the
  graph is mapping-invariant, every served frame's output is bit-identical
  to the failure-free run regardless of which mapping produced it.
"""
from __future__ import annotations

import math
import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import (Any, Dict, FrozenSet, Iterable, List, Optional, Sequence,
                    Tuple)

from repro.core.graph import Graph
from repro.core.mapping import Mapping, PlatformModel
from repro.core.synthesis import StagedProgram, synthesize
from repro.runtime.observability import (TIME_BUCKETS_S, Observability,
                                         failover_trace)

__all__ = [
    "FailureEvent", "FailureTrace", "FailureInjector", "HeartbeatConfig",
    "HeartbeatMonitor", "CheckpointBuffer", "FailoverEvent",
    "FailoverReport", "FailoverController", "NoViableMappingError",
]


# ---------------------------------------------------------------------------
# Failure model
# ---------------------------------------------------------------------------

UNIT = "unit"
LINK = "link"
KILL = "kill"
REVIVE = "revive"


@dataclass(frozen=True)
class FailureEvent:
    """One modeled fault-domain transition.

    ``kind`` is ``"unit"`` or ``"link"``; ``target`` is the unit name or
    the frozenset of the link's two endpoint unit names; ``action`` is
    ``"kill"`` or ``"revive"``.
    """

    t_s: float
    kind: str
    target: Any
    action: str


def _link_key(a: str, b: str) -> FrozenSet[str]:
    return frozenset((a, b))


class FailureTrace:
    """An ordered script of kill/revive events, queryable by modeled time.

    The trace is the *ground truth* the injector and the simulator consume;
    detection (heartbeats) is layered on top, so a component is physically
    dead from its kill instant even though the controller only learns of it
    ``HeartbeatMonitor.detect_time`` later.
    """

    def __init__(self, events: Iterable[FailureEvent] = ()):
        self.events: List[FailureEvent] = sorted(events, key=lambda e: e.t_s)

    # -- builders -----------------------------------------------------------

    def _add(self, t_s: float, kind: str, target: Any,
             action: str) -> "FailureTrace":
        if t_s < 0:
            raise ValueError(f"failure event at negative time {t_s}")
        self.events.append(FailureEvent(t_s, kind, target, action))
        self.events.sort(key=lambda e: e.t_s)
        return self

    def kill_unit(self, unit: str, at: float) -> "FailureTrace":
        return self._add(at, UNIT, unit, KILL)

    def revive_unit(self, unit: str, at: float) -> "FailureTrace":
        return self._add(at, UNIT, unit, REVIVE)

    def kill_link(self, a: str, b: str, at: float) -> "FailureTrace":
        return self._add(at, LINK, _link_key(a, b), KILL)

    def revive_link(self, a: str, b: str, at: float) -> "FailureTrace":
        return self._add(at, LINK, _link_key(a, b), REVIVE)

    # -- interval queries ---------------------------------------------------

    def _dead_intervals(self, kind: str, target: Any
                        ) -> List[Tuple[float, float]]:
        """Closed-open [kill, revive) intervals for one component."""
        out: List[Tuple[float, float]] = []
        open_at: Optional[float] = None
        for e in self.events:
            if e.kind != kind or e.target != target:
                continue
            if e.action == KILL and open_at is None:
                open_at = e.t_s
            elif e.action == REVIVE and open_at is not None:
                out.append((open_at, e.t_s))
                open_at = None
        if open_at is not None:
            out.append((open_at, math.inf))
        return out

    @staticmethod
    def _dead_at(intervals: List[Tuple[float, float]], t: float) -> bool:
        return any(k <= t < r for k, r in intervals)

    @staticmethod
    def _next_alive(intervals: List[Tuple[float, float]],
                    t: float) -> Optional[float]:
        """Earliest time >= t at which the component is alive; None if it
        stays dead forever from t on."""
        for k, r in intervals:
            if k <= t < r:
                return None if math.isinf(r) else r
        return t

    @staticmethod
    def _killed_between(intervals: List[Tuple[float, float]],
                        t0: float, t1: float) -> bool:
        """Did a kill happen in (t0, t1]? (A token that landed at t0 and
        would be consumed at t1 is lost iff its unit died in between.)"""
        return any(t0 < k <= t1 for k, _ in intervals)

    # unit-facing ----------------------------------------------------------

    def unit_dead_at(self, unit: str, t: float) -> bool:
        return self._dead_at(self._dead_intervals(UNIT, unit), t)

    def unit_next_alive(self, unit: str, t: float) -> Optional[float]:
        return self._next_alive(self._dead_intervals(UNIT, unit), t)

    def unit_killed_between(self, unit: str, t0: float, t1: float) -> bool:
        return self._killed_between(self._dead_intervals(UNIT, unit), t0, t1)

    # link-facing ----------------------------------------------------------

    def link_dead_at(self, a: str, b: str, t: float) -> bool:
        return self._dead_at(self._dead_intervals(LINK, _link_key(a, b)), t)

    def link_next_alive(self, a: str, b: str, t: float) -> Optional[float]:
        return self._next_alive(self._dead_intervals(LINK, _link_key(a, b)), t)

    def link_killed_between(self, a: str, b: str, t0: float,
                            t1: float) -> bool:
        return self._killed_between(
            self._dead_intervals(LINK, _link_key(a, b)), t0, t1)

    # controller-facing ----------------------------------------------------

    def first_kill_affecting(self, units: Sequence[str],
                             link_pairs: Sequence[Tuple[str, str]],
                             *, after: float,
                             before: float = math.inf
                             ) -> Optional[FailureEvent]:
        """Earliest kill event in (after, before] hitting any of ``units``
        or any link between the given unit pairs."""
        keys = {_link_key(a, b) for a, b in link_pairs}
        for e in self.events:
            if e.action != KILL or not (after < e.t_s <= before):
                continue
            if e.kind == UNIT and e.target in units:
                return e
            if e.kind == LINK and e.target in keys:
                return e
        return None

    def dead_units(self, t: float) -> List[str]:
        targets = {e.target for e in self.events if e.kind == UNIT}
        return sorted(u for u in targets if self.unit_dead_at(u, t))

    def dead_links(self, t: float) -> List[FrozenSet[str]]:
        targets = {e.target for e in self.events if e.kind == LINK}
        return sorted((k for k in targets
                       if self._dead_at(self._dead_intervals(LINK, k), t)),
                      key=sorted)


class FailureInjector:
    """Stateful trace consumer: delivers events as modeled time advances.

    The controller (or any runtime component with a clock) calls
    ``advance(now)`` each scheduling round and receives the events whose
    timestamps have elapsed since the previous call — the injection side
    of the companion paper's experiments, where a device is powered off at
    a chosen instant mid-inference.

    Beyond the raw event feed, the injector folds delivered kill/revive
    events into *current* up/down state — ``unit_up`` / ``link_up`` /
    ``dead_units`` / ``dead_links`` answer "as of the last advance()".
    The escalation layer (``runtime.escalation``) polls ``link_up`` for
    its endpoint↔server link each pump round; a down→up transition there
    is what triggers journal fail-back.
    """

    def __init__(self, trace: FailureTrace):
        self.trace = trace
        self._cursor = 0
        self._dead_units: set = set()
        self._dead_links: set = set()

    def advance(self, now: float) -> List[FailureEvent]:
        fresh: List[FailureEvent] = []
        while (self._cursor < len(self.trace.events)
               and self.trace.events[self._cursor].t_s <= now):
            e = self.trace.events[self._cursor]
            fresh.append(e)
            dead = self._dead_units if e.kind == UNIT else self._dead_links
            if e.action == KILL:
                dead.add(e.target)
            else:
                dead.discard(e.target)
            self._cursor += 1
        return fresh

    # -- current state (as of the last advance) -----------------------------

    def unit_up(self, unit: str) -> bool:
        return unit not in self._dead_units

    def link_up(self, a: str, b: str) -> bool:
        return _link_key(a, b) not in self._dead_links

    @property
    def dead_units(self) -> List[str]:
        return sorted(self._dead_units)

    @property
    def dead_links(self) -> List[FrozenSet[str]]:
        return sorted(self._dead_links, key=sorted)

    @property
    def exhausted(self) -> bool:
        return self._cursor >= len(self.trace.events)


# ---------------------------------------------------------------------------
# Detection: heartbeats
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HeartbeatConfig:
    """Liveness protocol constants. ``timeout_s`` is measured from a unit's
    last successful beat, so it must cover at least one full interval or
    healthy units would flap."""

    interval_s: float = 0.050
    timeout_s: float = 0.150

    def __post_init__(self) -> None:
        if self.interval_s <= 0:
            raise ValueError("heartbeat interval must be positive")
        if self.timeout_s < self.interval_s:
            raise ValueError(
                f"timeout_s ({self.timeout_s}) must be >= interval_s "
                f"({self.interval_s}) or healthy units time out")


class HeartbeatMonitor:
    """Models when a failure becomes *known*: a unit killed at ``t_fail``
    beats for the last time at ``floor(t_fail / interval) * interval``; the
    monitor declares it dead once ``timeout_s`` elapses past that beat."""

    def __init__(self, cfg: Optional[HeartbeatConfig] = None):
        self.cfg = cfg or HeartbeatConfig()

    def detect_time(self, t_fail: float) -> float:
        last_beat = math.floor(t_fail / self.cfg.interval_s) * self.cfg.interval_s
        return max(t_fail, last_beat + self.cfg.timeout_s)


# ---------------------------------------------------------------------------
# Bounded FIFO checkpoint buffer
# ---------------------------------------------------------------------------

class CheckpointBuffer:
    """Bounded FIFO of unacknowledged frames (frame_id -> external inputs).

    The controller never has more than ``capacity`` frames in flight: a
    frame enters the buffer when submitted to the staged pipeline and
    leaves on its ack. After a failure, ``unacked()`` is exactly the set
    of frames that must be replayed on the fallback mapping — bounding the
    buffer bounds both replay work and recovery memory.
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError("checkpoint buffer capacity must be >= 1")
        self.capacity = capacity
        self._buf: "OrderedDict[int, Any]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._buf)

    @property
    def full(self) -> bool:
        return len(self._buf) >= self.capacity

    def push(self, frame_id: int, inputs: Any) -> None:
        if self.full:
            raise OverflowError(
                f"checkpoint buffer full ({self.capacity} unacked frames); "
                f"ack before submitting more")
        self._buf[frame_id] = inputs

    def ack(self, frame_id: int) -> None:
        self._buf.pop(frame_id, None)

    def unacked(self) -> List[Tuple[int, Any]]:
        return list(self._buf.items())

    def clear(self) -> None:
        self._buf.clear()


# ---------------------------------------------------------------------------
# Failover controller
# ---------------------------------------------------------------------------

class NoViableMappingError(RuntimeError):
    """No fallback mapping survives the current dead unit/link set."""


@dataclass
class FailoverEvent:
    """One recovery: failure instant -> detection -> re-map -> replay."""

    t_fail_s: float
    t_detect_s: float
    resynth_s: float
    mapping_from: str
    mapping_to: Optional[str]
    dead_units: List[str] = field(default_factory=list)
    dead_links: List[Tuple[str, str]] = field(default_factory=list)
    replayed_frames: List[int] = field(default_factory=list)

    @property
    def recovery_latency_s(self) -> float:
        """Time from the physical failure until the replacement program is
        ready to serve: detection delay + re-synthesis."""
        return (self.t_detect_s - self.t_fail_s) + self.resynth_s


@dataclass
class FailoverReport:
    """Aggregate outcome of one ``FailoverController.serve`` call."""

    events: List[FailoverEvent] = field(default_factory=list)
    frames_replayed: List[int] = field(default_factory=list)
    frames_unserved: List[int] = field(default_factory=list)
    mapping_history: List[str] = field(default_factory=list)
    makespan_s: float = 0.0
    exhausted: bool = False          # ran out of viable mappings

    @property
    def recovery_latency_s(self) -> float:
        """Total modeled recovery latency across all failovers."""
        return sum(e.recovery_latency_s for e in self.events)

    @property
    def num_failovers(self) -> int:
        return len(self.events)


class FailoverController:
    """Serves frame streams through re-mappable staged programs.

    ``fallbacks`` is a ranked list of alternative ``Mapping``s (best
    first); the controller starts on ``primary`` and, on each detected
    failure, walks the list for the first mapping that is *viable* — every
    unit it uses alive, every boundary edge backed by an alive (and
    existing) platform link. Candidates are typically precomputed with
    ``Explorer.rank_fallbacks`` at deployment time, exactly as the
    Edge-PRUNE Explorer precomputes partition-point mapping files.
    """

    def __init__(self, g: Graph, primary: Mapping,
                 fallbacks: Sequence[Mapping] = (), *,
                 platform: Optional[PlatformModel] = None,
                 heartbeat: Optional[HeartbeatConfig] = None,
                 checkpoint_frames: int = 8,
                 obs: Optional[Observability] = None):
        self.g = g
        self.platform = platform
        self.monitor = HeartbeatMonitor(heartbeat)
        self.checkpoint_frames = checkpoint_frames
        self.candidates: List[Mapping] = [primary, *fallbacks]
        self.mapping = primary
        self.program: StagedProgram = synthesize(g, primary)
        # observability: each failover lands as modeled-clock detection /
        # resynthesis spans plus latency histograms
        self.obs = obs if (obs is not None and obs.enabled) else None
        if self.obs is not None:
            r = self.obs.registry
            self._h_detect = r.histogram(
                "repro_failover_detection_seconds", TIME_BUCKETS_S,
                help="modeled failure instant to heartbeat detection")
            self._h_recover = r.histogram(
                "repro_failover_recovery_seconds", TIME_BUCKETS_S,
                help="modeled failure instant to replacement program ready"
                     " (detection + re-synthesis)")
            self._c_failovers = r.counter(
                "repro_failovers_total", help="mapping switches performed")

    # -- mapping viability --------------------------------------------------

    def _boundary_pairs(self, m: Mapping) -> List[Tuple[str, str]]:
        return sorted({(m.unit_of(f.src.actor.name),
                        m.unit_of(f.dst.actor.name))
                       for f in m.boundary_edges(self.g)})

    def _viable(self, m: Mapping, failures: FailureTrace, t: float) -> bool:
        if any(failures.unit_dead_at(u, t) for u in m.units_used()):
            return False
        for a, b in self._boundary_pairs(m):
            if failures.link_dead_at(a, b, t):
                return False
            if (self.platform is not None
                    and self.platform.platform.link_between(a, b) is None):
                return False
        return True

    def _select(self, failures: FailureTrace, t: float) -> Optional[Mapping]:
        for m in self.candidates:
            if self._viable(m, failures, t):
                return m
        return None

    # -- serving ------------------------------------------------------------

    def serve(self, frames: List[Dict[str, Any]], *,
              failures: Optional[FailureTrace] = None,
              arrivals: Optional[List[float]] = None
              ) -> Tuple[List[Optional[Dict[str, Any]]], FailoverReport]:
        """Serve ``frames`` (external-input dicts) to completion.

        Returns one sink-output dict per frame (``None`` for frames that
        could not be served because no viable mapping remained) plus the
        ``FailoverReport``. Committed outputs are bit-identical to a
        failure-free run: a frame is only committed once its final stage
        acked, and un-acked frames are recomputed from their checkpointed
        inputs on the fallback mapping — stage functions are pure and the
        graph is mapping-invariant, so the replayed result is the same
        tensor.
        """
        failures = failures or FailureTrace()
        if arrivals is not None and len(arrivals) != len(frames):
            raise ValueError(f"arrivals has {len(arrivals)} entries for "
                             f"{len(frames)} frames")
        arrivals = arrivals or [0.0] * len(frames)
        pending: deque = deque(range(len(frames)))
        outputs: List[Optional[Dict[str, Any]]] = [None] * len(frames)
        buffer = CheckpointBuffer(self.checkpoint_frames)
        report = FailoverReport(mapping_history=[self.mapping.name])
        clock = 0.0

        while pending:
            # A failure may already be pending at `clock` (e.g. the unit
            # died while we were re-synthesizing, or at t=0 before the
            # first frame — the failure-during-prefill case).
            if not self._viable(self.mapping, failures, clock):
                t_detect = max(clock, self.monitor.detect_time(clock))
                if not self._failover(failures, clock, t_detect, [], report):
                    report.frames_unserved = list(pending)
                    break
                clock = report.events[-1].t_detect_s \
                    + report.events[-1].resynth_s
                continue

            window = list(pending)[:self.checkpoint_frames]
            for fid in window:
                buffer.push(fid, frames[fid])
            win_arrivals = [max(arrivals[fid], clock) for fid in window]
            sinks, sched = self.program.run_pipelined(
                [frames[fid] for fid in window],
                platform=self.platform, arrivals=win_arrivals)
            window_end = max(sched.makespan_s, clock)

            kill = failures.first_kill_affecting(
                self.mapping.units_used(),
                self._boundary_pairs(self.mapping),
                after=clock, before=window_end)
            if kill is None:
                for wi, fid in enumerate(window):
                    outputs[fid] = sinks[wi]
                    buffer.ack(fid)
                    pending.popleft()
                clock = window_end
                continue

            # Commit only frames whose final-stage ack beat the failure;
            # everything else in the window is unacknowledged state on a
            # (partially) dead mapping and will be replayed.
            t_fail = kill.t_s
            for wi, fid in enumerate(window):
                if sched.frame_done_s[wi] <= t_fail:
                    outputs[fid] = sinks[wi]
                    buffer.ack(fid)
                    pending.remove(fid)
            replay = [fid for fid, _ in buffer.unacked()]
            buffer.clear()
            if not self._failover(failures, t_fail,
                                  self.monitor.detect_time(t_fail),
                                  replay, report):
                report.frames_unserved = list(pending)
                break
            ev = report.events[-1]
            clock = ev.t_detect_s + ev.resynth_s
            report.frames_replayed.extend(replay)

        report.makespan_s = max(report.makespan_s, clock)
        return outputs, report

    def _failover(self, failures: FailureTrace, t_fail: float,
                  t_detect: float, replay: List[int],
                  report: FailoverReport) -> bool:
        """Switch to the best viable fallback at ``t_detect``. Returns
        False (and records an exhausted event) when none survives."""
        dead_u = failures.dead_units(t_detect)
        dead_l = [tuple(sorted(k)) for k in failures.dead_links(t_detect)]
        nxt = self._select(failures, t_detect)
        wall0 = time.perf_counter()
        if nxt is not None:
            program = synthesize(self.g, nxt)
        resynth = time.perf_counter() - wall0
        ev = FailoverEvent(
            t_fail_s=t_fail, t_detect_s=t_detect, resynth_s=resynth,
            mapping_from=self.mapping.name,
            mapping_to=nxt.name if nxt is not None else None,
            dead_units=dead_u, dead_links=dead_l,
            replayed_frames=list(replay))
        report.events.append(ev)
        if self.obs is not None:
            failover_trace(self.obs.tracer, [ev])
            self._h_detect.observe(ev.t_detect_s - ev.t_fail_s)
            self._h_recover.observe(ev.recovery_latency_s)
            if nxt is not None:
                self._c_failovers.inc()
        if nxt is None:
            report.exhausted = True
            report.makespan_s = max(report.makespan_s, t_detect)
            return False
        self.mapping = nxt
        self.program = program
        report.mapping_history.append(nxt.name)
        return True
