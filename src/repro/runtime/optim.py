"""AdamW + gradient clipping + schedules, in plain JAX (no optax dep).

Optimizer state mirrors the parameter pytree (same shardings apply), with
fp32 first/second moments regardless of the parameter dtype.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) \
        * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params: Any) -> Dict[str, Any]:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def apply(cfg: AdamWConfig, params: Any, opt: Dict[str, Any], grads: Any
          ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = opt["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt["m"])
    flat_v = tdef.flatten_up_to(opt["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
