"""The jitted step functions the launcher and dry-run lower.

All three apply the ZeRO-3 compute-copy discipline when a mesh is given:
master fp32 params stay FSDP("data") x TP("model") sharded; the step
casts them to bf16 and constrains the compute copy to model-only
sharding, which lowers to weight all-gather over "data" (forward) and
gradient reduce-scatter (backward) — see sharding.rules.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime import optim
from repro.sharding.rules import ShardCtx


def _prepare(params, cfg: ModelConfig, mesh):
    """Cast to bf16 (whole tree — stays master-sharded, cheap) and gather
    the NON-scan leaves (embed / head / encoder / projector) to their
    compute sharding. Scan-stacked layers are gathered per scan step
    inside the model via ctx.layer — gathering the full stack here would
    materialize every layer's compute copy at once."""
    ctx = ShardCtx(mesh) if mesh is not None else None
    params = T.cast_params_for_compute(params, cfg)
    if ctx is not None:
        params = {k: (v if k == "scan" else ctx.layer(v))
                  for k, v in params.items()}
    return params, ctx


def make_train_step(cfg: ModelConfig, opt_cfg: optim.AdamWConfig,
                    mesh=None, *, microbatches: int = 1):
    """train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``microbatches > 1`` enables gradient accumulation: the global batch
    is split on its leading dim and scanned, dividing activation/logits
    temp memory by the microbatch count at the cost of re-running the
    (already rematerialized) forward per slice. This is how the train_4k
    dry-runs of the vocab-heavy configs fit the 16 GB/chip budget.
    """

    def loss(params, batch):
        params, ctx = _prepare(params, cfg, mesh)
        return T.loss_fn(params, cfg, batch, ctx=ctx)

    def grads_of(params, batch):
        return jax.value_and_grad(loss, has_aux=True)(params, batch)

    def train_step(params, opt_state, batch
                   ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
        if microbatches == 1:
            (l, metrics), grads = grads_of(params, batch)
        else:
            mb = jax.tree.map(
                lambda a: a.reshape((microbatches, a.shape[0] // microbatches)
                                    + a.shape[1:]), batch)

            def acc_step(acc, one):
                (l, metrics), g = grads_of(params, one)
                acc_g, acc_l, acc_m = acc
                return (jax.tree.map(jnp.add, acc_g, g), acc_l + l,
                        jax.tree.map(jnp.add, acc_m, metrics)), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            zero_m = {"ce": jnp.zeros(()), "aux": jnp.zeros(())}
            (grads, l, metrics), _ = jax.lax.scan(
                acc_step, (zero_g, jnp.zeros(()), zero_m), mb)
            scale = 1.0 / microbatches
            grads = jax.tree.map(lambda g: g * scale, grads)
            l = l * scale
            metrics = jax.tree.map(lambda m: m * scale, metrics)
        params, opt_state, om = optim.apply(opt_cfg, params, opt_state, grads)
        return params, opt_state, {"loss": l, **metrics, **om}

    return train_step


def make_prefill_step(cfg: ModelConfig, *, max_len: int, mesh=None):
    def prefill_step(params, batch):
        params, ctx = _prepare(params, cfg, mesh)
        return T.prefill(params, cfg, batch, max_len=max_len, ctx=ctx)
    return prefill_step


def make_serve_step(cfg: ModelConfig, mesh=None):
    def serve_step(params, cache, token, cache_len):
        params, ctx = _prepare(params, cfg, mesh)
        return T.decode_step(params, cfg, token, cache, cache_len, ctx=ctx)
    return serve_step
