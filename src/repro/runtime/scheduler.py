"""Continuous-batching request scheduler (slot-based KV cache reuse).

The static-bucket ``ServeEngine`` path groups requests by prompt length
and decodes each bucket to completion with its own compiled
``(batch, prompt_len)`` functions: a new bucket shape means a new XLA
compile, and a short request parks its finished KV rows in the batch
until the longest request in the bucket drains.

The scheduler replaces that with the continuous-batching pattern:

* one decode function compiled ONCE at a fixed slot count ``max_slots`` —
  requests join and leave the running batch without recompiling;
* a persistent slot-based KV cache (``init_cache(cfg, max_slots,
  max_len)``): admitting a request prefills it at batch=1 and writes the
  resulting cache rows into a free slot; evicting just frees the slot
  index (``cache_len`` masking makes stale rows unreachable);
* an admission queue: requests arrive (optionally timestamped, e.g.
  Poisson arrivals in the serving bench), wait FIFO for a free slot, and
  are admitted *between* decode steps — work is re-admitted mid-flight
  exactly as the fault-tolerant Edge-PRUNE follow-up assumes.

Per-slot ``cache_len`` is what makes the shared batch sound: the decode
attention masks every cache row at position >= cache_len[slot], so slots
holding different-length contexts (or nothing at all) coexist in one
batched step. Under greedy sampling the emitted tokens are bit-identical
to the static-bucket path (see tests/test_scheduler.py).

``Request``/``Completion`` live here (serving.py re-exports them) so the
engine can delegate without an import cycle.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig


def sample_tokens(key: jax.Array, logits: jax.Array, *, greedy: bool,
                  temperature: float) -> Tuple[jax.Array, jax.Array]:
    """Shared sampling rule for both scheduler modes — the continuous ==
    static token-identity contract depends on there being exactly one.
    Returns (tokens (B,) int32, next key)."""
    if greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), key
    key, sub = jax.random.split(key)
    return jax.random.categorical(
        sub, logits / temperature, axis=-1).astype(jnp.int32), key


@dataclass
class Request:
    id: int
    prompt: np.ndarray                      # (S,) int32
    max_new_tokens: int = 16
    eos: Optional[int] = None
    embeds: Optional[np.ndarray] = None     # VLM/audio frontend output


@dataclass
class Completion:
    id: int
    tokens: List[int]
    prefill_s: float
    decode_s: float
    # Continuous-scheduler timeline (engine-clock seconds; 0.0 on the
    # static path which has no per-request timeline).
    arrival_s: float = 0.0
    first_token_s: float = 0.0
    finish_s: float = 0.0

    @property
    def ttft_s(self) -> float:
        """Time to first token (admission wait + prefill)."""
        return self.first_token_s - self.arrival_s

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s


def validate_request_fits(cfg: ModelConfig, req: Request,
                          max_len: int) -> None:
    """Shared admission check for both engine modes. Decode writes KV
    rows at positions len(prompt) .. len(prompt) + max_new_tokens - 2;
    on an uncapped global-attention cache, rows past max_len would
    silently wrap the ring onto the prompt and corrupt the context.
    Sliding-window / recurrent (subquadratic) configs and explicitly
    capped caches (max_cache_len) wrap by design and are exempt."""
    if len(req.prompt) > max_len:
        raise ValueError(
            f"request {req.id}: prompt length {len(req.prompt)} exceeds "
            f"max_len {max_len}")
    if cfg.is_subquadratic_decode or cfg.max_cache_len:
        return
    need = len(req.prompt) + req.max_new_tokens - 1
    if need > max_len:
        raise ValueError(
            f"request {req.id}: prompt ({len(req.prompt)}) + "
            f"max_new_tokens ({req.max_new_tokens}) needs {need} cache "
            f"rows, exceeding max_len {max_len}")


@dataclass
class SchedulerConfig:
    max_slots: int = 8          # decode batch width (compiled once)
    max_len: int = 512          # KV cache length per slot
    greedy: bool = True
    temperature: float = 1.0
    seed: int = 0


@dataclass
class SchedEvent:
    """Observable admission/eviction trace (asserted on by tests)."""
    t_s: float
    kind: str                   # "admit" | "evict" | "fail"
    request_id: int
    slot: int
    step: int                   # decode-step counter at event time


@dataclass(frozen=True)
class SlotFailure:
    """Injected loss of decode slots at a step boundary — the scheduler-
    level view of a processing-unit failure (the unit hosting those KV
    slots went away). ``slots=None`` means every active slot: whole-unit
    loss, the companion fault-tolerance paper's server-loss scenario."""
    step: int
    slots: Optional[Tuple[int, ...]] = None


@dataclass
class _Ticket:
    req: Request
    arrival_s: float
    slot: int = -1
    emitted: List[int] = field(default_factory=list)
    prefill_s: float = 0.0
    first_token_s: float = 0.0


class ContinuousScheduler:
    """Admission queue + shared decode batch over a slot-based KV cache."""

    def __init__(self, cfg: ModelConfig, params: Any,
                 sched: Optional[SchedulerConfig] = None, *,
                 failures: Optional[List[SlotFailure]] = None):
        self.cfg = cfg
        self.params = params
        self.sched = sched or SchedulerConfig()
        # Injected slot failures, applied at decode-step boundaries.
        self.failures = sorted(failures or [], key=lambda f: f.step)
        s = self.sched
        self.key = jax.random.PRNGKey(s.seed)
        self._prefill = jax.jit(
            lambda p, b: T.prefill(p, cfg, b, max_len=s.max_len))
        self._decode = jax.jit(
            lambda p, tok, cache, clen: T.decode_step(p, cfg, tok, cache, clen))
        self._insert = jax.jit(self._insert_impl)
        # Persistent slot state. cache_len/tokens are host-side mirrors so
        # admission/eviction never touches device state beyond the insert.
        self.cache = T.init_cache(cfg, s.max_slots, s.max_len)
        self.cache_len = np.zeros((s.max_slots,), np.int32)
        self.tokens = np.zeros((s.max_slots,), np.int32)
        self.free: List[int] = list(range(s.max_slots))[::-1]  # pop() -> 0,1,..
        self.active: Dict[int, _Ticket] = {}
        self.queue: deque = deque()     # tickets waiting for a slot (FIFO)
        self.backlog: List[_Ticket] = []  # submitted, not yet "arrived"
        self.events: List[SchedEvent] = []
        self.step_count = 0

    # -- slot cache surgery -------------------------------------------------

    @staticmethod
    def _insert_impl(batch_cache, req_cache, slot):
        """Write a batch=1 prefill cache into slot ``slot`` of the shared
        batch cache. Scanned-period leaves are (P, B, ...), remainder
        leaves (B, ...)."""
        scan = jax.tree.map(lambda big, small: big.at[:, slot].set(small[:, 0]),
                            batch_cache["scan"], req_cache["scan"])
        rem = jax.tree.map(lambda big, small: big.at[slot].set(small[0]),
                           batch_cache["rem"], req_cache["rem"])
        return {"scan": scan, "rem": rem}

    def _sample(self, logits: jax.Array) -> jax.Array:
        toks, self.key = sample_tokens(self.key, logits,
                                       greedy=self.sched.greedy,
                                       temperature=self.sched.temperature)
        return toks

    # -- public API ---------------------------------------------------------

    def submit(self, req: Request, arrival_s: float = 0.0) -> None:
        validate_request_fits(self.cfg, req, self.sched.max_len)
        self.backlog.append(_Ticket(req=req, arrival_s=arrival_s))

    def run(self, on_completion: Optional[Callable[[Completion], None]] = None
            ) -> List[Completion]:
        """Drain every submitted request; returns completions by id.
        ``on_completion`` (streaming mode) is invoked with each completion
        the moment its request finishes, before the drain returns."""
        t0 = time.perf_counter()
        out: List[Completion] = []
        self.backlog.sort(key=lambda t: t.arrival_s)
        while self.backlog or self.queue or self.active:
            now = time.perf_counter() - t0
            while self.backlog and self.backlog[0].arrival_s <= now:
                self.queue.append(self.backlog.pop(0))
            if not self.queue and not self.active:
                # idle until the next arrival (virtual clock = wall clock)
                time.sleep(max(0.0, self.backlog[0].arrival_s - now))
                continue
            self._apply_failures(t0)
            self._admit(t0)
            if self.active:
                done = self._decode_step(t0)
                if on_completion is not None:
                    for c in done:
                        on_completion(c)
                out.extend(done)
        return sorted(out, key=lambda c: c.id)

    # -- internals ----------------------------------------------------------

    def _apply_failures(self, t0: float) -> None:
        """Apply injected slot failures due at the current step boundary:
        every request on a failed slot is *re-queued, not dropped* — its
        KV state is gone, so it goes back to the head of the admission
        queue (FIFO order preserved) and is re-prefilled from its original
        prompt. Greedy decoding makes the re-run deterministic, so its
        final tokens — and those of every unaffected request, whose slots
        are untouched — are bit-identical to a failure-free run."""
        while self.failures and self.failures[0].step <= self.step_count:
            f = self.failures.pop(0)
            slots = list(self.active) if f.slots is None \
                else [s for s in f.slots if s in self.active]
            now = time.perf_counter() - t0
            victims = []
            for slot in slots:
                ticket = self.active.pop(slot)
                self.free.append(slot)
                self.cache_len[slot] = 0
                self.events.append(SchedEvent(now, "fail", ticket.req.id,
                                              slot, self.step_count))
                ticket.slot = -1
                ticket.emitted = []
                ticket.prefill_s = 0.0
                ticket.first_token_s = 0.0
                victims.append(ticket)
            victims.sort(key=lambda t: t.arrival_s)
            self.queue.extendleft(reversed(victims))

    def _admit(self, t0: float) -> None:
        while self.free and self.queue:
            ticket = self.queue.popleft()
            slot = self.free.pop()
            r = ticket.req
            batch = {"tokens": jnp.asarray(r.prompt[None])}
            if r.embeds is not None:
                batch["embeds"] = jnp.asarray(r.embeds[None])
            tp = time.perf_counter()
            logits, req_cache, clen = jax.block_until_ready(
                self._prefill(self.params, batch))
            self.cache = self._insert(self.cache, req_cache,
                                      jnp.int32(slot))
            ticket.prefill_s = time.perf_counter() - tp
            first = int(self._sample(logits)[0])
            ticket.emitted.append(first)
            ticket.first_token_s = time.perf_counter() - t0
            ticket.slot = slot
            self.cache_len[slot] = int(clen[0])
            self.tokens[slot] = first
            self.active[slot] = ticket
            self.events.append(SchedEvent(ticket.first_token_s, "admit",
                                          r.id, slot, self.step_count))

    def _finished(self, ticket: _Ticket) -> bool:
        return len(ticket.emitted) >= ticket.req.max_new_tokens

    def _decode_step(self, t0: float) -> List[Completion]:
        done: List[Completion] = []
        # Requests satisfied by the prefill token alone never decode.
        for slot in [s for s, tk in self.active.items() if self._finished(tk)]:
            done.append(self._evict(slot, t0))
        if not self.active:
            return done
        logits, self.cache, _ = self._decode(
            self.params, jnp.asarray(self.tokens), self.cache,
            jnp.asarray(self.cache_len))
        toks = np.asarray(self._sample(logits))
        self.step_count += 1
        for slot in self.active:     # free slots keep cache_len == 0
            self.cache_len[slot] += 1
        for slot, ticket in list(self.active.items()):
            t = int(toks[slot])
            if ticket.req.eos is not None and t == ticket.req.eos:
                done.append(self._evict(slot, t0))
                continue
            ticket.emitted.append(t)
            self.tokens[slot] = t
            if self._finished(ticket):
                done.append(self._evict(slot, t0))
        return done

    def _evict(self, slot: int, t0: float) -> Completion:
        ticket = self.active.pop(slot)
        self.free.append(slot)
        self.cache_len[slot] = 0
        now = time.perf_counter() - t0
        self.events.append(SchedEvent(now, "evict", ticket.req.id, slot,
                                      self.step_count))
        return Completion(
            ticket.req.id, ticket.emitted, ticket.prefill_s,
            now - ticket.first_token_s, arrival_s=ticket.arrival_s,
            first_token_s=ticket.first_token_s, finish_s=now)
