"""Continuous-batching request scheduler (slot- or page-based KV cache).

The static-bucket ``ServeEngine`` path groups requests by prompt length
and decodes each bucket to completion with its own compiled
``(batch, prompt_len)`` functions: a new bucket shape means a new XLA
compile, and a short request parks its finished KV rows in the batch
until the longest request in the bucket drains.

The scheduler replaces that with the continuous-batching pattern:

* one decode function compiled ONCE at a fixed slot count ``max_slots`` —
  requests join and leave the running batch without recompiling;
* a persistent KV cache in one of two layouts:

  - **slotted** (``init_cache(cfg, max_slots, max_len)``): every slot
    owns ``max_len`` dense KV rows. Simple, but a short request strands
    most of its rows for its whole lifetime;
  - **paged** (``SchedulerConfig(paged=True)``): global-attention K/V
    live in a shared pool of fixed-size blocks
    (``init_paged_cache``), handed out by a ``BlockAllocator`` — on
    admission for the prompt, block-by-block during decode growth —
    and addressed through per-slot block tables. A request holds only
    the blocks its context actually fills; eviction/failure returns
    them (exactly once) to the pool. When the pool is exhausted,
    admission *waits* instead of over-committing, and decode growth
    preempts (re-queues, never drops) the latest-admitted request.

* an admission queue: requests arrive (optionally timestamped, e.g.
  Poisson arrivals in the serving bench), wait FIFO for a free slot, and
  are admitted *between* decode steps — work is re-admitted mid-flight
  exactly as the fault-tolerant Edge-PRUNE follow-up assumes;
* **chunked prefill** (``SchedulerConfig(prefill_chunk=C)``): admission
  prefills a prompt in C-token ``prefill_extend`` steps interleaved with
  decode steps, so a long prompt no longer freezes every active stream
  for its whole prefill — the admission stall is bounded by one chunk.

Per-slot ``cache_len`` is what makes the shared batch sound: the decode
attention masks every cache row at position >= cache_len[slot], so slots
holding different-length contexts (or nothing at all) coexist in one
batched step. Under greedy sampling the emitted tokens are bit-identical
to the static-bucket path — in every layout combination (see
tests/test_scheduler.py).

``Request``/``Completion`` live here (serving.py re-exports them) so the
engine can delegate without an import cycle.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig


def sample_tokens(key: jax.Array, logits: jax.Array, *, greedy: bool,
                  temperature: float) -> Tuple[jax.Array, jax.Array]:
    """Shared sampling rule for both scheduler modes — the continuous ==
    static token-identity contract depends on there being exactly one.
    Returns (tokens (B,) int32, next key)."""
    if greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), key
    key, sub = jax.random.split(key)
    return jax.random.categorical(
        sub, logits / temperature, axis=-1).astype(jnp.int32), key


@dataclass
class Request:
    id: int
    prompt: np.ndarray                      # (S,) int32
    max_new_tokens: int = 16
    eos: Optional[int] = None
    embeds: Optional[np.ndarray] = None     # VLM/audio frontend output


@dataclass
class Completion:
    id: int
    tokens: List[int]
    prefill_s: float
    decode_s: float
    # Continuous-scheduler timeline (engine-clock seconds; 0.0 on the
    # static path which has no per-request timeline).
    arrival_s: float = 0.0
    first_token_s: float = 0.0
    finish_s: float = 0.0

    @property
    def ttft_s(self) -> float:
        """Time to first token (admission wait + prefill)."""
        return self.first_token_s - self.arrival_s

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s


def validate_request_fits(cfg: ModelConfig, req: Request,
                          max_len: int) -> None:
    """Shared admission check for both engine modes. Decode writes KV
    rows at positions len(prompt) .. len(prompt) + max_new_tokens - 2;
    on an uncapped global-attention cache, rows past max_len would
    silently wrap the ring onto the prompt and corrupt the context.
    Sliding-window / recurrent (subquadratic) configs and explicitly
    capped caches (max_cache_len) wrap by design and are exempt."""
    if len(req.prompt) > max_len:
        raise ValueError(
            f"request {req.id}: prompt length {len(req.prompt)} exceeds "
            f"max_len {max_len}")
    if cfg.is_subquadratic_decode or cfg.max_cache_len:
        return
    need = len(req.prompt) + req.max_new_tokens - 1
    if need > max_len:
        raise ValueError(
            f"request {req.id}: prompt ({len(req.prompt)}) + "
            f"max_new_tokens ({req.max_new_tokens}) needs {need} cache "
            f"rows, exceeding max_len {max_len}")


@dataclass
class SchedulerConfig:
    max_slots: int = 8          # decode batch width (compiled once)
    max_len: int = 512          # KV rows per slot (rounded up to a whole
    #                             number of blocks in paged mode)
    greedy: bool = True
    temperature: float = 1.0
    seed: int = 0
    # paged KV cache: global-attn K/V in a shared block pool instead of
    # dense per-slot rows. num_blocks=0 sizes the pool for slotted parity
    # (max_slots full slots) + the reserved null block; size it smaller
    # to actually oversubscribe.
    paged: bool = False
    block_size: int = 16        # KV rows per block
    num_blocks: int = 0
    # chunked prefill: admit prompts prefill_chunk tokens at a time,
    # interleaved with decode steps (0 = one-shot prefill). Falls back to
    # one-shot for configs/requests outside supports_chunked_prefill.
    prefill_chunk: int = 0
    # assert slot/block accounting invariants at every step boundary
    debug: bool = False


@dataclass
class SchedEvent:
    """Observable admission/eviction trace (asserted on by tests)."""
    t_s: float
    kind: str                   # "admit" | "evict" | "fail" | "preempt"
    request_id: int
    slot: int
    step: int                   # decode-step counter at event time


@dataclass(frozen=True)
class SlotFailure:
    """Injected loss of decode slots at a step boundary — the scheduler-
    level view of a processing-unit failure (the unit hosting those KV
    slots went away). ``slots=None`` means every active slot: whole-unit
    loss, the companion fault-tolerance paper's server-loss scenario."""
    step: int
    slots: Optional[Tuple[int, ...]] = None


class BlockAllocator:
    """Fixed pool of KV-cache blocks with leak/double-free accounting.

    Physical block 0 is reserved as the null block: free slots and
    unallocated block-table entries point at it, so their (masked,
    never-read) decode writes land somewhere harmless. ``alloc`` returns
    None when the request can't be satisfied — the scheduler queues or
    preempts instead of over-committing — and ``free`` raises on a block
    that isn't currently held, so a double-free is an error, not silent
    pool corruption."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError(f"need >= 2 blocks (one is the reserved null "
                             f"block), got {num_blocks}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._held: set = set()
        self.hwm = 0                    # high-water mark, blocks in use

    @property
    def capacity(self) -> int:
        return self.num_blocks - 1      # block 0 reserved

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._held)

    def alloc(self, n: int) -> Optional[List[int]]:
        if n > len(self._free):
            return None
        blocks = [self._free.pop() for _ in range(n)]
        self._held.update(blocks)
        self.hwm = max(self.hwm, len(self._held))
        return blocks

    def reset_hwm(self) -> None:
        """Restart high-water tracking from the current occupancy (e.g.
        between a warmup drain and a measured run)."""
        self.hwm = len(self._held)

    def free(self, blocks: List[int]) -> None:
        for b in blocks:
            if b not in self._held:
                raise ValueError(f"block {b} freed but not held "
                                 f"(double free or foreign block)")
            self._held.remove(b)
            self._free.append(b)

    def check(self) -> None:
        assert len(self._free) + len(self._held) == self.capacity, \
            (len(self._free), len(self._held), self.capacity)
        assert 0 not in self._held and 0 not in self._free


@dataclass
class _Ticket:
    req: Request
    arrival_s: float
    slot: int = -1
    emitted: List[int] = field(default_factory=list)
    prefill_s: float = 0.0
    first_token_s: float = 0.0
    blocks: List[int] = field(default_factory=list)   # paged mode
    admit_seq: int = -1         # admission order (preemption picks latest)


@dataclass
class _ChunkedPrefill:
    """A prompt mid-way through chunked admission: its slot (and, paged,
    its prompt blocks) are reserved; K/V accumulates in a batch=1 scratch
    cache that is inserted into the shared cache once the prompt is
    done."""
    ticket: _Ticket
    slot: int
    cache: Any
    pos: int = 0                # prompt tokens consumed so far


class ContinuousScheduler:
    """Admission queue + shared decode batch over a slot/paged KV cache."""

    def __init__(self, cfg: ModelConfig, params: Any,
                 sched: Optional[SchedulerConfig] = None, *,
                 failures: Optional[List[SlotFailure]] = None):
        self.cfg = cfg
        self.params = params
        self.sched = sched or SchedulerConfig()
        # Injected slot failures, applied at decode-step boundaries. A
        # cursor (not destructive pops) tracks what has been applied, so
        # run() is re-entrant: a second run() with new submissions still
        # sees failures the first drain never reached.
        self.failures = sorted(failures or [], key=lambda f: f.step)
        self._failure_pos = 0
        s = self.sched
        if s.paged and cfg.max_cache_len:
            raise ValueError(
                "paged KV cache is position-indexed; max_cache_len ring "
                "caps are a slotted-path feature")
        if s.paged and all(k != "attn" for k in cfg.layer_kinds):
            raise ValueError(
                f"{cfg.name}: paged KV cache pages global-attention K/V, "
                "but this config has none (local windows and recurrent "
                "state are fixed-size per slot) — use the slotted layout; "
                "its memory is already bounded")
        # paged mode wants a whole number of blocks per slot
        self.max_len = s.max_len if not s.paged else \
            -(-s.max_len // s.block_size) * s.block_size
        self.key = jax.random.PRNGKey(s.seed)
        max_len = self.max_len
        self._prefill_fn = jax.jit(
            lambda p, b: T.prefill(p, cfg, b, max_len=max_len))
        self._insert = jax.jit(self._insert_impl)
        # chunked prefill (gated to configs the extend path supports)
        self._chunk = s.prefill_chunk \
            if (s.prefill_chunk > 0 and T.supports_chunked_prefill(cfg)) \
            else 0
        self._scratch_len = -(-max_len // self._chunk) * self._chunk \
            if self._chunk else max_len
        if self._chunk:
            self._extend_fn = jax.jit(
                lambda p, tok, c, cl: T.prefill_extend(p, cfg, tok, c, cl))
            self._insert_sliced = jax.jit(self._insert_sliced_impl)
        self._chunking: Optional[_ChunkedPrefill] = None
        # Persistent slot state. cache_len/tokens/block_tables are host-
        # side mirrors so admission/eviction never touches device state
        # beyond the insert.
        if s.paged:
            self.pages_per_slot = max_len // s.block_size
            num_blocks = s.num_blocks or \
                (s.max_slots * self.pages_per_slot + 1)
            self.alloc = BlockAllocator(num_blocks, s.block_size)
            self.block_tables = np.zeros(
                (s.max_slots, self.pages_per_slot), np.int32)
            self.cache = T.init_paged_cache(cfg, num_blocks, s.block_size,
                                            s.max_slots, max_len=max_len)
            self._decode = jax.jit(
                lambda p, tok, cache, clen, tbl: T.decode_step(
                    p, cfg, tok, cache, clen, block_tables=tbl))
            self._insert_paged = jax.jit(
                lambda c, rc, bids, slot: T.paged_insert(
                    cfg, c, rc, bids, slot, block_size=s.block_size))
        else:
            self.alloc = None
            self.block_tables = None
            self.cache = T.init_cache(cfg, s.max_slots, max_len)
            self._decode = jax.jit(
                lambda p, tok, cache, clen: T.decode_step(p, cfg, tok,
                                                          cache, clen))
        self.cache_len = np.zeros((s.max_slots,), np.int32)
        self.tokens = np.zeros((s.max_slots,), np.int32)
        self.free: List[int] = list(range(s.max_slots))[::-1]  # pop() -> 0,1,..
        self.active: Dict[int, _Ticket] = {}
        self.queue: deque = deque()     # tickets waiting for a slot (FIFO)
        self.backlog: List[_Ticket] = []  # submitted, not yet "arrived"
        self._backlog_pos = 0           # consumed-prefix cursor into backlog
        self._admit_seq = 0
        self.events: List[SchedEvent] = []
        self.step_count = 0

    # -- slot cache surgery -------------------------------------------------

    @staticmethod
    def _insert_impl(batch_cache, req_cache, slot):
        """Write a batch=1 prefill cache into slot ``slot`` of the shared
        batch cache. Scanned-period leaves are (P, B, ...), remainder
        leaves (B, ...)."""
        scan = jax.tree.map(lambda big, small: big.at[:, slot].set(small[:, 0]),
                            batch_cache["scan"], req_cache["scan"])
        rem = jax.tree.map(lambda big, small: big.at[slot].set(small[0]),
                           batch_cache["rem"], req_cache["rem"])
        return {"scan": scan, "rem": rem}

    def _insert_sliced_impl(self, batch_cache, req_cache, slot):
        """Slotted insert from the chunk-rounded scratch cache: keep the
        first max_len rows of every K/V leaf. Only reachable for chunked-
        prefill configs (all-global-attn), where every cache leaf has the
        row dim right after batch."""
        ml = self.max_len
        scan = jax.tree.map(
            lambda big, small: big.at[:, slot].set(small[:, 0, :ml]),
            batch_cache["scan"], req_cache["scan"])
        rem = jax.tree.map(
            lambda big, small: big.at[slot].set(small[0, :ml]),
            batch_cache["rem"], req_cache["rem"])
        return {"scan": scan, "rem": rem}

    def _sample(self, logits: jax.Array) -> jax.Array:
        toks, self.key = sample_tokens(self.key, logits,
                                       greedy=self.sched.greedy,
                                       temperature=self.sched.temperature)
        return toks

    # -- public API ---------------------------------------------------------

    def submit(self, req: Request, arrival_s: float = 0.0) -> None:
        validate_request_fits(self.cfg, req, self.max_len)
        if self.sched.paged:
            rows = max(1, len(req.prompt) + max(req.max_new_tokens - 1, 0))
            need = -(-rows // self.sched.block_size)
            if need > self.alloc.capacity:
                raise ValueError(
                    f"request {req.id}: needs {need} KV blocks worst-case, "
                    f"pool holds {self.alloc.capacity}")
        self.backlog.append(_Ticket(req=req, arrival_s=arrival_s))

    def run(self, on_completion: Optional[Callable[[Completion], None]] = None
            ) -> List[Completion]:
        """Drain every submitted request; returns completions by id.
        ``on_completion`` (streaming mode) is invoked with each completion
        the moment its request finishes, before the drain returns.
        Re-entrant: a later run() continues from the same step counter and
        failure cursor, serving anything submitted since."""
        t0 = time.perf_counter()
        out: List[Completion] = []
        pending = sorted(self.backlog[self._backlog_pos:],
                         key=lambda t: t.arrival_s)
        self.backlog[self._backlog_pos:] = pending
        while (self._backlog_pos < len(self.backlog) or self.queue
               or self.active or self._chunking is not None):
            now = time.perf_counter() - t0
            while (self._backlog_pos < len(self.backlog)
                   and self.backlog[self._backlog_pos].arrival_s <= now):
                self.queue.append(self.backlog[self._backlog_pos])
                self._backlog_pos += 1
            if not self.queue and not self.active and self._chunking is None:
                # idle until the next arrival (virtual clock = wall
                # clock). Failures due at this step boundary still apply
                # — they must not be silently deferred past the gap.
                self._apply_failures(t0)
                time.sleep(max(
                    0.0, self.backlog[self._backlog_pos].arrival_s - now))
                continue
            self._apply_failures(t0)
            self._advance_chunked(t0)
            self._admit(t0)
            if self.active:
                done = self._decode_step(t0)
                if on_completion is not None:
                    for c in done:
                        on_completion(c)
                out.extend(done)
            if self.sched.debug:
                self._check_invariants()
        return sorted(out, key=lambda c: c.id)

    def kv_stats(self) -> Dict[str, float]:
        """KV-memory accounting for the serving bench: what a dense
        slotted cache reserves vs what the paged pool holds / has ever
        held (high-water mark), in bytes of global-attention K/V."""
        row = T.kv_row_bytes(self.cfg)
        s = self.sched
        # the slotted baseline reserves the *configured* max_len, not the
        # paged path's block-rounded self.max_len
        out = {"slotted_kv_reserved_bytes":
               float(s.max_slots * s.max_len * row)}
        if s.paged:
            bs = s.block_size
            out["paged_kv_pool_bytes"] = float(self.alloc.capacity * bs * row)
            out["paged_kv_hwm_bytes"] = float(self.alloc.hwm * bs * row)
            out["paged_kv_hwm_blocks"] = float(self.alloc.hwm)
        return out

    # -- internals ----------------------------------------------------------

    def _release_slot(self, slot: int, ticket: _Ticket) -> None:
        """Return a slot (and, paged, its blocks — exactly once) to the
        free pool, zeroing every host-side mirror so no stale state
        outlives the occupancy."""
        self.free.append(slot)
        self.cache_len[slot] = 0
        self.tokens[slot] = 0
        if self.sched.paged:
            if ticket.blocks:
                self.alloc.free(ticket.blocks)
                ticket.blocks = []
            self.block_tables[slot] = 0

    @staticmethod
    def _reset_ticket(ticket: _Ticket) -> None:
        ticket.slot = -1
        ticket.emitted = []
        ticket.prefill_s = 0.0
        ticket.first_token_s = 0.0
        ticket.admit_seq = -1

    def _apply_failures(self, t0: float) -> None:
        """Apply injected slot failures due at the current step boundary:
        every request on a failed slot is *re-queued, not dropped* — its
        KV state (and paged blocks) is gone, so it goes back to the head
        of the admission queue (FIFO order preserved) and is re-prefilled
        from its original prompt. A prompt mid-way through chunked
        prefill on a failed slot restarts the same way. Greedy decoding
        makes the re-run deterministic, so its final tokens — and those
        of every unaffected request, whose slots are untouched — are
        bit-identical to a failure-free run."""
        while (self._failure_pos < len(self.failures)
               and self.failures[self._failure_pos].step <= self.step_count):
            f = self.failures[self._failure_pos]
            self._failure_pos += 1
            slots = list(self.active) if f.slots is None \
                else [s for s in f.slots if s in self.active]
            now = time.perf_counter() - t0
            victims = []
            for slot in slots:
                ticket = self.active.pop(slot)
                self._release_slot(slot, ticket)
                self.events.append(SchedEvent(now, "fail", ticket.req.id,
                                              slot, self.step_count))
                self._reset_ticket(ticket)
                victims.append(ticket)
            st = self._chunking
            if st is not None and (f.slots is None or st.slot in f.slots):
                self._chunking = None
                self._release_slot(st.slot, st.ticket)
                self.events.append(SchedEvent(now, "fail", st.ticket.req.id,
                                              st.slot, self.step_count))
                self._reset_ticket(st.ticket)
                victims.append(st.ticket)
            victims.sort(key=lambda t: t.arrival_s)
            self.queue.extendleft(reversed(victims))

    def _admit(self, t0: float) -> None:
        s = self.sched
        while self.free and self.queue:
            ticket = self.queue[0]
            r = ticket.req
            chunked = self._chunk > 0 and r.embeds is None
            if chunked and self._chunking is not None:
                break           # one chunked prefill in flight at a time
            if s.paged:
                need = max(1, -(-len(r.prompt) // s.block_size))
                blocks = self.alloc.alloc(need)
                if blocks is None:
                    break       # pool exhausted: wait, don't over-commit
            self.queue.popleft()
            slot = self.free.pop()
            ticket.admit_seq = self._admit_seq
            self._admit_seq += 1
            if s.paged:
                ticket.blocks = blocks
                self.block_tables[slot, :len(blocks)] = blocks
            if chunked:
                ticket.slot = slot
                self._chunking = _ChunkedPrefill(
                    ticket=ticket, slot=slot,
                    cache=T.init_cache(self.cfg, 1, self._scratch_len))
            else:
                self._admit_one_shot(ticket, slot, t0)

    def _admit_one_shot(self, ticket: _Ticket, slot: int, t0: float) -> None:
        r = ticket.req
        batch = {"tokens": jnp.asarray(r.prompt[None])}
        if r.embeds is not None:
            batch["embeds"] = jnp.asarray(r.embeds[None])
        tp = time.perf_counter()
        logits, req_cache, clen = jax.block_until_ready(
            self._prefill_fn(self.params, batch))
        if self.sched.paged:
            self.cache = self._insert_paged(
                self.cache, req_cache, jnp.asarray(self.block_tables[slot]),
                jnp.int32(slot))
        else:
            self.cache = self._insert(self.cache, req_cache, jnp.int32(slot))
        ticket.prefill_s += time.perf_counter() - tp
        first = int(self._sample(logits)[0])
        self._activate(ticket, slot, first, int(clen[0]), t0)

    def _advance_chunked(self, t0: float) -> None:
        """Run ONE prefill chunk of the in-flight chunked admission, so
        prefill work interleaves with decode steps instead of stalling
        them. On the last chunk the scratch K/V is inserted into the
        shared cache and the request joins the decode batch."""
        st = self._chunking
        if st is None:
            return
        r = st.ticket.req
        c = self._chunk
        real = min(c, len(r.prompt) - st.pos)
        chunk = np.zeros((c,), np.int32)
        chunk[:real] = r.prompt[st.pos:st.pos + real]
        tp = time.perf_counter()
        logits, st.cache, _ = jax.block_until_ready(self._extend_fn(
            self.params, jnp.asarray(chunk[None]), st.cache,
            jnp.full((1,), st.pos, jnp.int32)))
        st.ticket.prefill_s += time.perf_counter() - tp
        st.pos += real
        if st.pos < len(r.prompt):
            return
        if self.sched.paged:
            self.cache = self._insert_paged(
                self.cache, st.cache, jnp.asarray(self.block_tables[st.slot]),
                jnp.int32(st.slot))
        else:
            self.cache = self._insert_sliced(self.cache, st.cache,
                                             jnp.int32(st.slot))
        first = int(self._sample(logits[:, real - 1])[0])
        self._chunking = None
        self._activate(st.ticket, st.slot, first, len(r.prompt), t0)

    def _activate(self, ticket: _Ticket, slot: int, first: int, clen: int,
                  t0: float) -> None:
        ticket.emitted.append(first)
        ticket.first_token_s = time.perf_counter() - t0
        ticket.slot = slot
        self.cache_len[slot] = clen
        self.tokens[slot] = first
        self.active[slot] = ticket
        self.events.append(SchedEvent(ticket.first_token_s, "admit",
                                      ticket.req.id, slot, self.step_count))

    def _finished(self, ticket: _Ticket) -> bool:
        return len(ticket.emitted) >= ticket.req.max_new_tokens

    def _pick_preempt_victim(self, exclude: int) -> Optional[int]:
        """Latest-admitted block holder other than ``exclude`` — an
        in-flight chunked prefill counts (it holds its prompt blocks), so
        a pool dried out by a half-prefilled prompt can still be
        reclaimed."""
        seq = {s: tk.admit_seq for s, tk in self.active.items()}
        if self._chunking is not None:
            seq[self._chunking.slot] = self._chunking.ticket.admit_seq
        seq.pop(exclude, None)
        if not seq:
            return None
        return max(seq, key=seq.get)

    def _preempt(self, slot: int, t0: float) -> None:
        """Evict-and-requeue to reclaim blocks for an older request's
        decode growth: the victim restarts from its prompt (greedy decode
        makes the re-run bit-identical), back at the queue head."""
        if self._chunking is not None and self._chunking.slot == slot:
            ticket = self._chunking.ticket
            self._chunking = None
        else:
            ticket = self.active.pop(slot)
        self._release_slot(slot, ticket)
        now = time.perf_counter() - t0
        self.events.append(SchedEvent(now, "preempt", ticket.req.id, slot,
                                      self.step_count))
        self._reset_ticket(ticket)
        self.queue.appendleft(ticket)

    def _grow_blocks(self, t0: float) -> None:
        """Paged decode growth: before a decode step, every active slot
        whose next KV write position falls in an unallocated page gets one
        fresh block; admission order wins when the pool runs dry — the
        latest-admitted other request is preempted to free blocks.
        Guaranteed to terminate because submit() validates that any single
        request's worst case fits the pool."""
        if not self.sched.paged:
            return
        bs = self.sched.block_size
        for slot in sorted(self.active,
                           key=lambda s: self.active[s].admit_seq):
            if slot not in self.active:     # preempted earlier this pass
                continue
            page = int(self.cache_len[slot]) // bs
            if self.block_tables[slot, page]:
                continue
            blocks = self.alloc.alloc(1)
            while blocks is None:
                victim = self._pick_preempt_victim(exclude=slot)
                if victim is None:
                    raise RuntimeError(
                        f"paged KV pool exhausted growing slot {slot} with "
                        f"no other active request to preempt")
                self._preempt(victim, t0)
                blocks = self.alloc.alloc(1)
            self.block_tables[slot, page] = blocks[0]
            self.active[slot].blocks.append(blocks[0])

    def _decode_step(self, t0: float) -> List[Completion]:
        done: List[Completion] = []
        # Requests satisfied by the prefill token alone never decode.
        for slot in [s for s, tk in self.active.items() if self._finished(tk)]:
            done.append(self._evict(slot, t0))
        if not self.active:
            return done
        self._grow_blocks(t0)
        if self.sched.paged:
            logits, self.cache, _ = self._decode(
                self.params, jnp.asarray(self.tokens), self.cache,
                jnp.asarray(self.cache_len), jnp.asarray(self.block_tables))
        else:
            logits, self.cache, _ = self._decode(
                self.params, jnp.asarray(self.tokens), self.cache,
                jnp.asarray(self.cache_len))
        toks = np.asarray(self._sample(logits))
        self.step_count += 1
        for slot in self.active:     # free slots keep cache_len == 0
            self.cache_len[slot] += 1
        for slot, ticket in list(self.active.items()):
            t = int(toks[slot])
            if ticket.req.eos is not None and t == ticket.req.eos:
                done.append(self._evict(slot, t0))
                continue
            ticket.emitted.append(t)
            self.tokens[slot] = t
            if self._finished(ticket):
                done.append(self._evict(slot, t0))
        return done

    def _evict(self, slot: int, t0: float) -> Completion:
        ticket = self.active.pop(slot)
        self._release_slot(slot, ticket)
        now = time.perf_counter() - t0
        self.events.append(SchedEvent(now, "evict", ticket.req.id, slot,
                                      self.step_count))
        return Completion(
            ticket.req.id, ticket.emitted, ticket.prefill_s,
            now - ticket.first_token_s, arrival_s=ticket.arrival_s,
            first_token_s=ticket.first_token_s, finish_s=now)

    def _check_invariants(self) -> None:
        """Step-boundary slot/block accounting (SchedulerConfig(debug=
        True)): a free slot has no residual length/token/table state, and
        the block pool's books balance — every held block is named by
        exactly one table entry of exactly one live ticket."""
        free = set(self.free)
        occupied = set(self.active)
        if self._chunking is not None:
            occupied.add(self._chunking.slot)
        assert not (free & occupied), (free, occupied)
        for slot in range(self.sched.max_slots):
            if slot in free:
                assert self.cache_len[slot] == 0, f"slot {slot}: stale len"
                assert self.tokens[slot] == 0, f"slot {slot}: stale token"
                if self.sched.paged:
                    assert not self.block_tables[slot].any(), \
                        f"slot {slot}: stale block table"
        if self.sched.paged:
            self.alloc.check()
            held_by_tickets: List[int] = []
            for tk in self.active.values():
                held_by_tickets.extend(tk.blocks)
            if self._chunking is not None:
                held_by_tickets.extend(self._chunking.ticket.blocks)
            assert len(held_by_tickets) == len(set(held_by_tickets)), \
                "block owned by two tickets"
            assert set(held_by_tickets) == self.alloc._held, \
                (set(held_by_tickets), self.alloc._held)
            table_entries = self.block_tables[self.block_tables > 0]
            assert len(table_entries) == len(set(table_entries.tolist())), \
                "block mapped by two table entries"
            assert set(table_entries.tolist()) == self.alloc._held
