"""Continuous-batching request scheduler: mechanism under pluggable policies.

The scheduler is the *mechanism* half of the serving stack (the policy
half lives in ``runtime.policies``; the user-facing facade is
``runtime.engine.Engine``). It owns:

* the decode loop — one decode function compiled ONCE at a fixed slot
  count ``max_slots``; requests join and leave the running batch between
  steps without recompiling;
* the KV cache, behind a ``KVLayout`` object in one of two shapes:

  - ``SlottedLayout`` (``init_cache(cfg, max_slots, max_len)``): every
    slot owns ``max_len`` dense KV rows. Simple, but a short request
    strands most of its rows for its whole lifetime;
  - ``PagedLayout`` (``SchedulerConfig(paged=True)``): global-attention
    K/V live in a shared pool of fixed-size blocks
    (``init_paged_cache``), handed out by a ``BlockAllocator`` — on
    admission for the prompt, block-by-block during decode growth —
    and addressed through per-slot block tables. A request holds only
    the blocks its context actually fills; eviction/failure *releases*
    its references (a block returns to the pool when its last reference
    drops). When the pool is exhausted, admission *waits* instead of
    over-committing (an admission ``watermark`` can additionally hold
    back the last few blocks to damp growth-preemption thrash), and
    decode growth preempts (re-queues, never drops) a victim chosen by
    the preemption policy;

* **prefix sharing** (``SchedulerConfig(prefix_cache=True)``, paged
  only): a prefix index maps hashes of block-aligned prompt prefixes to
  the resident block chains that hold their K/V. Admission matches a new
  prompt against the index, maps every fully-matched block into the
  request's table as a *shared* reference (``BlockAllocator.share``),
  and resumes prefill mid-prompt (``prefill_extend`` over the unmatched
  tail, attending over a scratch cache seeded from the shared blocks).
  Shared full blocks are never written: the boundary page (partial tail
  block, or the recomputed last prompt token) is always a private block
  written by copy-on-write at insert time, and decode growth allocates
  fresh pages past the prompt — with a defensive COW copy should a
  write ever target a block with refcount > 1. Sharing is therefore
  invisible to the decode kernels (they address K/V purely through the
  block tables) and greedy tokens are bit-identical with sharing on or
  off (tests/test_conformance_matrix.py);

* the waiting set — *which* waiting request is admitted next is the
  injected ``AdmissionPolicy``'s call (``min(waiting, key=policy.key)``,
  FIFO by default); *who* is preempted under pool pressure is the
  ``PreemptionPolicy``'s (latest-admitted by default); *how* logits
  become tokens is the ``Sampler``'s, which owns the PRNG state;
* **chunked prefill** (``SchedulerConfig(prefill_chunk=C)``): admission
  prefills a prompt in C-token ``prefill_extend`` steps interleaved with
  decode steps, so a long prompt no longer freezes every active stream
  for its whole prefill — the admission stall is bounded by one chunk;
* the request lifecycle — per-token streaming to a ``RequestHandle``,
  cancellation (a cancelled request never emits another token once
  ``cancel()`` returns), injected ``SlotFailure`` re-queue/terminate,
  and a ``finish_reason`` on every ``Completion``;
* **wall-clock deadline enforcement**
  (``SchedulerConfig(enforce_deadlines=True)``): EDF admission only
  *orders* by deadline — enforcement additionally *sheds* a request
  whose due instant (``policies.request_due_s``) passes, before prefill
  or mid-decode, completing it with ``finish_reason="timeout"`` and
  releasing its slot/blocks; a shed request never emits another token.

Per-slot ``cache_len`` is what makes the shared batch sound: the decode
attention masks every cache row at position >= cache_len[slot], so slots
holding different-length contexts (or nothing at all) coexist in one
batched step. Greedy decoding is per-request deterministic regardless of
admission order, so under greedy sampling every layout/policy
combination emits tokens bit-identical to the static-bucket path (see
tests/test_scheduler.py, tests/test_engine_lifecycle.py).

``Request``/``Completion`` live here (serving.py re-exports them) so the
engine can delegate without an import cycle.
"""
from __future__ import annotations

import heapq
import time
from collections import Counter
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.observability import (SIZE_BUCKETS, TIME_BUCKETS_S,
                                         Observability)
from repro.runtime.policies import (BatchAdmission, EvictLatest,
                                    FifoAdmission, Sampler, make_admission,
                                    make_preemption, request_due_s,
                                    sample_tokens)

__all__ = [
    "Request", "Completion", "SchedulerConfig", "SchedEvent", "SlotFailure",
    "BlockAllocator", "SlottedLayout", "PagedLayout", "ContinuousScheduler",
    "sample_tokens", "validate_request_fits", "FINISH_REASONS",
    "COUNTER_KEYS",
]

FINISH_REASONS = ("eos", "length", "cancelled", "failed", "timeout")

# stats() key schema — the typed-empty snapshot for policies with no
# continuous scheduler (Engine.stats on batch admission) must agree
COUNTER_KEYS = (
    "requests_submitted", "admissions", "evictions", "preemptions",
    "slot_failures", "cancellations", "sheds", "steps", "tokens_generated",
    "prefix_hits", "prefill_tokens_total", "prefill_tokens_saved")


@dataclass
class Request:
    id: int
    prompt: np.ndarray                      # (S,) int32
    max_new_tokens: int = 16
    eos: Optional[int] = None
    embeds: Optional[np.ndarray] = None     # VLM/audio frontend output
    # lifecycle / policy fields
    priority: int = 0                       # higher = sooner (priority policy)
    deadline_s: Optional[float] = None      # seconds from arrival (EDF)
    # how many failure/preemption restarts before the request completes
    # as "failed" instead of re-queueing; None = restart forever (the
    # pre-lifecycle behavior, and the token-identity default)
    max_restarts: Optional[int] = None


@dataclass
class Completion:
    id: int
    tokens: List[int]
    prefill_s: float
    decode_s: float
    # Continuous-scheduler timeline (engine-clock seconds; 0.0 on the
    # static path which has no per-request timeline).
    arrival_s: float = 0.0
    first_token_s: float = 0.0
    finish_s: float = 0.0
    # why the request stopped:
    # "eos" | "length" | "cancelled" | "failed" | "timeout"
    finish_reason: str = "length"
    # times the request was re-queued (slot failure or pool preemption)
    restarts: int = 0

    @property
    def ttft_s(self) -> float:
        """Time to first token (admission wait + prefill)."""
        return self.first_token_s - self.arrival_s

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s


def validate_request_fits(cfg: ModelConfig, req: Request,
                          max_len: int) -> None:
    """Shared admission check for every engine path. Decode writes KV
    rows at positions len(prompt) .. len(prompt) + max_new_tokens - 2;
    on an uncapped global-attention cache, rows past max_len would
    silently wrap the ring onto the prompt and corrupt the context.
    Sliding-window / recurrent (subquadratic) configs and explicitly
    capped caches (max_cache_len) wrap by design and are exempt."""
    if len(req.prompt) > max_len:
        raise ValueError(
            f"request {req.id}: prompt length {len(req.prompt)} exceeds "
            f"max_len {max_len}")
    if cfg.is_subquadratic_decode or cfg.max_cache_len:
        return
    need = len(req.prompt) + req.max_new_tokens - 1
    if need > max_len:
        raise ValueError(
            f"request {req.id}: prompt ({len(req.prompt)}) + "
            f"max_new_tokens ({req.max_new_tokens}) needs {need} cache "
            f"rows, exceeding max_len {max_len}")


@dataclass
class SchedulerConfig:
    max_slots: int = 8          # decode batch width (compiled once)
    max_len: int = 512          # KV rows per slot (rounded up to a whole
    #                             number of blocks in paged mode)
    greedy: bool = True
    temperature: float = 1.0
    seed: int = 0
    # paged KV cache: global-attn K/V in a shared block pool instead of
    # dense per-slot rows. num_blocks=0 sizes the pool for slotted parity
    # (max_slots full slots) + the reserved null block; size it smaller
    # to actually oversubscribe.
    paged: bool = False
    block_size: int = 16        # KV rows per block
    num_blocks: int = 0
    # admission watermark: require this many free blocks beyond the
    # prompt's need before admitting, so decode growth of the already-
    # running requests doesn't immediately preempt the newcomer back out
    # (growth-preemption thrash under oversubscription)
    watermark: int = 0
    # chunked prefill: admit prompts prefill_chunk tokens at a time,
    # interleaved with decode steps (0 = one-shot prefill). Falls back to
    # one-shot for configs/requests outside supports_chunked_prefill.
    prefill_chunk: int = 0
    # prefix sharing (paged only): admission matches new prompts against
    # resident block chains, maps fully-matched blocks into the request's
    # table (refcounted, copy-on-write on any write into a shared block)
    # and skips prefill for the matched region. Falls back silently for
    # configs outside supports_chunked_prefill (the mid-prompt resume
    # needs the position-indexed extend path).
    prefix_cache: bool = False
    # wall-clock deadline ENFORCEMENT (EDF admission only *orders* by
    # deadline): a request whose due instant (arrival_s + deadline_s,
    # see policies.request_due_s) passes is shed at the next step
    # boundary — retired from the waiting set before prefill, or evicted
    # mid-decode — completing with finish_reason="timeout" and never
    # emitting another token. Requests without a deadline are untouched.
    enforce_deadlines: bool = False
    # assert slot/block accounting invariants at every step boundary
    debug: bool = False


@dataclass
class SchedEvent:
    """Observable admission/eviction trace (asserted on by tests).
    ``kind`` is "admit" | "evict" | "fail" | "preempt" | "cancel" |
    "shed" (deadline enforcement timed the request out)."""
    t_s: float
    kind: str
    request_id: int
    slot: int
    step: int                   # decode-step counter at event time


@dataclass(frozen=True)
class SlotFailure:
    """Injected loss of decode slots at a step boundary — the scheduler-
    level view of a processing-unit failure (the unit hosting those KV
    slots went away). ``slots=None`` means every active slot: whole-unit
    loss, the companion fault-tolerance paper's server-loss scenario."""
    step: int
    slots: Optional[Tuple[int, ...]] = None


class BlockAllocator:
    """Fixed pool of KV-cache blocks with per-block reference counts.

    Physical block 0 is reserved as the null block: free slots and
    unallocated block-table entries point at it, so their (masked,
    never-read) decode writes land somewhere harmless; it is never
    allocated and never freed. ``alloc`` hands out blocks at refcount 1
    and returns None when the request can't be satisfied — the scheduler
    queues or preempts instead of over-committing. ``share`` adds a
    reference to an already-held block (prefix sharing maps one physical
    block into several requests' tables); ``release`` drops one
    reference per block and returns a block to the free pool only when
    its count reaches zero. Releasing a block that isn't held raises, so
    a double-free is an error, not silent pool corruption (``free`` is
    the legacy alias of ``release``). ``alloc(n, watermark=w)``
    additionally refuses to dip into the last ``w`` free blocks — the
    admission-time damper that keeps headroom for the running requests'
    decode growth."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError(f"need >= 2 blocks (one is the reserved null "
                             f"block), got {num_blocks}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._refs: Dict[int, int] = {}     # block -> reference count
        self.hwm = 0                    # high-water mark, blocks in use

    @property
    def capacity(self) -> int:
        return self.num_blocks - 1      # block 0 reserved

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._refs)

    def refcount(self, block: int) -> int:
        """Current reference count of ``block`` (0 = not held)."""
        return self._refs.get(block, 0)

    def alloc(self, n: int, watermark: int = 0) -> Optional[List[int]]:
        if n + watermark > len(self._free):
            return None
        blocks = [self._free.pop() for _ in range(n)]
        for b in blocks:
            self._refs[b] = 1
        self.hwm = max(self.hwm, len(self._refs))
        return blocks

    def share(self, blocks: List[int]) -> None:
        """Add one reference to each (already-held) block — the prefix-
        sharing path, mapping a resident chain into another table."""
        for b in blocks:
            if b not in self._refs:
                raise ValueError(f"block {b} shared but not held")
            self._refs[b] += 1

    def reset_hwm(self) -> None:
        """Restart high-water tracking from the current occupancy (e.g.
        between a warmup drain and a measured run)."""
        self.hwm = len(self._refs)

    def release(self, blocks: List[int]) -> List[int]:
        """Drop one reference per block; blocks whose count reaches zero
        return to the free pool. Returns the blocks actually freed (the
        caller invalidates prefix-index entries for exactly those)."""
        freed: List[int] = []
        for b in blocks:
            count = self._refs.get(b)
            if count is None:
                raise ValueError(f"block {b} freed but not held "
                                 f"(double free or foreign block)")
            if count == 1:
                del self._refs[b]
                self._free.append(b)
                freed.append(b)
            else:
                self._refs[b] = count - 1
        return freed

    # legacy name: without share() every refcount is 1 and release ==
    # the old free-exactly-once semantics
    free = release

    def check(self) -> None:
        assert len(self._free) + len(self._refs) == self.capacity, \
            (len(self._free), len(self._refs), self.capacity)
        assert 0 not in self._refs and 0 not in self._free
        assert all(c >= 1 for c in self._refs.values()), \
            "refcount dropped below 1 while held"


# ---------------------------------------------------------------------------
# KV layouts: the cache-shape half of the old monolith, one object each
# ---------------------------------------------------------------------------

class SlottedLayout:
    """Dense per-slot KV rows: slot ``i`` owns rows ``[i, :max_len]`` of
    every cache leaf. Reservation always succeeds (the rows exist by
    construction), growth never happens, release is a no-op."""

    paged = False

    def __init__(self, cfg: ModelConfig, s: SchedulerConfig, max_len: int,
                 scratch_len: int):
        self.max_len = max_len
        self.cache = T.init_cache(cfg, s.max_slots, max_len)
        self._decode = jax.jit(
            lambda p, tok, cache, clen: T.decode_step(p, cfg, tok, cache,
                                                      clen))
        self._insert = jax.jit(self._insert_impl)
        self._insert_sliced = jax.jit(self._insert_sliced_impl)

    @staticmethod
    def _insert_impl(batch_cache, req_cache, slot):
        """Write a batch=1 prefill cache into slot ``slot`` of the shared
        batch cache. Scanned-period leaves are (P, B, ...), remainder
        leaves (B, ...)."""
        scan = jax.tree.map(lambda big, small: big.at[:, slot].set(small[:, 0]),
                            batch_cache["scan"], req_cache["scan"])
        rem = jax.tree.map(lambda big, small: big.at[slot].set(small[0]),
                           batch_cache["rem"], req_cache["rem"])
        return {"scan": scan, "rem": rem}

    def _insert_sliced_impl(self, batch_cache, req_cache, slot):
        """Insert from the chunk-rounded scratch cache: keep the first
        max_len rows of every K/V leaf. Only reachable for chunked-
        prefill configs (all-global-attn), where every cache leaf has the
        row dim right after batch."""
        ml = self.max_len
        scan = jax.tree.map(
            lambda big, small: big.at[:, slot].set(small[:, 0, :ml]),
            batch_cache["scan"], req_cache["scan"])
        rem = jax.tree.map(
            lambda big, small: big.at[slot].set(small[0, :ml]),
            batch_cache["rem"], req_cache["rem"])
        return {"scan": scan, "rem": rem}

    def validate(self, req: Request) -> None:
        pass

    def try_reserve(self, req: Request) -> Optional[List[int]]:
        return []

    def bind(self, slot: int, blocks: List[int]) -> None:
        pass

    def register_prefix(self, slot: int, prompt: np.ndarray) -> None:
        pass                            # sharing is a paged-pool feature

    def insert(self, req_cache, slot: int) -> None:
        self.cache = self._insert(self.cache, req_cache, jnp.int32(slot))

    def insert_scratch(self, scratch_cache, slot: int) -> None:
        self.cache = self._insert_sliced(self.cache, scratch_cache,
                                         jnp.int32(slot))

    def decode(self, params, tokens: jax.Array, cache_len: jax.Array):
        logits, self.cache, _ = self._decode(params, tokens, self.cache,
                                             cache_len)
        return logits

    def needs_block(self, slot: int, pos: int) -> bool:
        return False

    def grow_one(self, slot: int, pos: int) -> bool:
        raise RuntimeError("slotted layout never grows")

    def release(self, slot: int) -> None:
        pass

    def kv_stats(self, s: SchedulerConfig, cfg: ModelConfig) -> Dict[str, float]:
        row = T.kv_row_bytes(cfg)
        return {"slotted_kv_reserved_bytes":
                float(s.max_slots * s.max_len * row)}

    def check(self, occupied_slots: set, max_slots: int) -> None:
        pass


@dataclass
class _PagedReservation:
    """Outcome of a paged admission reservation. ``blocks`` is the
    slot's table in page order: the first ``shared_pages`` entries are
    resident blocks mapped in by the prefix match (refcount already
    incremented), the rest freshly allocated private blocks.
    ``seed_blocks`` are the source blocks whose pool rows cover prompt
    positions ``[0, matched_rows)`` — the scratch cache is seeded from
    them so ``prefill_extend`` can resume mid-prompt. The boundary page
    (the one containing row ``matched_rows``) is always private: its
    shared rows are copied through the scratch and written at insert
    time — copy-on-write realized at admission."""
    blocks: List[int]
    shared_pages: int = 0
    seed_blocks: List[int] = field(default_factory=list)
    matched_rows: int = 0


class PagedLayout:
    """Block-pool KV: global-attention K/V in shared fixed-size blocks
    addressed through per-slot block tables; local-window / recurrent
    state stays slot-indexed inside the same cache pytree. Owns the
    allocator, the tables, the per-slot block bookkeeping (references
    released exactly once, whoever triggers it) and — with
    ``prefix_cache`` — the prefix index that lets admissions share
    resident block chains."""

    paged = True

    def __init__(self, cfg: ModelConfig, s: SchedulerConfig, max_len: int,
                 scratch_len: int):
        if cfg.max_cache_len:
            raise ValueError(
                "paged KV cache is position-indexed; max_cache_len ring "
                "caps are a slotted-path feature")
        if all(k != "attn" for k in cfg.layer_kinds):
            raise ValueError(
                f"{cfg.name}: paged KV cache pages global-attention K/V, "
                "but this config has none (local windows and recurrent "
                "state are fixed-size per slot) — use the slotted layout; "
                "its memory is already bounded")
        self.max_len = max_len
        self.block_size = s.block_size
        self.watermark = s.watermark
        self.pages_per_slot = max_len // s.block_size
        num_blocks = s.num_blocks or (s.max_slots * self.pages_per_slot + 1)
        self.alloc = BlockAllocator(num_blocks, s.block_size)
        if self.watermark >= self.alloc.capacity:
            raise ValueError(
                f"watermark {self.watermark} leaves no admissible blocks "
                f"in a pool of {self.alloc.capacity}")
        self.block_tables = np.zeros((s.max_slots, self.pages_per_slot),
                                     np.int32)
        self._slot_blocks: Dict[int, List[int]] = {}
        self.cache = T.init_paged_cache(cfg, num_blocks, s.block_size,
                                        s.max_slots, max_len=max_len)
        self._decode = jax.jit(
            lambda p, tok, cache, clen, tbl: T.decode_step(
                p, cfg, tok, cache, clen, block_tables=tbl))
        self._insert_paged = jax.jit(
            lambda c, rc, bids, slot: T.paged_insert(
                cfg, c, rc, bids, slot, block_size=s.block_size))
        # prefix sharing: the mid-prompt resume runs through
        # prefill_extend, so gate on the same support predicate as
        # chunked prefill (silent fallback, like prefill_chunk)
        self.prefix_cache = s.prefix_cache and T.supports_chunked_prefill(cfg)
        # chained hash of a block-aligned token prefix -> (resident block
        # holding its last page of K/V rows, that page's tokens). The
        # tokens are compared on every match, so a hash collision can
        # degrade to a miss but never share foreign K/V.
        self._prefix_full: Dict[int, Tuple[int, np.ndarray]] = {}
        # chained hash of a prompt's full pages -> [(tail block, prompt
        # length, tail tokens), ...] for prompts whose last page is
        # partially filled: one bucket per full-page chain, so a
        # boundary probe is a single lookup plus tail comparisons
        self._prefix_partial: Dict[int, List[Tuple[int, int,
                                                   np.ndarray]]] = {}
        self._block_keys: Dict[int, List[Tuple[str, int]]] = {}
        self._shared_pages: Dict[int, int] = {}     # slot -> shared table pages
        self._table_pending: Dict[int, List[int]] = {}  # bound, not inserted
        self._seed = jax.jit(
            lambda sc, c, bids: T.paged_seed(cfg, sc, c, bids))
        self._copy_block = jax.jit(
            lambda c, src, dst: T.paged_copy_block(cfg, c, src, dst))
        self.prefix_hits = 0            # admissions that matched a chain

    def _prompt_need(self, req: Request) -> int:
        return max(1, -(-len(req.prompt) // self.block_size))

    # -- prefix index -------------------------------------------------------

    # Keys are *chained* hashes: key_p = hash(key_{p-1}, page-p tokens),
    # so matching/registering a prompt hashes every token once — O(L) —
    # instead of re-hashing the prefix from position 0 per boundary
    # (O(L^2/bs)). Entries carry the tokens they summarize; a match
    # compares them, so a hash collision degrades to a cache miss, never
    # to sharing foreign K/V.

    @staticmethod
    def _chain(key: int, tokens: np.ndarray) -> int:
        return hash((key, np.ascontiguousarray(tokens, np.int32).tobytes()))

    def match_prefix(self, prompt: np.ndarray) -> Tuple[List[int], int]:
        """Longest resident match for ``prompt``: returns (source blocks
        covering pages 0..ceil(matched/bs)-1, matched row count). Matches
        are capped at ``len(prompt) - 1`` rows — the last prompt token is
        always recomputed so admission has logits to sample the first
        output token from."""
        bs = self.block_size
        cap = len(prompt) - 1
        src: List[int] = []
        key = 0
        while (len(src) + 1) * bs <= cap:
            page = prompt[len(src) * bs:(len(src) + 1) * bs]
            nxt = self._chain(key, page)
            entry = self._prefix_full.get(nxt)
            if entry is None or not np.array_equal(entry[1], page):
                break
            src.append(entry[0])
            key = nxt
        k = len(src)
        matched = k * bs
        # boundary extension into page k: (a) a full resident block whose
        # prefix covers this whole prompt (the capped exact-cover case),
        # else (b) a resident partial tail block with an identical fill
        if (k + 1) * bs == len(prompt):
            page = prompt[k * bs:]
            entry = self._prefix_full.get(self._chain(key, page))
            if entry is not None and np.array_equal(entry[1], page):
                return src + [entry[0]], cap
        best = None
        for blk, length, tail in self._prefix_partial.get(key, ()):
            if length <= cap and (best is None or length > best[1]) \
                    and np.array_equal(tail, prompt[k * bs:length]):
                best = (blk, length)
        if best is not None:
            return src + [best[0]], best[1]
        return src, matched

    def register_prefix(self, slot: int, prompt: np.ndarray) -> None:
        """Index ``slot``'s freshly-inserted prompt K/V so later
        admissions can share it: one entry per block-aligned prefix
        (full blocks only) plus a whole-prompt entry for a partially
        filled tail block. First writer wins; entries die with their
        block (refcount 0 -> unregister)."""
        if not self.prefix_cache:
            return
        bs = self.block_size
        table = self.block_tables[slot]
        key = 0
        for p in range(len(prompt) // bs):
            page = prompt[p * bs:(p + 1) * bs]
            key = self._chain(key, page)
            if key not in self._prefix_full:
                blk = int(table[p])
                self._prefix_full[key] = (blk, np.array(page, np.int32))
                self._block_keys.setdefault(blk, []).append(("full", key))
        if len(prompt) % bs:
            tail = np.array(prompt[-(len(prompt) % bs):], np.int32)
            bucket = self._prefix_partial.setdefault(key, [])
            if not any(length == len(prompt) and np.array_equal(t, tail)
                       for _, length, t in bucket):
                blk = int(table[len(prompt) // bs])
                bucket.append((blk, len(prompt), tail))
                self._block_keys.setdefault(blk, []).append(("partial", key))

    def _unregister(self, freed: List[int]) -> None:
        for b in freed:
            for kind, key in self._block_keys.pop(b, ()):
                if kind == "full":
                    self._prefix_full.pop(key, None)
                    continue
                bucket = self._prefix_partial.get(key)
                if bucket is not None:
                    bucket[:] = [e for e in bucket if e[0] != b]
                    if not bucket:
                        del self._prefix_partial[key]

    def validate(self, req: Request) -> None:
        """Reject requests the pool can never serve. Two separate
        bounds: the worst case must fit the *whole* pool (decode growth
        bypasses the watermark, and _grow_blocks' termination guarantee
        rests on this), and the prompt plus the watermark must fit at
        admission time (else the request waits forever)."""
        rows = max(1, len(req.prompt) + max(req.max_new_tokens - 1, 0))
        worst = -(-rows // self.block_size)
        if worst > self.alloc.capacity:
            raise ValueError(
                f"request {req.id}: needs {worst} KV blocks worst-case, "
                f"pool holds {self.alloc.capacity}")
        prompt_need = self._prompt_need(req)
        if prompt_need + self.watermark > self.alloc.capacity:
            raise ValueError(
                f"request {req.id}: prompt needs {prompt_need} KV blocks "
                f"but admission holds back watermark {self.watermark} of "
                f"{self.alloc.capacity} — can never be admitted")

    def try_reserve(self, req: Request) -> Optional[_PagedReservation]:
        """Reserve the prompt's blocks, sharing what the prefix index can
        supply: fully-matched pages map resident blocks into the table
        (one extra reference each), only the remainder is allocated. The
        boundary page is always among the private blocks (see
        ``_PagedReservation``). Returns None when the pool (minus the
        admission watermark) can't supply the private need — admission
        waits rather than over-commit."""
        if 1 + self.watermark > self.alloc.available:
            # the boundary page is always private, so no reservation can
            # succeed — skip the O(prompt) prefix match a dry pool would
            # otherwise re-run every scheduler step
            return None
        src: List[int] = []
        matched = 0
        if self.prefix_cache and req.embeds is None:
            src, matched = self.match_prefix(req.prompt)
        shared_pages = matched // self.block_size
        private = self.alloc.alloc(self._prompt_need(req) - shared_pages,
                                   watermark=self.watermark)
        if private is None:
            return None
        chain = src[:shared_pages]
        self.alloc.share(chain)
        if matched:
            self.prefix_hits += 1
        return _PagedReservation(blocks=chain + private,
                                 shared_pages=shared_pages,
                                 seed_blocks=src, matched_rows=matched)

    def bind(self, slot: int, res: _PagedReservation) -> None:
        """Take ownership of the reservation's blocks for ``slot``. The
        block table row stays zeroed (null block) until the insert
        commits it: decode steps interleave with a chunked prefill, and
        the batched decode writes every slot's (masked, never-read) K/V
        row through the table — a mid-prefill slot must direct those at
        the null block, not at a block another request shares."""
        self._slot_blocks[slot] = list(res.blocks)
        self._shared_pages[slot] = res.shared_pages
        self._table_pending[slot] = list(res.blocks)

    def _commit_table(self, slot: int) -> None:
        blocks = self._table_pending.pop(slot, None)
        if blocks is not None:
            self.block_tables[slot, :len(blocks)] = blocks

    def _insert_ids(self, slot: int) -> np.ndarray:
        """Block ids for a prompt insert: shared pages are redirected to
        the null block so their (already-resident, possibly recomputed)
        rows are dropped instead of overwriting a block another request
        reads — the write half of copy-on-write."""
        ids = self.block_tables[slot].copy()
        ids[:self._shared_pages.get(slot, 0)] = 0
        return ids

    def insert(self, req_cache, slot: int) -> None:
        self._commit_table(slot)
        self.cache = self._insert_paged(
            self.cache, req_cache, jnp.asarray(self._insert_ids(slot)),
            jnp.int32(slot))

    # the chunk-rounded scratch cache inserts through the same block
    # table; rows past the table's coverage are never addressed
    insert_scratch = insert

    def seed_scratch(self, scratch_cache, res: _PagedReservation,
                     rows: int):
        """Copy the matched prefix's K/V out of the resident pool blocks
        into the head of a batch=1 scratch cache, so ``prefill_extend``
        can resume at ``rows`` instead of position 0. Whole pages are
        copied (rows past ``rows`` in the last page are overwritten by
        the extend, or sit beyond the prompt where attention never
        reads); the source blocks are read synchronously at admission,
        so no reference is taken."""
        pages = -(-rows // self.block_size)
        return self._seed(scratch_cache, self.cache,
                          jnp.asarray(res.seed_blocks[:pages], jnp.int32))

    def decode(self, params, tokens: jax.Array, cache_len: jax.Array):
        logits, self.cache, _ = self._decode(
            params, tokens, self.cache, cache_len,
            jnp.asarray(self.block_tables))
        return logits

    def needs_block(self, slot: int, pos: int) -> bool:
        blk = int(self.block_tables[slot, pos // self.block_size])
        return not blk or self.alloc.refcount(blk) > 1

    def grow_one(self, slot: int, pos: int) -> bool:
        """Make the block covering position ``pos`` privately writable
        for ``slot``: allocate it if the table entry is empty, or — if
        the entry names a block some other request still references —
        copy-on-write it into a fresh block first. (With prompt-only
        sharing the COW branch is structurally unreachable: shared pages
        lie strictly below the prompt tail, decode writes strictly above
        it. It is kept as the safety net the sharing invariant promises.)
        Growth ignores the admission watermark — the headroom it guards
        exists precisely for the running requests' growth."""
        page = pos // self.block_size
        blocks = self.alloc.alloc(1)
        if blocks is None:
            return False
        cur = int(self.block_tables[slot, page])
        if cur:                         # shared entry: copy before write
            self.cache = self._copy_block(self.cache, jnp.int32(cur),
                                          jnp.int32(blocks[0]))
            held = self._slot_blocks[slot]
            held[held.index(cur)] = blocks[0]
            self._unregister(self.alloc.release([cur]))
        else:
            self._slot_blocks[slot].append(blocks[0])
        self.block_tables[slot, page] = blocks[0]
        return True

    def release(self, slot: int) -> None:
        blocks = self._slot_blocks.pop(slot, [])
        self._shared_pages.pop(slot, None)
        self._table_pending.pop(slot, None)
        if blocks:
            self._unregister(self.alloc.release(blocks))
        self.block_tables[slot] = 0

    def kv_stats(self, s: SchedulerConfig, cfg: ModelConfig) -> Dict[str, float]:
        row = T.kv_row_bytes(cfg)
        bs = s.block_size
        # the slotted baseline reserves the *configured* max_len, not the
        # paged path's block-rounded max_len
        return {
            "slotted_kv_reserved_bytes": float(s.max_slots * s.max_len * row),
            "paged_kv_pool_bytes": float(self.alloc.capacity * bs * row),
            "paged_kv_hwm_bytes": float(self.alloc.hwm * bs * row),
            "paged_kv_hwm_blocks": float(self.alloc.hwm),
        }

    def check(self, occupied_slots: set, max_slots: int) -> None:
        """Block books: every held block's reference count equals the
        number of table entries naming it across occupied slots (one
        per slot — a slot never maps the same block at two pages), and
        the prefix index only names held blocks."""
        self.alloc.check()
        assert set(self._slot_blocks) == occupied_slots, \
            (set(self._slot_blocks), occupied_slots)
        refs: Counter = Counter()
        for slot, blocks in self._slot_blocks.items():
            assert len(blocks) == len(set(blocks)), \
                f"slot {slot} references a block at two pages"
            entries = self.block_tables[slot][self.block_tables[slot] > 0]
            if slot in self._table_pending:     # bound, prefill in flight
                assert not entries.size, \
                    f"slot {slot}: table committed before insert"
            else:
                assert sorted(entries.tolist()) == sorted(blocks), \
                    f"slot {slot}: table and block list disagree"
            refs.update(blocks)
        assert dict(refs) == self.alloc._refs, (dict(refs), self.alloc._refs)
        for slot in range(max_slots):
            if slot not in occupied_slots:
                assert not self.block_tables[slot].any(), \
                    f"slot {slot}: stale block table"
        for blk in self._block_keys:
            assert blk in self.alloc._refs, \
                f"prefix index names freed block {blk}"


# ---------------------------------------------------------------------------
# tickets
# ---------------------------------------------------------------------------

@dataclass(eq=False)                    # identity semantics: list/backlog
class _Ticket:                          # removal must never compare prompts
    req: Request
    arrival_s: float
    submit_seq: int = -1        # submission order (admission tie-break)
    slot: int = -1
    emitted: List[int] = field(default_factory=list)
    prefill_s: float = 0.0
    first_token_s: float = 0.0
    admit_seq: int = -1         # admission order (preemption input)
    restarts: int = 0           # failure/preemption re-queues so far
    cancelled: bool = False     # set via request_cancel()
    retired: bool = False       # completed while a stale heap entry remains
    where: str = "backlog"      # backlog | queued | active | chunking | done
    handle: Any = None          # RequestHandle, when served via Engine
    # observability bookkeeping (scheduler-clock seconds)
    queued_at_s: float = 0.0    # last _enqueue instant (queue-wait metric)
    last_emit_s: float = 0.0    # last token instant (inter-token metric)


@dataclass
class _ChunkedPrefill:
    """A prompt mid-way through chunked admission: its slot (and, paged,
    its prompt blocks) are reserved; K/V accumulates in a batch=1 scratch
    cache that is inserted into the shared cache once the prompt is
    done."""
    ticket: _Ticket
    slot: int
    cache: Any
    pos: int = 0                # prompt tokens consumed so far


class ContinuousScheduler:
    """Admission queue + shared decode batch over a slot/paged KV cache.

    Policies are injected (``admission``, ``preemption``, ``sampler``) —
    names or instances from ``runtime.policies``; the defaults (FIFO,
    evict-latest, greedy) reproduce the pre-policy scheduler exactly."""

    def __init__(self, cfg: ModelConfig, params: Any,
                 sched: Optional[SchedulerConfig] = None, *,
                 failures: Optional[List[SlotFailure]] = None,
                 admission: Any = None, preemption: Any = None,
                 sampler: Optional[Sampler] = None,
                 obs: Optional[Observability] = None):
        self.cfg = cfg
        self.params = params
        self.sched = s = sched or SchedulerConfig()
        self.admission = make_admission(admission) if admission is not None \
            else FifoAdmission()
        if isinstance(self.admission, BatchAdmission):
            raise ValueError(
                "batch admission is the Engine's static-bucket path; the "
                "continuous scheduler needs an ordering policy "
                "(fifo | priority | edf)")
        self.preemption = make_preemption(preemption) \
            if preemption is not None else EvictLatest()
        self.sampler = sampler or Sampler(greedy=s.greedy,
                                          temperature=s.temperature,
                                          seed=s.seed)
        # Injected slot failures, applied at decode-step boundaries. A
        # cursor (not destructive pops) tracks what has been applied, so
        # run() is re-entrant: a second run() with new submissions still
        # sees failures the first drain never reached.
        self.failures = sorted(failures or [], key=lambda f: f.step)
        self._failure_pos = 0
        # paged mode wants a whole number of blocks per slot
        self.max_len = s.max_len if not s.paged else \
            -(-s.max_len // s.block_size) * s.block_size
        max_len = self.max_len
        self._prefill_fn = jax.jit(
            lambda p, b: T.prefill(p, cfg, b, max_len=max_len))
        # chunked prefill (gated to configs the extend path supports)
        self._chunk = s.prefill_chunk \
            if (s.prefill_chunk > 0 and T.supports_chunked_prefill(cfg)) \
            else 0
        self._scratch_len = -(-max_len // self._chunk) * self._chunk \
            if self._chunk else max_len
        self._chunking: Optional[_ChunkedPrefill] = None
        layout_cls = PagedLayout if s.paged else SlottedLayout
        self.layout = layout_cls(cfg, s, max_len, self._scratch_len)
        # prefix sharing resumes prefill mid-prompt through the same
        # extend path chunked prefill uses (the layout re-checks config
        # support, so the flag is the effective one)
        self._prefix = getattr(self.layout, "prefix_cache", False)
        if self._chunk or self._prefix:
            self._extend_fn = jax.jit(
                lambda p, tok, c, cl: T.prefill_extend(p, cfg, tok, c, cl))
        # prefill-work accounting for the serving bench: prompt tokens
        # admitted vs prompt tokens whose K/V came from a shared prefix
        self.prefill_tokens_total = 0
        self.prefill_tokens_saved = 0
        # Persistent slot state. cache_len/tokens (and the layout's block
        # tables) are host-side mirrors so admission/eviction never
        # touches device state beyond the insert.
        self.cache_len = np.zeros((s.max_slots,), np.int32)
        self.tokens = np.zeros((s.max_slots,), np.int32)
        self.free: List[int] = list(range(s.max_slots))[::-1]  # pop() -> 0,1,..
        self.active: Dict[int, _Ticket] = {}
        # waiting set: a heap keyed by the admission policy's (static,
        # total-order) key, so each admission is O(log n) instead of a
        # min-scan + remove. Cancelled entries are retired in place and
        # skipped lazily at the top; _queue_stale counts them.
        self.queue: List[tuple] = []
        self._queue_stale = 0
        self.backlog: List[_Ticket] = []  # submitted, not yet "arrived"
        self._backlog_pos = 0           # consumed-prefix cursor into backlog
        self._backlog_dirty = False
        self._admit_seq = 0
        self._submit_seq = 0
        self.events: List[SchedEvent] = []
        self.step_count = 0
        self._t0: Optional[float] = None
        self._cancel_requests: List[_Ticket] = []   # via request_cancel()
        # deadline enforcement: min-heap of (due_s, submit_seq, ticket)
        # over live deadline-carrying tickets, so the per-boundary shed
        # check is O(expired log n), not a scan of the waiting set.
        # Entries for finished tickets are skipped lazily at the top.
        self._deadline_heap: List[tuple] = []
        self.tokens_generated = 0
        # Observability (None = disabled; the hot path pays one `is None`
        # test per hook). Trace timestamps run on a *construction-epoch*
        # clock (`_obs_now`) rather than the scheduler's per-drain `_t0`:
        # `_t0` resets between drains, and a trace track's timestamps
        # must never go backwards. Metric *durations* are differences of
        # scheduler-clock stamps, so they are epoch-independent.
        self.obs = obs if (obs is not None and obs.enabled) else None
        if self.obs is not None:
            self._obs_epoch = time.perf_counter()
            self._phase: Dict[str, float] = {}
            r = self.obs.registry
            self._m = {
                "ttft": r.histogram(
                    "repro_ttft_seconds", TIME_BUCKETS_S,
                    help="arrival to first token (admission wait + prefill)"),
                "inter_token": r.histogram(
                    "repro_inter_token_seconds", TIME_BUCKETS_S,
                    help="steady-state gap between consecutive tokens "
                         "of one request"),
                "step": r.histogram(
                    "repro_step_duration_seconds", TIME_BUCKETS_S,
                    help="one scheduler iteration, boundary to boundary"),
                "queue_wait": r.histogram(
                    "repro_queue_wait_seconds", TIME_BUCKETS_S,
                    help="enqueue to admission pop"),
                "chunk": r.histogram(
                    "repro_prefill_chunk_tokens", SIZE_BUCKETS,
                    help="prompt tokens prefilled per admission/chunk step"),
                "blocks": r.histogram(
                    "repro_blocks_in_use", SIZE_BUCKETS,
                    help="paged KV blocks held, sampled each step"),
            }
            for ph in ("admission", "prefill", "decode", "sampling", "kv"):
                self._m["step_" + ph] = r.histogram(
                    f"repro_step_{ph}_seconds", TIME_BUCKETS_S,
                    help=f"per-step time inside the {ph} phase")

    # -- legacy attribute surface (tests/benches reach for these) -----------

    @property
    def alloc(self) -> Optional[BlockAllocator]:
        return getattr(self.layout, "alloc", None)

    @property
    def block_tables(self) -> Optional[np.ndarray]:
        return getattr(self.layout, "block_tables", None)

    @property
    def cache(self):
        return self.layout.cache

    @property
    def key(self) -> jax.Array:
        return self.sampler.key

    @key.setter
    def key(self, k: jax.Array) -> None:
        self.sampler.key = k

    # -- public API ---------------------------------------------------------

    def submit(self, req: Request, arrival_s: float = 0.0) -> _Ticket:
        """Queue a request for admission at ``arrival_s`` (seconds from
        drain start). Returns the internal ticket — the Engine wraps it
        in a ``RequestHandle``; direct callers can ignore it."""
        validate_request_fits(self.cfg, req, self.max_len)
        self.layout.validate(req)
        if self.done:
            # a fresh drain after a completed one starts a fresh arrival
            # epoch, whichever drive path (run() or step_once()) follows
            self._t0 = None
        ticket = _Ticket(req=req, arrival_s=arrival_s,
                         submit_seq=self._submit_seq)
        self._submit_seq += 1
        self.backlog.append(ticket)
        self._backlog_dirty = True
        if self.sched.enforce_deadlines and req.deadline_s is not None:
            heapq.heappush(self._deadline_heap,
                           (request_due_s(ticket), ticket.submit_seq, ticket))
        return ticket

    def request_cancel(self, ticket: _Ticket) -> None:
        """Flag a ticket for cancellation (the RequestHandle's path).
        Only flips a flag and records the ticket — retirement happens at
        the next step boundary (or inside the admission loop, for a
        cancel issued from another stream's token callback mid-pass), so
        this is safe to call from inside a token callback. The recorded
        list keeps the purge O(#cancelled), not O(waiting)."""
        ticket.cancelled = True
        self._cancel_requests.append(ticket)

    @property
    def done(self) -> bool:
        """True when nothing is queued, active, mid-prefill, or pending
        arrival — a step_once() now would be a no-op."""
        return (self._backlog_pos >= len(self.backlog)
                and self._waiting() == 0
                and not self.active and self._chunking is None)

    # -- waiting-set heap ---------------------------------------------------

    def _waiting(self) -> int:
        return len(self.queue) - self._queue_stale

    def _enqueue(self, ticket: _Ticket) -> None:
        """Push into the waiting heap under the admission policy's key
        (computed once — policy inputs are static per ticket); the
        submit_seq tiebreak keeps entries totally ordered without ever
        comparing tickets."""
        ticket.where = "queued"
        heapq.heappush(self.queue, (self.admission.key(ticket),
                                    ticket.submit_seq, ticket))
        if self.obs is not None:
            # only ever called while stepping, so _t0 is set
            ticket.queued_at_s = time.perf_counter() - self._t0
            self.obs.tracer.async_begin(
                "engine", "queue", f"req {ticket.req.id} queued",
                ticket.req.id, self._obs_now(),
                args={"restarts": ticket.restarts})

    def _queue_head(self) -> Optional[_Ticket]:
        """The policy's next pick, skipping entries retired by
        cancellation (lazy deletion)."""
        while self.queue and self.queue[0][2].retired:
            heapq.heappop(self.queue)
            self._queue_stale -= 1
        return self.queue[0][2] if self.queue else None

    def run(self, on_completion: Optional[Callable[[Completion], None]] = None
            ) -> List[Completion]:
        """Drain every submitted request; returns completions by id.
        ``on_completion`` (streaming mode) is invoked with each completion
        the moment its request finishes, before the drain returns.
        Re-entrant: a later run() continues from the same step counter and
        failure cursor, serving anything submitted since (arrivals are
        measured from *this* call when the scheduler is idle; a drain
        resumed mid-flight — e.g. after step-driven streaming — keeps
        the original epoch so in-flight timestamps stay coherent)."""
        if self._t0 is None or (self._waiting() == 0 and not self.active
                                and self._chunking is None):
            self._t0 = time.perf_counter()
        self._sort_pending()
        out: List[Completion] = []
        while not self.done:
            out.extend(self.step_once(on_completion))
        return sorted(out, key=lambda c: c.id)

    def step_once(self, on_completion: Optional[
            Callable[[Completion], None]] = None) -> List[Completion]:
        """One scheduler iteration: deliver arrivals, purge cancellations,
        apply due failures, advance the in-flight chunked prefill, admit,
        and (if anything is active) run one decode step. Returns the
        completions this iteration produced. Drives the step-wise Engine
        API (``RequestHandle.stream()`` pulls this between tokens)."""
        if self.obs is None:
            return self._step_impl(on_completion)
        self._phase = {}
        w0 = time.perf_counter()
        out = self._step_impl(on_completion)
        self._obs_step_done(w0, time.perf_counter())
        return out

    def _step_impl(self, on_completion: Optional[
            Callable[[Completion], None]] = None) -> List[Completion]:
        if self._t0 is None:
            self._t0 = time.perf_counter()
        if self._backlog_dirty:
            self._sort_pending()
        t0 = self._t0
        obs = self.obs
        done: List[Completion] = []
        now = time.perf_counter() - t0
        while (self._backlog_pos < len(self.backlog)
               and self.backlog[self._backlog_pos].arrival_s <= now):
            self._enqueue(self.backlog[self._backlog_pos])
            self._backlog_pos += 1
        done.extend(self._purge_cancelled(t0))
        done.extend(self._shed_expired(t0))
        if (self._waiting() == 0 and not self.active
                and self._chunking is None):
            if obs is not None:
                # an arrival-gap sleep (or a no-op boundary) is not an
                # engine step — keep it out of the step histograms
                self._phase["idle"] = 1.0
            if self._backlog_pos < len(self.backlog):
                # idle until the next arrival (virtual clock = wall
                # clock). Failures due at this step boundary still apply
                # — they must not be silently deferred past the gap.
                done.extend(self._apply_failures(t0))
                time.sleep(max(
                    0.0, self.backlog[self._backlog_pos].arrival_s - now))
            return self._deliver(done, on_completion)
        wa = time.perf_counter()
        done.extend(self._apply_failures(t0))
        self._advance_chunked(t0)
        done.extend(self._admit(t0))
        if obs is not None:
            # admission machinery = this whole region minus the prefill
            # compute the leaf helpers attributed to their own phase
            self._phase["admission"] = (
                time.perf_counter() - wa - self._phase.get("prefill", 0.0))
        if self.active:
            done.extend(self._decode_step(t0))
        if self.sched.debug:
            self._check_invariants()
        return self._deliver(done, on_completion)

    # -- observability hooks (self.obs is not None on every call) -----------

    def _obs_now(self) -> float:
        return time.perf_counter() - self._obs_epoch

    def _obs_step_done(self, w0: float, w1: float) -> None:
        ph = self._phase
        if "idle" in ph:
            return
        m = self._m
        m["step"].observe(w1 - w0)
        for k in ("admission", "prefill", "decode", "sampling", "kv"):
            if k in ph:
                m["step_" + k].observe(ph[k])
        alloc = self.alloc
        if alloc is not None:
            m["blocks"].observe(alloc.in_use)
        args = {k: round(v * 1e3, 4) for k, v in ph.items()}
        args.update(active=len(self.active), queued=self._waiting())
        self.obs.tracer.complete(
            "engine", "steps", f"step {self.step_count}",
            w0 - self._obs_epoch, w1 - w0, args=args)

    def _obs_dequeue(self, ticket: _Ticket) -> None:
        """Close the request's queued span (admission pop, queue-side
        shed/cancel — every way a ticket leaves the waiting set)."""
        self.obs.tracer.async_end(
            "engine", "queue", ticket.req.id, self._obs_now())

    def _obs_slot_begin(self, ticket: _Ticket, slot: int,
                        matched: int) -> None:
        ts = self._obs_now()
        tr = self.obs.tracer
        tr.begin("engine", f"slot {slot}", f"req {ticket.req.id}", ts,
                 args={"prompt_tokens": len(ticket.req.prompt),
                       "restarts": ticket.restarts})
        if matched:
            tr.instant("engine", f"slot {slot}", "prefix-hit", ts,
                       args={"request": ticket.req.id,
                             "matched_rows": matched})

    def _obs_prefill(self, slot: int, name: str, tp: float, dt: float,
                     tokens: int) -> None:
        """Attribute one prefill compute burst: phase accounting, the
        chunk-size histogram, and an X span nested in the slot track.
        ``tp`` is the raw perf_counter() start stamp."""
        self._phase["prefill"] = self._phase.get("prefill", 0.0) + dt
        self._m["chunk"].observe(tokens)
        self.obs.tracer.complete("engine", f"slot {slot}", name,
                                 tp - self._obs_epoch, dt,
                                 args={"tokens": tokens})

    def kv_stats(self) -> Dict[str, float]:
        """KV-memory accounting for the serving bench: what a dense
        slotted cache reserves vs what the paged pool holds / has ever
        held (high-water mark), in bytes of global-attention K/V."""
        return self.layout.kv_stats(self.sched, self.cfg)

    def stats(self) -> Dict[str, int]:
        """Lifecycle counters accumulated so far (the serving bench
        reports preemptions when sweeping the admission watermark)."""
        c = Counter(e.kind for e in self.events)
        return {"requests_submitted": self._submit_seq,
                "admissions": c["admit"], "evictions": c["evict"],
                "preemptions": c["preempt"], "slot_failures": c["fail"],
                "cancellations": c["cancel"], "sheds": c["shed"],
                "steps": self.step_count,
                "tokens_generated": self.tokens_generated,
                "prefix_hits": getattr(self.layout, "prefix_hits", 0),
                "prefill_tokens_total": self.prefill_tokens_total,
                "prefill_tokens_saved": self.prefill_tokens_saved}

    # -- internals ----------------------------------------------------------

    def _sort_pending(self) -> None:
        pending = sorted(self.backlog[self._backlog_pos:],
                         key=lambda t: t.arrival_s)
        self.backlog[self._backlog_pos:] = pending
        self._backlog_dirty = False

    @staticmethod
    def _deliver(done: List[Completion],
                 on_completion: Optional[Callable[[Completion], None]]
                 ) -> List[Completion]:
        if on_completion is not None:
            for c in done:
                on_completion(c)
        return done

    def _event(self, t_s: float, kind: str, rid: int, slot: int) -> None:
        """Record a lifecycle event; disruptions (preempt/fail/shed/
        cancel) additionally land as instant markers on the trace track
        of the slot (or the queue, for never-admitted requests)."""
        self.events.append(SchedEvent(t_s, kind, rid, slot, self.step_count))
        if self.obs is not None and kind in ("preempt", "fail",
                                             "shed", "cancel"):
            thread = f"slot {slot}" if slot >= 0 else "queue"
            self.obs.tracer.instant("engine", thread, kind, self._obs_now(),
                                    args={"request": rid})

    def _emit(self, ticket: _Ticket, tok: int) -> None:
        """Append a token and stream it to the handle. After a failure
        re-queue the greedy re-decode re-produces the already-streamed
        prefix; the handle dedups by index so consumers see each token
        once."""
        ticket.emitted.append(tok)
        self.tokens_generated += 1
        if ticket.handle is not None:
            ticket.handle._emit(len(ticket.emitted) - 1, tok)

    def _finish(self, ticket: _Ticket, reason: str, t0: float) -> Completion:
        now = time.perf_counter() - t0
        decode_s = now - ticket.first_token_s if ticket.first_token_s > 0.0 \
            else 0.0
        c = Completion(
            ticket.req.id, ticket.emitted, ticket.prefill_s, decode_s,
            arrival_s=ticket.arrival_s, first_token_s=ticket.first_token_s,
            finish_s=now, finish_reason=reason, restarts=ticket.restarts)
        ticket.where = "done"
        if ticket.handle is not None:
            ticket.handle._complete(c)
        return c

    def _release_slot(self, slot: int) -> None:
        """Return a slot (and, paged, its blocks — exactly once) to the
        free pool, zeroing every host-side mirror so no stale state
        outlives the occupancy."""
        self.free.append(slot)
        self.cache_len[slot] = 0
        self.tokens[slot] = 0
        self.layout.release(slot)
        if self.obs is not None:
            # every occupied slot opened its span at admission; closing
            # here covers every exit path (finish/evict/preempt/fail/
            # shed/cancel, mid-chunking included)
            self.obs.tracer.end("engine", f"slot {slot}", self._obs_now())

    @staticmethod
    def _reset_ticket(ticket: _Ticket) -> None:
        ticket.slot = -1
        ticket.emitted = []
        ticket.prefill_s = 0.0
        ticket.first_token_s = 0.0
        ticket.admit_seq = -1

    def _purge_cancelled(self, t0: float) -> List[Completion]:
        """Retire every cancelled request at this step boundary: waiting
        and not-yet-arrived requests complete with no tokens, an active
        slot or in-flight chunked prefill is released. cancel() itself
        only flips a flag, so a request cancelled *during* a decode step
        (from another stream's token callback) is caught before its next
        token is emitted. O(#cancelled): dispatches over the recorded
        cancel requests by ticket state, never scanning the waiting set
        (waiting entries are retired in place in the heap)."""
        out: List[Completion] = []
        if not self._cancel_requests:
            return out
        requests, self._cancel_requests = self._cancel_requests, []
        for ticket in requests:
            if ticket.where == "done":      # raced a finish; nothing to do
                continue
            if ticket.where == "backlog":
                self.backlog.remove(ticket)     # always at index >= cursor
                out.append(self._cancel_ticket(ticket, t0))
            elif ticket.where == "queued":
                ticket.retired = True           # lazy heap deletion
                self._queue_stale += 1
                if self.obs is not None:
                    self._obs_dequeue(ticket)
                out.append(self._cancel_ticket(ticket, t0))
            elif ticket.where == "active":
                out.append(self._evict(ticket.slot, t0, "cancelled",
                                       kind="cancel"))
            elif ticket.where == "chunking":
                st = self._chunking
                self._chunking = None
                self._release_slot(st.slot)
                out.append(self._cancel_ticket(ticket, t0, slot=st.slot))
        return out

    def _cancel_ticket(self, ticket: _Ticket, t0: float,
                       slot: int = -1) -> Completion:
        now = time.perf_counter() - t0
        self._event(now, "cancel", ticket.req.id, slot)
        return self._finish(ticket, "cancelled", t0)

    def _shed_expired(self, t0: float) -> List[Completion]:
        """Deadline enforcement at a step boundary: complete every
        live request whose due instant has passed with
        ``finish_reason="timeout"``. A waiting request is retired in
        place (never prefilled); an active one is evicted mid-decode —
        its slot and (paged) block references are released, and with the
        shed happening *before* the decode step, not one token is
        emitted after it. A ticket mid-chunked-prefill releases its slot
        and reserved blocks the same way. No-op unless the scheduler was
        built with ``enforce_deadlines=True`` (the heap is only fed
        then), so the conformance-matrix identity paths never pay for
        this."""
        out: List[Completion] = []
        if not self._deadline_heap:
            return out
        now = time.perf_counter() - t0
        while self._deadline_heap and self._deadline_heap[0][0] <= now:
            _, _, ticket = heapq.heappop(self._deadline_heap)
            if ticket.where == "done" or ticket.cancelled:
                continue                    # finished/cancelled first
            if ticket.where == "backlog":
                # due <= now implies arrival_s <= now, so arrivals have
                # normally been delivered already — defensive only
                self.backlog.remove(ticket)
                out.append(self._shed_ticket(ticket, t0))
            elif ticket.where == "queued":
                ticket.retired = True       # lazy heap deletion
                self._queue_stale += 1
                if self.obs is not None:
                    self._obs_dequeue(ticket)
                out.append(self._shed_ticket(ticket, t0))
            elif ticket.where == "active":
                out.append(self._evict(ticket.slot, t0, "timeout",
                                       kind="shed"))
            elif ticket.where == "chunking":
                st = self._chunking
                self._chunking = None
                self._release_slot(st.slot)
                out.append(self._shed_ticket(ticket, t0, slot=st.slot))
        return out

    def _shed_ticket(self, ticket: _Ticket, t0: float,
                     slot: int = -1) -> Completion:
        now = time.perf_counter() - t0
        self._event(now, "shed", ticket.req.id, slot)
        return self._finish(ticket, "timeout", t0)

    def _retire_from_admission(self, ticket: _Ticket,
                               t0: float) -> Completion:
        """A cancel issued mid-admission-pass (from an earlier admitted
        request's token callback) reaches the ticket before the purge
        does: complete it here so it is never prefilled — the 'not one
        more token after cancel() returns' contract covers the first
        token too."""
        heapq.heappop(self.queue)
        if self.obs is not None:
            self._obs_dequeue(ticket)
        return self._cancel_ticket(ticket, t0)

    def _requeue_or_fail(self, victims: List[_Ticket],
                         t0: float) -> List[Completion]:
        """Post-failure/preemption routing: re-queue (restart from the
        prompt) while the request has restart budget, else complete as
        "failed" with the tokens already streamed."""
        out: List[Completion] = []
        for ticket in sorted(victims, key=lambda t: t.arrival_s):
            mr = ticket.req.max_restarts
            if mr is not None and ticket.restarts >= mr:
                if ticket.handle is not None:
                    # after earlier restarts, this attempt's replay may be
                    # shorter than what was already streamed — the handle
                    # holds the longest (deduped) history, and "failed"
                    # reports the tokens streamed before the loss
                    ticket.emitted = list(ticket.handle.tokens)
                out.append(self._finish(ticket, "failed", t0))
                continue
            ticket.restarts += 1
            self._reset_ticket(ticket)
            if ticket.handle is not None and not self.sampler.greedy:
                # a stochastic re-decode can't replay the streamed prefix
                # (the key advanced), so the handle's index dedup would
                # splice two different runs — restart its stream instead
                ticket.handle._restart()
            self._enqueue(ticket)
        return out

    def _apply_failures(self, t0: float) -> List[Completion]:
        """Apply injected slot failures due at the current step boundary:
        every request on a failed slot is *re-queued, not dropped* — its
        KV state (and paged blocks) is gone, so it goes back into the
        admission queue (where its original arrival keys it ahead of
        younger work under FIFO) and is re-prefilled from its original
        prompt. A prompt mid-way through chunked prefill on a failed slot
        restarts the same way. Greedy decoding makes the re-run
        deterministic, so its final tokens — and those of every
        unaffected request, whose slots are untouched — are bit-identical
        to a failure-free run. Requests whose ``max_restarts`` budget is
        exhausted complete as "failed" instead."""
        out: List[Completion] = []
        while (self._failure_pos < len(self.failures)
               and self.failures[self._failure_pos].step <= self.step_count):
            f = self.failures[self._failure_pos]
            self._failure_pos += 1
            slots = list(self.active) if f.slots is None \
                else [s for s in f.slots if s in self.active]
            now = time.perf_counter() - t0
            victims = []
            for slot in slots:
                ticket = self.active.pop(slot)
                self._release_slot(slot)
                self._event(now, "fail", ticket.req.id, slot)
                victims.append(ticket)
            st = self._chunking
            if st is not None and (f.slots is None or st.slot in f.slots):
                self._chunking = None
                self._release_slot(st.slot)
                self._event(now, "fail", st.ticket.req.id, st.slot)
                victims.append(st.ticket)
            out.extend(self._requeue_or_fail(victims, t0))
        return out

    def _admit(self, t0: float) -> List[Completion]:
        """Admit waiting requests into free slots, in the admission
        policy's order, until slots or (paged) blocks run out. When the
        policy's next pick can't be served, admission stops — no head-of-
        line bypass, so the policy order is also the service order.
        Returns completions of requests cancelled mid-pass (by an
        earlier admission's token callback) before they were prefilled."""
        out: List[Completion] = []
        while self.free:
            ticket = self._queue_head()
            if ticket is None:
                break
            if ticket.cancelled:
                out.append(self._retire_from_admission(ticket, t0))
                continue
            if (self.sched.enforce_deadlines
                    and request_due_s(ticket) <= time.perf_counter() - t0):
                # expired while queued behind this pass's earlier
                # prefills: shed before prefill, not after
                heapq.heappop(self.queue)
                if self.obs is not None:
                    self._obs_dequeue(ticket)
                out.append(self._shed_ticket(ticket, t0))
                continue
            r = ticket.req
            chunked = self._chunk > 0 and r.embeds is None
            if chunked and self._chunking is not None:
                break           # one chunked prefill in flight at a time
            res = self.layout.try_reserve(r)
            if res is None:
                break           # pool exhausted: wait, don't over-commit
            heapq.heappop(self.queue)
            slot = self.free.pop()
            ticket.admit_seq = self._admit_seq
            self._admit_seq += 1
            self.layout.bind(slot, res)
            self.prefill_tokens_total += len(r.prompt)
            matched = getattr(res, "matched_rows", 0)
            if self.obs is not None:
                self._m["queue_wait"].observe(
                    time.perf_counter() - t0 - ticket.queued_at_s)
                self._obs_dequeue(ticket)
                self._obs_slot_begin(ticket, slot, matched)
            if chunked:
                # resume at the last chunk boundary inside the matched
                # region, so every extend step keeps the compiled chunk
                # shape (shared pages beyond the resume point still save
                # memory; their recomputed rows are dropped at insert)
                resume = (matched // self._chunk) * self._chunk
                scratch = T.init_cache(self.cfg, 1, self._scratch_len)
                if resume:
                    scratch = self.layout.seed_scratch(scratch, res, resume)
                    self.prefill_tokens_saved += resume
                ticket.slot = slot
                ticket.where = "chunking"
                self._chunking = _ChunkedPrefill(
                    ticket=ticket, slot=slot, cache=scratch, pos=resume)
            elif matched:
                self._admit_prefix_resume(ticket, slot, res, matched, t0)
            else:
                self._admit_one_shot(ticket, slot, t0)
        return out

    def _admit_one_shot(self, ticket: _Ticket, slot: int, t0: float) -> None:
        r = ticket.req
        batch = {"tokens": jnp.asarray(r.prompt[None])}
        if r.embeds is not None:
            batch["embeds"] = jnp.asarray(r.embeds[None])
        tp = time.perf_counter()
        logits, req_cache, clen = jax.block_until_ready(
            self._prefill_fn(self.params, batch))
        self.layout.insert(req_cache, slot)
        if self._prefix and r.embeds is None:
            self.layout.register_prefix(slot, r.prompt)
        dt = time.perf_counter() - tp
        ticket.prefill_s += dt
        if self.obs is not None:
            self._obs_prefill(slot, "prefill", tp, dt, len(r.prompt))
        first = int(self.sampler(logits)[0])
        self._activate(ticket, slot, first, int(clen[0]), t0)

    def _admit_prefix_resume(self, ticket: _Ticket, slot: int, res,
                             matched: int, t0: float) -> None:
        """Prefix-cache hit on the one-shot path: the matched prompt
        rows' K/V already sit in resident pool blocks (now mapped into
        this slot's table), so prefill runs only over the unmatched tail
        — a scratch cache is seeded with the matched rows and one
        ``prefill_extend`` resumes mid-prompt. The insert then writes
        only the private pages (shared pages keep the resident blocks).
        Greedy tokens are bit-identical to a full prefill: the seeded
        rows are exactly what this prompt's prefill would recompute."""
        r = ticket.req
        tp = time.perf_counter()
        scratch = T.init_cache(self.cfg, 1, self._scratch_len)
        scratch = self.layout.seed_scratch(scratch, res, matched)
        tail = jnp.asarray(np.ascontiguousarray(r.prompt[matched:],
                                                np.int32)[None])
        logits, scratch, _ = jax.block_until_ready(self._extend_fn(
            self.params, tail, scratch,
            jnp.full((1,), matched, jnp.int32)))
        self.layout.insert_scratch(scratch, slot)
        self.layout.register_prefix(slot, r.prompt)
        dt = time.perf_counter() - tp
        ticket.prefill_s += dt
        if self.obs is not None:
            self._obs_prefill(slot, "prefill (prefix resume)", tp, dt,
                              len(r.prompt) - matched)
        self.prefill_tokens_saved += matched
        first = int(self.sampler(logits[:, -1])[0])
        self._activate(ticket, slot, first, len(r.prompt), t0)

    def _advance_chunked(self, t0: float) -> None:
        """Run ONE prefill chunk of the in-flight chunked admission, so
        prefill work interleaves with decode steps instead of stalling
        them. On the last chunk the scratch K/V is inserted into the
        shared cache and the request joins the decode batch."""
        st = self._chunking
        if st is None:
            return
        r = st.ticket.req
        c = self._chunk
        real = min(c, len(r.prompt) - st.pos)
        chunk = np.zeros((c,), np.int32)
        chunk[:real] = r.prompt[st.pos:st.pos + real]
        tp = time.perf_counter()
        logits, st.cache, _ = jax.block_until_ready(self._extend_fn(
            self.params, jnp.asarray(chunk[None]), st.cache,
            jnp.full((1,), st.pos, jnp.int32)))
        dt = time.perf_counter() - tp
        st.ticket.prefill_s += dt
        if self.obs is not None:
            self._obs_prefill(st.slot, "prefill chunk", tp, dt, real)
        st.pos += real
        if st.pos < len(r.prompt):
            return
        self.layout.insert_scratch(st.cache, st.slot)
        if self._prefix and r.embeds is None:
            self.layout.register_prefix(st.slot, r.prompt)
        first = int(self.sampler(logits[:, real - 1])[0])
        self._chunking = None
        self._activate(st.ticket, st.slot, first, len(r.prompt), t0)

    def _activate(self, ticket: _Ticket, slot: int, first: int, clen: int,
                  t0: float) -> None:
        ticket.first_token_s = time.perf_counter() - t0
        ticket.slot = slot
        ticket.where = "active"
        self._emit(ticket, first)
        self.cache_len[slot] = clen
        self.tokens[slot] = first
        self.active[slot] = ticket
        self._event(ticket.first_token_s, "admit", ticket.req.id, slot)
        if self.obs is not None:
            self._m["ttft"].observe(ticket.first_token_s - ticket.arrival_s)
            ticket.last_emit_s = ticket.first_token_s

    def _finished(self, ticket: _Ticket) -> bool:
        return len(ticket.emitted) >= ticket.req.max_new_tokens

    def _pick_preempt_victim(self, exclude: int) -> Optional[int]:
        """Ask the preemption policy for a victim among current block
        holders other than ``exclude`` — an in-flight chunked prefill
        counts (it holds its prompt blocks), so a pool dried out by a
        half-prefilled prompt can still be reclaimed."""
        cands = [tk for s, tk in self.active.items() if s != exclude]
        if self._chunking is not None and self._chunking.slot != exclude:
            cands.append(self._chunking.ticket)
        if not cands:
            return None
        return self.preemption.pick(cands).slot

    def _preempt(self, slot: int, t0: float) -> Optional[Completion]:
        """Evict-and-requeue to reclaim blocks for another request's
        decode growth: the victim restarts from its prompt (greedy decode
        makes the re-run bit-identical) — or completes as "failed" if its
        restart budget is spent (the returned Completion)."""
        if self._chunking is not None and self._chunking.slot == slot:
            ticket = self._chunking.ticket
            self._chunking = None
        else:
            ticket = self.active.pop(slot)
        self._release_slot(slot)
        now = time.perf_counter() - t0
        self._event(now, "preempt", ticket.req.id, slot)
        out = self._requeue_or_fail([ticket], t0)
        return out[0] if out else None

    def _grow_blocks(self, t0: float) -> List[Completion]:
        """Paged decode growth: before a decode step, every active slot
        whose next KV write position falls in an unallocated page gets one
        fresh block; when the pool runs dry the preemption policy picks a
        victim to evict-and-requeue. Guaranteed to terminate because
        submit() validates that any single request's worst case fits the
        pool. Returns completions of victims that ran out of restart
        budget."""
        out: List[Completion] = []
        if not self.layout.paged:
            return out
        for slot in sorted(self.active,
                           key=lambda s: self.active[s].admit_seq):
            if slot not in self.active:     # preempted earlier this pass
                continue
            pos = int(self.cache_len[slot])
            if not self.layout.needs_block(slot, pos):
                continue
            while not self.layout.grow_one(slot, pos):
                victim = self._pick_preempt_victim(exclude=slot)
                if victim is None:
                    raise RuntimeError(
                        f"paged KV pool exhausted growing slot {slot} with "
                        f"no other active request to preempt")
                c = self._preempt(victim, t0)
                if c is not None:
                    out.append(c)
        return out

    def _decode_step(self, t0: float) -> List[Completion]:
        done: List[Completion] = []
        obs = self.obs
        # Requests satisfied by the prefill token alone never decode.
        for slot in [s for s, tk in self.active.items() if self._finished(tk)]:
            done.append(self._evict(slot, t0, "length"))
        if not self.active:
            return done
        wk = time.perf_counter()
        done.extend(self._grow_blocks(t0))
        if obs is not None:
            wd = time.perf_counter()
            self._phase["kv"] = self._phase.get("kv", 0.0) + (wd - wk)
        logits = self.layout.decode(self.params, jnp.asarray(self.tokens),
                                    jnp.asarray(self.cache_len))
        if obs is not None:
            # force the async dispatch so decode vs sampling attribution
            # is real; values are untouched, so greedy identity holds
            logits = jax.block_until_ready(logits)
            ws = time.perf_counter()
            self._phase["decode"] = self._phase.get("decode", 0.0) + (ws - wd)
        toks = np.asarray(self.sampler(logits))
        if obs is not None:
            now_s = time.perf_counter()
            self._phase["sampling"] = \
                self._phase.get("sampling", 0.0) + (now_s - ws)
            now_s -= t0
        self.step_count += 1
        for slot in self.active:     # free slots keep cache_len == 0
            self.cache_len[slot] += 1
        for slot, ticket in list(self.active.items()):
            if ticket.cancelled:
                # cancelled mid-step by another stream's token callback:
                # this step's token is dropped, nothing was emitted after
                # cancel() returned
                done.append(self._evict(slot, t0, "cancelled",
                                        kind="cancel"))
                continue
            t = int(toks[slot])
            if ticket.req.eos is not None and t == ticket.req.eos:
                done.append(self._evict(slot, t0, "eos"))
                continue
            self._emit(ticket, t)
            if obs is not None:
                self._m["inter_token"].observe(now_s - ticket.last_emit_s)
                ticket.last_emit_s = now_s
            self.tokens[slot] = t
            if self._finished(ticket):
                done.append(self._evict(slot, t0, "length"))
        return done

    def _evict(self, slot: int, t0: float, reason: str,
               kind: str = "evict") -> Completion:
        ticket = self.active.pop(slot)
        self._release_slot(slot)
        now = time.perf_counter() - t0
        self._event(now, kind, ticket.req.id, slot)
        return self._finish(ticket, reason, t0)

    def _check_invariants(self) -> None:
        """Step-boundary slot/block accounting (SchedulerConfig(debug=
        True)): a free slot has no residual length/token/table state, and
        the layout's books balance — every held block is named by exactly
        one table entry of exactly one occupied slot."""
        free = set(self.free)
        occupied = set(self.active)
        if self._chunking is not None:
            occupied.add(self._chunking.slot)
        assert not (free & occupied), (free, occupied)
        for slot in range(self.sched.max_slots):
            if slot in free:
                assert self.cache_len[slot] == 0, f"slot {slot}: stale len"
                assert self.tokens[slot] == 0, f"slot {slot}: stale token"
        self.layout.check(occupied, self.sched.max_slots)
