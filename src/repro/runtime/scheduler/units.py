"""Unit-aware execution core: prefill/decode disaggregation on modeled clocks.

The serving paper's collaborative-inference story, brought to the
``Engine``: the scheduler's work no longer all lands on one implicit
unit. ``ExecutionCore`` owns a set of ``UnitExecutor``s over shared
``UnitClocks`` (the same recurrence the Simulator and ``run_pipelined``
use — ``start = max(ready, clock[unit])``):

* ``PrefillExecutor`` — one per dedicated prefill unit. Every prompt
  burst (one-shot, prefix-resume tail, or one chunk of a chunked
  prefill) is charged to a prefill unit chosen by the *placement
  policy*; the finish instant becomes the slot's K/V-ready time.
* the prefill→decode **handoff** is zero-copy: the slot's KV blocks stay
  exactly where prefill wrote them in the shared pool, the decode units
  simply start addressing them through the block table. No bytes move
  and no refcount changes — the handoff is pure bookkeeping, which is
  why ``BlockAllocator``'s books balance across arbitrary
  handoff/preemption/failure interleavings
  (tests/test_kv_handoff_props.py).
* ``DecodeExecutor`` — one per pipeline stage on the decode units.
  Each decode step's batch is split into ``decode_stages`` microbatches
  that pipeline across the stage-partitioned units in the in-flight
  batching shape: stage k of microbatch m overlaps stage k−1 of
  microbatch m+1, and a microbatch's next token waits for its previous
  token to clear the last stage (the sampled token feeds back).

The clocks are *modeled* (deterministic ``sec_per_token`` costs, not
wall time): token content is bit-identical across every unit topology —
``units=1`` is the degenerate case whose makespan equals the sequential
work sum — and the modeled makespans are reproducible enough to gate in
CI (benchmarks/serving_bench.py asserts the 2-unit prefill/decode split
beats single-unit by >= 1.3x).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from repro.core.clocks import UnitClocks
from repro.runtime.observability import MODELED
from repro.runtime.policies import make_placement

__all__ = ["UnitSpec", "UnitExecutor", "PrefillExecutor", "DecodeExecutor",
           "ExecutionCore"]


@dataclass(frozen=True)
class UnitSpec:
    """One modeled processing unit. ``role`` is "prefill" | "decode";
    ``stage`` is the decode pipeline stage the unit hosts (decode only)."""
    name: str
    role: str
    stage: int = 0


class UnitExecutor:
    """Work runner bound to one unit's clock: charging it occupies the
    unit from ``max(ready, clock)`` for the given cost."""

    role = "unit"

    def __init__(self, spec: UnitSpec, clocks: UnitClocks):
        self.spec = spec
        self.clocks = clocks

    @property
    def name(self) -> str:
        return self.spec.name

    @property
    def busy_s(self) -> float:
        return self.clocks.busy_s.get(self.spec.name, 0.0)

    def charge(self, ready_s: float, cost_s: float) -> Tuple[float, float]:
        return self.clocks.charge(self.spec.name, ready_s, cost_s)


class PrefillExecutor(UnitExecutor):
    role = "prefill"


class DecodeExecutor(UnitExecutor):
    role = "decode"

    @property
    def stage(self) -> int:
        return self.spec.stage


class ExecutionCore:
    """Modeled multi-unit timeline of one scheduler's work.

    The scheduler calls in at three points: ``prefill`` for every prompt
    compute burst, ``handoff`` when a slot's finished K/V joins the
    decode batch, and ``decode_step`` once per batched decode step.
    ``release`` drops a slot's pending state on any exit path
    (finish/evict/preempt/fail/shed/cancel), so a reused slot never
    inherits a stale ready time.
    """

    def __init__(self, s, obs: Any = None):
        if s.units < 1:
            raise ValueError(f"units must be >= 1, got {s.units}")
        if not 0 <= s.prefill_units < s.units:
            raise ValueError(
                f"prefill_units must be in [0, units): {s.prefill_units} "
                f"of {s.units} (at least one unit must decode)")
        decode_units = s.units - s.prefill_units
        if not 1 <= s.decode_stages <= decode_units:
            raise ValueError(
                f"decode_stages must be in [1, {decode_units}] "
                f"(the decode-unit count), got {s.decode_stages}")
        self.decode_stages = s.decode_stages
        self.prefill_spt = s.prefill_sec_per_token
        self.decode_spt = s.decode_sec_per_token
        self.clocks = UnitClocks()
        self.units: List[UnitSpec] = []
        self.decode_execs: List[DecodeExecutor] = []
        for k in range(decode_units):
            spec = UnitSpec(f"decode{k}", "decode", stage=k)
            self.units.append(spec)
            if k < s.decode_stages:     # extra decode units stay idle
                self.decode_execs.append(DecodeExecutor(spec, self.clocks))
        self.prefill_execs: List[PrefillExecutor] = []
        for k in range(s.prefill_units):
            spec = UnitSpec(f"prefill{k}", "prefill")
            self.units.append(spec)
            self.prefill_execs.append(PrefillExecutor(spec, self.clocks))
        if not self.prefill_execs:
            # colocated prefill: prompt bursts run on the first decode
            # stage's unit (the classic single-unit serialization)
            self.prefill_execs = [
                PrefillExecutor(self.decode_execs[0].spec, self.clocks)]
        self.placement = make_placement(s.placement)
        # slot -> modeled instant its K/V is ready (prefill chain tail)
        self.slot_ready: Dict[int, float] = {}
        # microbatch lane -> finish of its previous decode step (the
        # token-feedback dependency: lane m's next token starts after
        # its previous token left the last stage)
        self._lane_done: Dict[int, float] = {}
        self.sequential_s = 0.0     # sum of all work = 1-unit makespan
        self.handoffs = 0
        self.steps = 0
        # per-unit MODELED trace tracks only for non-trivial topologies:
        # the single-unit degenerate timeline would just duplicate the
        # wall-clock step slices, and the engine's default trace stays
        # wall-clock-only (tests/test_server.py pins that)
        self._obs = obs if (obs is not None and s.units > 1
                            and getattr(obs, "enabled", False)) else None

    # -- scheduler hooks ----------------------------------------------------

    def prefill(self, slot: int, tokens: int,
                label: str = "prefill") -> float:
        """Charge one prompt compute burst of ``tokens`` to a placement-
        chosen prefill unit; returns (and records) the slot's new K/V-
        ready instant. Chunks of one slot chain: each starts no earlier
        than the previous chunk's finish."""
        if tokens <= 0:
            return self.slot_ready.get(slot, 0.0)
        ex = self.placement.pick(self.prefill_execs)
        cost = tokens * self.prefill_spt
        start, finish = ex.charge(self.slot_ready.get(slot, 0.0), cost)
        self.slot_ready[slot] = finish
        self.sequential_s += cost
        if self._obs is not None:
            self._trace(ex.name, label, start, finish - start,
                        {"slot": slot, "tokens": tokens})
        return finish

    def handoff(self, slot: int, blocks: int = 0) -> None:
        """The slot's finished K/V joins the decode batch. Zero-copy by
        construction: the KV blocks stay in the shared pool where the
        prefill unit wrote them (no bytes charged, no refcount change) —
        only the ready-time bookkeeping crosses units."""
        self.handoffs += 1
        if self._obs is not None and self.decode_execs:
            self._instant(self.decode_execs[0].name, "kv-handoff",
                          self.slot_ready.get(slot, 0.0),
                          {"slot": slot, "blocks": blocks})

    def decode_step(self, slots: List[int]) -> None:
        """Charge one batched decode step. The batch splits into
        ``decode_stages`` contiguous microbatches; microbatch m flows
        through the stage executors in order, overlapping stage k−1 of
        microbatch m+1 with stage k of microbatch m (in-flight
        batching). Slots fresh from prefill gate their microbatch on the
        handoff-ready instant."""
        if not slots:
            return
        k = len(self.decode_execs)
        self.steps += 1
        step = self.steps
        # contiguous split keeps lane membership stable step to step
        # while the active set is stable, so the token-feedback chain
        # (lane m waits for its own previous token) is honest
        per = -(-len(slots) // k)
        for m in range(k):
            lane = slots[m * per:(m + 1) * per]
            if not lane:
                break
            # a lane waits for its own previous token to clear the last
            # stage, and for any member's prefill handoff to land
            ready = self._lane_done.get(m, 0.0)
            for s in lane:
                if s in self.slot_ready:
                    ready = max(ready, self.slot_ready.pop(s))
            cost = len(lane) * self.decode_spt / k  # per-stage share
            finish = ready
            for ex in self.decode_execs:
                start, finish = ex.charge(finish, cost)
                if self._obs is not None:
                    self._trace(ex.name, f"step {step} mb{m}", start,
                                finish - start,
                                {"slots": len(lane), "stage": ex.stage})
            self._lane_done[m] = finish
            self.sequential_s += len(lane) * self.decode_spt

    def release(self, slot: int) -> None:
        """Forget a slot's pending ready time (every slot-exit path)."""
        self.slot_ready.pop(slot, None)

    # -- reporting ----------------------------------------------------------

    @property
    def makespan_s(self) -> float:
        return self.clocks.makespan_s

    @property
    def speedup(self) -> float:
        """Modeled speedup of this unit topology over serializing the
        same work on one unit."""
        m = self.clocks.makespan_s
        return self.sequential_s / m if m > 0 else 1.0

    def summary(self) -> Dict[str, Any]:
        return {
            "units": [{"name": u.name, "role": u.role, "stage": u.stage}
                      for u in self.units],
            "decode_stages": self.decode_stages,
            "modeled_makespan_s": self.clocks.makespan_s,
            "modeled_sequential_s": self.sequential_s,
            "modeled_speedup": self.speedup,
            "unit_busy_s": self.clocks.busy_s,
            "kv_handoffs": self.handoffs,
        }

    # -- per-unit trace tracks (modeled clock) ------------------------------

    def _trace(self, unit: str, name: str, start_s: float, dur_s: float,
               args: Dict[str, Any]) -> None:
        self._obs.tracer.complete("units", unit, name, start_s, dur_s,
                                  clock=MODELED, args=args)

    def _instant(self, unit: str, name: str, ts_s: float,
                 args: Dict[str, Any]) -> None:
        self._obs.tracer.instant("units", unit, name, ts_s,
                                 clock=MODELED, args=args)
