"""KV-cache layouts: the cache-shape half of the scheduler, one object each.

``SlottedLayout`` gives every slot dense ``max_len`` rows;
``PagedLayout`` pools global-attention K/V behind per-slot block tables
(allocator-backed, prefix-sharing, copy-on-write). Both compile their
decode step once — and when ``SchedulerConfig.decode_stages > 1`` they
compile the *stage-partitioned* decode step
(``transformer.decode_step_staged``), whose contiguous layer groups are
what the execution core's ``DecodeExecutor`` pipeline charges its
per-stage clocks for. The staged step composes the same layer ops in the
same order, so greedy tokens stay bit-identical to the single-stage path
(pinned by tests/test_conformance_matrix.py).
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.scheduler.allocator import BlockAllocator
from repro.runtime.scheduler.prefix_pool import VictimCache
from repro.runtime.scheduler.types import Request, SchedulerConfig

__all__ = ["SlottedLayout", "PagedLayout", "_PagedReservation"]


def _decode_fn(cfg: ModelConfig, s: SchedulerConfig, *, paged: bool):
    """The layout's compiled decode step: whole-model by default, stage-
    partitioned when the config pipelines decode across units."""
    stages = s.decode_stages
    if paged:
        if stages > 1:
            return jax.jit(lambda p, tok, cache, clen, tbl:
                           T.decode_step_staged(p, cfg, tok, cache, clen,
                                                num_stages=stages,
                                                block_tables=tbl))
        return jax.jit(lambda p, tok, cache, clen, tbl:
                       T.decode_step(p, cfg, tok, cache, clen,
                                     block_tables=tbl))
    if stages > 1:
        return jax.jit(lambda p, tok, cache, clen:
                       T.decode_step_staged(p, cfg, tok, cache, clen,
                                            num_stages=stages))
    return jax.jit(lambda p, tok, cache, clen:
                   T.decode_step(p, cfg, tok, cache, clen))


class SlottedLayout:
    """Dense per-slot KV rows: slot ``i`` owns rows ``[i, :max_len]`` of
    every cache leaf. Reservation always succeeds (the rows exist by
    construction), growth never happens, release is a no-op."""

    paged = False

    def __init__(self, cfg: ModelConfig, s: SchedulerConfig, max_len: int,
                 scratch_len: int):
        self.max_len = max_len
        self.cache = T.init_cache(cfg, s.max_slots, max_len)
        self._decode = _decode_fn(cfg, s, paged=False)
        self._insert = jax.jit(self._insert_impl)
        self._insert_sliced = jax.jit(self._insert_sliced_impl)

    @staticmethod
    def _insert_impl(batch_cache, req_cache, slot):
        """Write a batch=1 prefill cache into slot ``slot`` of the shared
        batch cache. Scanned-period leaves are (P, B, ...), remainder
        leaves (B, ...)."""
        scan = jax.tree.map(lambda big, small: big.at[:, slot].set(small[:, 0]),
                            batch_cache["scan"], req_cache["scan"])
        rem = jax.tree.map(lambda big, small: big.at[slot].set(small[0]),
                           batch_cache["rem"], req_cache["rem"])
        return {"scan": scan, "rem": rem}

    def _insert_sliced_impl(self, batch_cache, req_cache, slot):
        """Insert from the chunk-rounded scratch cache: keep the first
        max_len rows of every K/V leaf. Only reachable for chunked-
        prefill configs (all-global-attn), where every cache leaf has the
        row dim right after batch."""
        ml = self.max_len
        scan = jax.tree.map(
            lambda big, small: big.at[:, slot].set(small[:, 0, :ml]),
            batch_cache["scan"], req_cache["scan"])
        rem = jax.tree.map(
            lambda big, small: big.at[slot].set(small[0, :ml]),
            batch_cache["rem"], req_cache["rem"])
        return {"scan": scan, "rem": rem}

    def validate(self, req: Request) -> None:
        pass

    def try_reserve(self, req: Request) -> Optional[List[int]]:
        return []

    def bind(self, slot: int, blocks: List[int]) -> None:
        pass

    def register_prefix(self, slot: int, prompt: np.ndarray) -> None:
        pass                            # sharing is a paged-pool feature

    def insert(self, req_cache, slot: int) -> None:
        self.cache = self._insert(self.cache, req_cache, jnp.int32(slot))

    def insert_scratch(self, scratch_cache, slot: int) -> None:
        self.cache = self._insert_sliced(self.cache, scratch_cache,
                                         jnp.int32(slot))

    def decode(self, params, tokens: jax.Array, cache_len: jax.Array):
        logits, self.cache, _ = self._decode(params, tokens, self.cache,
                                             cache_len)
        return logits

    def needs_block(self, slot: int, pos: int) -> bool:
        return False

    def grow_one(self, slot: int, pos: int) -> bool:
        raise RuntimeError("slotted layout never grows")

    def release(self, slot: int) -> None:
        pass

    def kv_stats(self, s: SchedulerConfig, cfg: ModelConfig) -> Dict[str, float]:
        row = T.kv_row_bytes(cfg)
        return {"slotted_kv_reserved_bytes":
                float(s.max_slots * s.max_len * row)}

    def check(self, occupied_slots: set, max_slots: int) -> None:
        pass


@dataclass
class _PagedReservation:
    """Outcome of a paged admission reservation. ``blocks`` is the
    slot's table in page order: the first ``shared_pages`` entries are
    resident blocks mapped in by the prefix match (refcount already
    incremented), the rest freshly allocated private blocks.
    ``seed_blocks`` are the source blocks whose pool rows cover prompt
    positions ``[0, matched_rows)`` — the scratch cache is seeded from
    them so ``prefill_extend`` can resume mid-prompt. The boundary page
    (the one containing row ``matched_rows``) is always private: its
    shared rows are copied through the scratch and written at insert
    time — copy-on-write realized at admission."""
    blocks: List[int]
    shared_pages: int = 0
    seed_blocks: List[int] = field(default_factory=list)
    matched_rows: int = 0
    tenant: str = ""                    # prefix-cache namespace (Request.tenant)


class PagedLayout:
    """Block-pool KV: global-attention K/V in shared fixed-size blocks
    addressed through per-slot block tables; local-window / recurrent
    state stays slot-indexed inside the same cache pytree. Owns the
    allocator, the tables, the per-slot block bookkeeping (references
    released exactly once, whoever triggers it) and — with
    ``prefix_cache`` — the prefix index that lets admissions share
    resident block chains."""

    paged = True

    def __init__(self, cfg: ModelConfig, s: SchedulerConfig, max_len: int,
                 scratch_len: int):
        if cfg.max_cache_len:
            raise ValueError(
                "paged KV cache is position-indexed; max_cache_len ring "
                "caps are a slotted-path feature")
        if all(k != "attn" for k in cfg.layer_kinds):
            raise ValueError(
                f"{cfg.name}: paged KV cache pages global-attention K/V, "
                "but this config has none (local windows and recurrent "
                "state are fixed-size per slot) — use the slotted layout; "
                "its memory is already bounded")
        self.max_len = max_len
        self.block_size = s.block_size
        self.watermark = s.watermark
        self.pages_per_slot = max_len // s.block_size
        num_blocks = s.num_blocks or (s.max_slots * self.pages_per_slot + 1)
        self.alloc = BlockAllocator(num_blocks, s.block_size)
        if self.watermark >= self.alloc.capacity:
            raise ValueError(
                f"watermark {self.watermark} leaves no admissible blocks "
                f"in a pool of {self.alloc.capacity}")
        self.block_tables = np.zeros((s.max_slots, self.pages_per_slot),
                                     np.int32)
        self._slot_blocks: Dict[int, List[int]] = {}
        self.cache = T.init_paged_cache(cfg, num_blocks, s.block_size,
                                        s.max_slots, max_len=max_len)
        self._decode = _decode_fn(cfg, s, paged=True)
        self._insert_paged = jax.jit(
            lambda c, rc, bids, slot: T.paged_insert(
                cfg, c, rc, bids, slot, block_size=s.block_size))
        # prefix sharing: the mid-prompt resume runs through
        # prefill_extend, so gate on the same support predicate as
        # chunked prefill (silent fallback, like prefill_chunk)
        self.prefix_cache = s.prefix_cache and T.supports_chunked_prefill(cfg)
        # tenant-scoped prefix index: each namespace maps a chained hash
        # of a block-aligned token prefix -> (resident block holding its
        # last page of K/V rows, that page's tokens, parent chain key).
        # A request only probes its own tenant's namespace, so a hash
        # hit can never map another tenant's K/V; the tokens are also
        # compared on every match, so a collision within a namespace
        # degrades to a miss, never to sharing foreign K/V. The parent
        # key makes the index walkable for checkpoint export (hashes
        # are not invertible); empty namespaces are pruned so the outer
        # dicts are empty exactly when the index is.
        self._prefix_full: Dict[str, Dict[int, Tuple[int, np.ndarray,
                                                     int]]] = {}
        # tenant -> chained hash of a prompt's full pages -> [(tail
        # block, prompt length, tail tokens), ...] for prompts whose
        # last page is partially filled: one bucket per full-page
        # chain, so a boundary probe is a single lookup plus tail
        # comparisons
        self._prefix_partial: Dict[str, Dict[int, List[Tuple[
            int, int, np.ndarray]]]] = {}
        self._block_keys: Dict[int, List[Tuple[str, str, int]]] = {}
        self._block_tenant: Dict[int, str] = {}     # indexed block -> owner
        self._slot_tenant: Dict[int, str] = {}      # bound slot -> tenant
        self._shared_pages: Dict[int, int] = {}     # slot -> shared table pages
        self._table_pending: Dict[int, List[int]] = {}  # bound, not inserted
        self._seed = jax.jit(
            lambda sc, c, bids: T.paged_seed(cfg, sc, c, bids))
        self._copy_block = jax.jit(
            lambda c, src, dst: T.paged_copy_block(cfg, c, src, dst))
        self.prefix_hits = 0            # admissions that matched a chain
        self.victim_hits = 0            # matches that touched pooled blocks
        self.victim_evictions = 0       # pooled blocks freed under pressure
        # victim cache: released refcount-1 indexed blocks park here
        # (still held, K/V resident, index entries alive) instead of
        # freeing, so the prefix index outlives requests and drain
        # epochs; evicted only under allocation pressure
        self.victim: Optional[VictimCache] = None
        self._protect: frozenset = frozenset()      # mid-reservation blocks
        if self.prefix_cache and s.victim_cache:
            self.victim = VictimCache(
                block_bytes=s.block_size * T.kv_row_bytes(cfg),
                policy=s.victim_eviction,
                quotas=s.prefix_cache_tenants)

    def _prompt_need(self, req: Request) -> int:
        return max(1, -(-len(req.prompt) // self.block_size))

    # -- prefix index -------------------------------------------------------

    # Keys are *chained* hashes: key_p = hash(key_{p-1}, page-p tokens),
    # so matching/registering a prompt hashes every token once — O(L) —
    # instead of re-hashing the prefix from position 0 per boundary
    # (O(L^2/bs)). Entries carry the tokens they summarize; a match
    # compares them, so a hash collision degrades to a cache miss, never
    # to sharing foreign K/V.

    @staticmethod
    def _chain(key: int, tokens: np.ndarray) -> int:
        return hash((key, np.ascontiguousarray(tokens, np.int32).tobytes()))

    def match_prefix(self, prompt: np.ndarray,
                     tenant: str = "") -> Tuple[List[int], int]:
        """Longest resident match for ``prompt`` within ``tenant``'s
        namespace: returns (source blocks covering pages
        0..ceil(matched/bs)-1, matched row count). Matches are capped at
        ``len(prompt) - 1`` rows — the last prompt token is always
        recomputed so admission has logits to sample the first output
        token from."""
        bs = self.block_size
        full = self._prefix_full.get(tenant, {})
        partial = self._prefix_partial.get(tenant, {})
        cap = len(prompt) - 1
        src: List[int] = []
        key = 0
        while (len(src) + 1) * bs <= cap:
            page = prompt[len(src) * bs:(len(src) + 1) * bs]
            nxt = self._chain(key, page)
            entry = full.get(nxt)
            if entry is None or not np.array_equal(entry[1], page):
                break
            src.append(entry[0])
            key = nxt
        k = len(src)
        matched = k * bs
        # boundary extension into page k: (a) a full resident block whose
        # prefix covers this whole prompt (the capped exact-cover case),
        # else (b) a resident partial tail block with an identical fill
        if (k + 1) * bs == len(prompt):
            page = prompt[k * bs:]
            entry = full.get(self._chain(key, page))
            if entry is not None and np.array_equal(entry[1], page):
                return src + [entry[0]], cap
        best = None
        for blk, length, tail in partial.get(key, ()):
            if length <= cap and (best is None or length > best[1]) \
                    and np.array_equal(tail, prompt[k * bs:length]):
                best = (blk, length)
        if best is not None:
            return src + [best[0]], best[1]
        return src, matched

    def register_prefix(self, slot: int, prompt: np.ndarray) -> None:
        """Index ``slot``'s freshly-inserted prompt K/V so later
        admissions can share it: one entry per block-aligned prefix
        (full blocks only) plus a whole-prompt entry for a partially
        filled tail block. First writer wins; entries die with their
        block (refcount 0 -> unregister)."""
        if not self.prefix_cache:
            return
        bs = self.block_size
        tenant = self._slot_tenant.get(slot, "")
        table = self.block_tables[slot]
        key = 0
        for p in range(len(prompt) // bs):
            page = prompt[p * bs:(p + 1) * bs]
            nxt = self._chain(key, page)
            full = self._prefix_full.setdefault(tenant, {})
            if nxt not in full:
                blk = int(table[p])
                full[nxt] = (blk, np.array(page, np.int32), key)
                self._block_keys.setdefault(blk, []).append(
                    ("full", tenant, nxt))
                self._block_tenant[blk] = tenant
            key = nxt
        if len(prompt) % bs:
            tail = np.array(prompt[-(len(prompt) % bs):], np.int32)
            bucket = self._prefix_partial.setdefault(
                tenant, {}).setdefault(key, [])
            if not any(length == len(prompt) and np.array_equal(t, tail)
                       for _, length, t in bucket):
                blk = int(table[len(prompt) // bs])
                bucket.append((blk, len(prompt), tail))
                self._block_keys.setdefault(blk, []).append(
                    ("partial", tenant, key))
                self._block_tenant[blk] = tenant

    def _unregister(self, freed: List[int]) -> None:
        for b in freed:
            self._block_tenant.pop(b, None)
            for kind, tenant, key in self._block_keys.pop(b, ()):
                if kind == "full":
                    ns = self._prefix_full.get(tenant)
                    if ns is not None:
                        ns.pop(key, None)
                        if not ns:
                            del self._prefix_full[tenant]
                    continue
                tns = self._prefix_partial.get(tenant)
                bucket = tns.get(key) if tns is not None else None
                if bucket is not None:
                    bucket[:] = [e for e in bucket if e[0] != b]
                    if not bucket:
                        del tns[key]
                        if not tns:
                            del self._prefix_partial[tenant]

    def validate(self, req: Request) -> None:
        """Reject requests the pool can never serve. Two separate
        bounds: the worst case must fit the *whole* pool (decode growth
        bypasses the watermark, and _grow_blocks' termination guarantee
        rests on this), and the prompt plus the watermark must fit at
        admission time (else the request waits forever)."""
        rows = max(1, len(req.prompt) + max(req.max_new_tokens - 1, 0))
        worst = -(-rows // self.block_size)
        if worst > self.alloc.capacity:
            raise ValueError(
                f"request {req.id}: needs {worst} KV blocks worst-case, "
                f"pool holds {self.alloc.capacity}")
        prompt_need = self._prompt_need(req)
        if prompt_need + self.watermark > self.alloc.capacity:
            raise ValueError(
                f"request {req.id}: prompt needs {prompt_need} KV blocks "
                f"but admission holds back watermark {self.watermark} of "
                f"{self.alloc.capacity} — can never be admitted")

    def try_reserve(self, req: Request) -> Optional[_PagedReservation]:
        """Reserve the prompt's blocks, sharing what the prefix index can
        supply: fully-matched pages map resident blocks into the table
        (one extra reference each), only the remainder is allocated. The
        boundary page is always among the private blocks (see
        ``_PagedReservation``). Returns None when the pool (minus the
        admission watermark) can't supply the private need — admission
        waits rather than over-commit. Victim-pooled blocks count as
        available (they are reclaimable on demand); a matched chain's
        pooled blocks are *revived* — the pool's reference becomes the
        slot's — rather than re-allocated, which is what makes a hit on
        a completed request's chain (a cross-request victim hit) free."""
        victims = len(self.victim) if self.victim is not None else 0
        if 1 + self.watermark > self.alloc.available + victims:
            # the boundary page is always private, so no reservation can
            # succeed — skip the O(prompt) prefix match a dry pool would
            # otherwise re-run every scheduler step
            return None
        need = self._prompt_need(req)
        while True:
            src: List[int] = []
            matched = 0
            if self.prefix_cache and req.embeds is None:
                src, matched = self.match_prefix(req.prompt, req.tenant)
            shared_pages = matched // self.block_size
            reclaim = None
            if self.victim is not None:
                # eviction under this allocation's pressure must not eat
                # the chain it is about to share or seed from
                self._protect = frozenset(src)
                reclaim = self._reclaim
            try:
                private = self.alloc.alloc(need - shared_pages,
                                           watermark=self.watermark,
                                           reclaim=reclaim)
            finally:
                self._protect = frozenset()
            if private is not None:
                break
            if self.victim is None:
                return None
            pooled = [b for b in src if b in self.victim]
            if not pooled:
                return None
            # every evictable block is protected by this very match:
            # sacrifice the deepest matched page and retry shorter
            self.victim.drop(pooled[-1:])
            self._free_blocks(pooled[-1:])
            self.victim_evictions += 1
        chain = src[:shared_pages]
        if matched:
            self.prefix_hits += 1
        if self.victim is None:
            self.alloc.share(chain)
        else:
            if matched and any(b in self.victim for b in src):
                self.victim_hits += 1
            share = []
            for b in chain:
                if b in self.victim:
                    self.victim.revive(b)
                else:
                    share.append(b)
            self.alloc.share(share)
            if matched:
                self.victim.record_match(src)
        return _PagedReservation(blocks=chain + private,
                                 shared_pages=shared_pages,
                                 seed_blocks=src, matched_rows=matched,
                                 tenant=req.tenant)

    def bind(self, slot: int, res: _PagedReservation) -> None:
        """Take ownership of the reservation's blocks for ``slot``. The
        block table row stays zeroed (null block) until the insert
        commits it: decode steps interleave with a chunked prefill, and
        the batched decode writes every slot's (masked, never-read) K/V
        row through the table — a mid-prefill slot must direct those at
        the null block, not at a block another request shares."""
        self._slot_blocks[slot] = list(res.blocks)
        self._slot_tenant[slot] = res.tenant
        self._shared_pages[slot] = res.shared_pages
        self._table_pending[slot] = list(res.blocks)

    def _commit_table(self, slot: int) -> None:
        blocks = self._table_pending.pop(slot, None)
        if blocks is not None:
            self.block_tables[slot, :len(blocks)] = blocks

    def _insert_ids(self, slot: int) -> np.ndarray:
        """Block ids for a prompt insert: shared pages are redirected to
        the null block so their (already-resident, possibly recomputed)
        rows are dropped instead of overwriting a block another request
        reads — the write half of copy-on-write."""
        ids = self.block_tables[slot].copy()
        ids[:self._shared_pages.get(slot, 0)] = 0
        return ids

    def insert(self, req_cache, slot: int) -> None:
        self._commit_table(slot)
        self.cache = self._insert_paged(
            self.cache, req_cache, jnp.asarray(self._insert_ids(slot)),
            jnp.int32(slot))

    # the chunk-rounded scratch cache inserts through the same block
    # table; rows past the table's coverage are never addressed
    insert_scratch = insert

    def seed_scratch(self, scratch_cache, res: _PagedReservation,
                     rows: int):
        """Copy the matched prefix's K/V out of the resident pool blocks
        into the head of a batch=1 scratch cache, so ``prefill_extend``
        can resume at ``rows`` instead of position 0. Whole pages are
        copied (rows past ``rows`` in the last page are overwritten by
        the extend, or sit beyond the prompt where attention never
        reads); the source blocks are read synchronously at admission,
        so no reference is taken."""
        pages = -(-rows // self.block_size)
        return self._seed(scratch_cache, self.cache,
                          jnp.asarray(res.seed_blocks[:pages], jnp.int32))

    def decode(self, params, tokens: jax.Array, cache_len: jax.Array):
        logits, self.cache, _ = self._decode(
            params, tokens, self.cache, cache_len,
            jnp.asarray(self.block_tables))
        return logits

    def needs_block(self, slot: int, pos: int) -> bool:
        blk = int(self.block_tables[slot, pos // self.block_size])
        return not blk or self.alloc.refcount(blk) > 1

    def grow_one(self, slot: int, pos: int) -> bool:
        """Make the block covering position ``pos`` privately writable
        for ``slot``: allocate it if the table entry is empty, or — if
        the entry names a block some other request still references —
        copy-on-write it into a fresh block first. (With prompt-only
        sharing the COW branch is structurally unreachable: shared pages
        lie strictly below the prompt tail, decode writes strictly above
        it. It is kept as the safety net the sharing invariant promises.)
        Growth ignores the admission watermark — the headroom it guards
        exists precisely for the running requests' growth — but does
        reclaim victim-pooled blocks before failing into a preemption:
        idle cached prefixes must never evict a live request."""
        page = pos // self.block_size
        blocks = self.alloc.alloc(
            1, reclaim=self._reclaim if self.victim is not None else None)
        if blocks is None:
            return False
        cur = int(self.block_tables[slot, page])
        if cur:                         # shared entry: copy before write
            self.cache = self._copy_block(self.cache, jnp.int32(cur),
                                          jnp.int32(blocks[0]))
            held = self._slot_blocks[slot]
            held[held.index(cur)] = blocks[0]
            self._free_blocks([cur])
        else:
            self._slot_blocks[slot].append(blocks[0])
        self.block_tables[slot, page] = blocks[0]
        return True

    def _free_blocks(self, blocks: List[int]) -> None:
        """The one true-free path: drop a reference per block, and for
        blocks that actually leave the pool, invalidate their index
        entries and their victim-cache hit history (block ids are
        reused; a fresh allocation must not inherit a dead chain's
        heat)."""
        freed = self.alloc.release(blocks)
        self._unregister(freed)
        if self.victim is not None:
            self.victim.forget(freed)

    def _reclaim(self, shortfall: int) -> None:
        """Allocation-pressure hook (see BlockAllocator.alloc): evict up
        to ``shortfall`` victim-pooled blocks, least valuable first, so
        the retried allocation can succeed. Blocks the in-flight
        reservation matched are protected."""
        picks = self.victim.pick(shortfall, exclude=self._protect)
        if picks:
            self.victim.drop(picks)
            self._free_blocks(picks)
            self.victim_evictions += len(picks)

    def enforce_quota(self, tenant: str) -> None:
        """Evict the tenant's own pooled blocks (never another's) until
        it is back under its configured byte budget."""
        evict = self.victim.over_quota(tenant)
        if evict:
            self.victim.drop(evict)
            self._free_blocks(evict)
            self.victim_evictions += len(evict)

    def release(self, slot: int) -> None:
        """Give back a slot's blocks. Without a victim cache every last
        reference frees the block (and kills its index entries); with
        one, indexed blocks whose last reference this was transfer
        ownership to the victim pool instead — K/V resident, index
        alive — so the chain survives the request (and the drain epoch)
        until allocation pressure reclaims it."""
        blocks = self._slot_blocks.pop(slot, [])
        self._shared_pages.pop(slot, None)
        self._table_pending.pop(slot, None)
        tenant = self._slot_tenant.pop(slot, "")
        if blocks:
            if self.victim is not None:
                keep = [(self._block_tenant.get(b, tenant), page, b)
                        for page, b in enumerate(blocks)
                        if self.alloc.refcount(b) == 1
                        and b in self._block_keys]
                keepset = {b for _, _, b in keep}
                rest = [b for b in blocks if b not in keepset]
            else:
                keep, rest = [], blocks
            if rest:
                self._free_blocks(rest)
            if keep:
                self.victim.admit(keep)
                for t in {t for t, _, _ in keep}:
                    self.enforce_quota(t)
        self.block_tables[slot] = 0

    def kv_stats(self, s: SchedulerConfig, cfg: ModelConfig) -> Dict[str, float]:
        row = T.kv_row_bytes(cfg)
        bs = s.block_size
        # the slotted baseline reserves the *configured* max_len, not the
        # paged path's block-rounded max_len
        out = {
            "slotted_kv_reserved_bytes": float(s.max_slots * s.max_len * row),
            "paged_kv_pool_bytes": float(self.alloc.capacity * bs * row),
            "paged_kv_hwm_bytes": float(self.alloc.hwm * bs * row),
            "paged_kv_hwm_blocks": float(self.alloc.hwm),
        }
        if self.victim is not None:
            out["victim_kv_blocks"] = float(len(self.victim))
            out["victim_kv_bytes"] = float(self.victim.total_bytes)
        return out

    def prefix_cache_stats(self) -> Dict[str, object]:
        """Cache-service gauges for Engine.snapshot / the server's
        /status: hit counters plus victim-pool occupancy, per tenant."""
        out: Dict[str, object] = {
            "enabled": self.prefix_cache,
            "victim_cache": self.victim is not None,
            "prefix_hits": self.prefix_hits,
            "victim_hits": self.victim_hits,
            "victim_evictions": self.victim_evictions,
        }
        if self.victim is not None:
            out["victim_blocks"] = len(self.victim)
            out["victim_bytes"] = self.victim.total_bytes
            out["per_tenant_bytes"] = self.victim.per_tenant_bytes()
            out["tenant_quotas"] = dict(self.victim.quotas)
        return out

    def check(self, occupied_slots: set, max_slots: int) -> None:
        """Block books: every held block's reference count equals the
        number of table entries naming it across occupied slots (one
        per slot — a slot never maps the same block at two pages) plus
        one for victim-pool ownership — a block is never simultaneously
        live and pooled — and the prefix index only names held
        blocks."""
        self.alloc.check()
        assert set(self._slot_blocks) == occupied_slots, \
            (set(self._slot_blocks), occupied_slots)
        refs: Counter = Counter()
        for slot, blocks in self._slot_blocks.items():
            assert len(blocks) == len(set(blocks)), \
                f"slot {slot} references a block at two pages"
            entries = self.block_tables[slot][self.block_tables[slot] > 0]
            if slot in self._table_pending:     # bound, prefill in flight
                assert not entries.size, \
                    f"slot {slot}: table committed before insert"
            else:
                assert sorted(entries.tolist()) == sorted(blocks), \
                    f"slot {slot}: table and block list disagree"
            refs.update(blocks)
        if self.victim is not None:
            for blk in self.victim.blocks:
                assert blk not in refs, \
                    f"block {blk} simultaneously live and in victim pool"
                assert blk in self._block_keys, \
                    f"victim pool holds unindexed block {blk}"
                refs[blk] += 1
        assert dict(refs) == self.alloc._refs, (dict(refs), self.alloc._refs)
        for slot in range(max_slots):
            if slot not in occupied_slots:
                assert not self.block_tables[slot].any(), \
                    f"slot {slot}: stale block table"
        for blk, keys in self._block_keys.items():
            assert blk in self.alloc._refs, \
                f"prefix index names freed block {blk}"
            assert all(t == self._block_tenant.get(blk) for _, t, _ in keys), \
                f"block {blk} indexed under two tenants"
