"""Request/completion/config dataclasses shared across the scheduler package.

Everything here is plain data: the request the user submits, the
completion they get back, the scheduler's configuration (including the
multi-unit execution-core knobs), the observable event log entries, and
the internal ticket/chunked-prefill bookkeeping records. No jax.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.models.config import ModelConfig

FINISH_REASONS = ("eos", "length", "cancelled", "failed", "timeout",
                  "local_fallback")

# stats() key schema — the typed-empty snapshot for policies with no
# continuous scheduler (Engine.stats on batch admission) must agree
COUNTER_KEYS = (
    "requests_submitted", "admissions", "evictions", "preemptions",
    "slot_failures", "cancellations", "sheds", "steps", "tokens_generated",
    "prefix_hits", "victim_hits", "victim_evictions",
    "prefill_tokens_total", "prefill_tokens_saved")


@dataclass
class Request:
    id: int
    prompt: np.ndarray                      # (S,) int32
    max_new_tokens: int = 16
    eos: Optional[int] = None
    embeds: Optional[np.ndarray] = None     # VLM/audio frontend output
    # lifecycle / policy fields
    priority: int = 0                       # higher = sooner (priority policy)
    deadline_s: Optional[float] = None      # seconds from arrival (EDF)
    # how many failure/preemption restarts before the request completes
    # as "failed" instead of re-queueing; None = restart forever (the
    # pre-lifecycle behavior, and the token-identity default)
    max_restarts: Optional[int] = None
    # prefix-cache namespace: a request only ever matches (and registers)
    # prefix chains under its own tenant, so a hash hit can never map
    # another tenant's K/V. "" is the default shared namespace.
    tenant: str = ""


@dataclass
class Completion:
    id: int
    tokens: List[int]
    prefill_s: float
    decode_s: float
    # Continuous-scheduler timeline (engine-clock seconds; 0.0 on the
    # static path which has no per-request timeline).
    arrival_s: float = 0.0
    first_token_s: float = 0.0
    finish_s: float = 0.0
    # why the request stopped:
    # "eos" | "length" | "cancelled" | "failed" | "timeout" |
    # "local_fallback" (a TieredEngine answered locally because the
    # escalation link was down and the deadline could not wait)
    finish_reason: str = "length"
    # times the request was re-queued (slot failure or pool preemption)
    restarts: int = 0

    @property
    def ttft_s(self) -> float:
        """Time to first token (admission wait + prefill)."""
        return self.first_token_s - self.arrival_s

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s


def validate_request_fits(cfg: ModelConfig, req: Request,
                          max_len: int) -> None:
    """Shared admission check for every engine path. Decode writes KV
    rows at positions len(prompt) .. len(prompt) + max_new_tokens - 2;
    on an uncapped global-attention cache, rows past max_len would
    silently wrap the ring onto the prompt and corrupt the context.
    Sliding-window / recurrent (subquadratic) configs and explicitly
    capped caches (max_cache_len) wrap by design and are exempt."""
    if len(req.prompt) > max_len:
        raise ValueError(
            f"request {req.id}: prompt length {len(req.prompt)} exceeds "
            f"max_len {max_len}")
    if cfg.is_subquadratic_decode or cfg.max_cache_len:
        return
    need = len(req.prompt) + req.max_new_tokens - 1
    if need > max_len:
        raise ValueError(
            f"request {req.id}: prompt ({len(req.prompt)}) + "
            f"max_new_tokens ({req.max_new_tokens}) needs {need} cache "
            f"rows, exceeding max_len {max_len}")


@dataclass
class SchedulerConfig:
    max_slots: int = 8          # decode batch width (compiled once)
    max_len: int = 512          # KV rows per slot (rounded up to a whole
    #                             number of blocks in paged mode)
    greedy: bool = True
    temperature: float = 1.0
    seed: int = 0
    # paged KV cache: global-attn K/V in a shared block pool instead of
    # dense per-slot rows. num_blocks=0 sizes the pool for slotted parity
    # (max_slots full slots) + the reserved null block; size it smaller
    # to actually oversubscribe.
    paged: bool = False
    block_size: int = 16        # KV rows per block
    num_blocks: int = 0
    # admission watermark: require this many free blocks beyond the
    # prompt's need before admitting, so decode growth of the already-
    # running requests doesn't immediately preempt the newcomer back out
    # (growth-preemption thrash under oversubscription)
    watermark: int = 0
    # chunked prefill: admit prompts prefill_chunk tokens at a time,
    # interleaved with decode steps (0 = one-shot prefill). Falls back to
    # one-shot for configs/requests outside supports_chunked_prefill.
    prefill_chunk: int = 0
    # prefix sharing (paged only): admission matches new prompts against
    # resident block chains, maps fully-matched blocks into the request's
    # table (refcounted, copy-on-write on any write into a shared block)
    # and skips prefill for the matched region. Falls back silently for
    # configs outside supports_chunked_prefill (the mid-prompt resume
    # needs the position-indexed extend path).
    prefix_cache: bool = False
    # victim cache (requires prefix_cache): when a request completes,
    # its refcount-1 indexed blocks move to a reclaimable victim pool
    # instead of freeing, so the prefix index outlives the request and
    # cold admissions (even across drain epochs) still hit. Victim
    # blocks are evicted — weighted-LRU order — only under allocation
    # pressure, and count as available for admission.
    victim_cache: bool = False
    # eviction order among victim blocks: a name from
    # policies.VICTIM_EVICTION_POLICIES ("lru" | "weighted-lru") or a
    # policy instance
    victim_eviction: Any = "weighted-lru"
    # per-tenant victim-pool byte budgets ({tenant: bytes}); a tenant
    # that exceeds its budget evicts only its own chains (oldest first),
    # never another tenant's. Unlisted tenants are unbudgeted.
    prefix_cache_tenants: Optional[Dict[str, int]] = None
    # wall-clock deadline ENFORCEMENT (EDF admission only *orders* by
    # deadline): a request whose due instant (arrival_s + deadline_s,
    # see policies.request_due_s) passes is shed at the next step
    # boundary — retired from the waiting set before prefill, or evicted
    # mid-decode — completing with finish_reason="timeout" and never
    # emitting another token. Requests without a deadline are untouched.
    enforce_deadlines: bool = False
    # --- multi-unit execution core (modeled per-unit clocks) -----------
    # units: processing units the execution core schedules over.
    # prefill_units of them are dedicated to chunked/one-shot prefill
    # (0 = prefill shares the decode units — the classic colocated
    # setup); the remaining units run decode, with decode microbatches
    # pipelined across decode_stages stage-partitioned units (1 = whole-
    # model decode steps). The clocks are MODELED: token content is
    # bit-identical to the single-unit path in every configuration —
    # units=1/prefill_units=0/decode_stages=1 is the degenerate case
    # with pure accounting and no behavior change at all.
    units: int = 1
    prefill_units: int = 0
    decode_stages: int = 1
    # which prefill unit takes the next prompt burst: a name from
    # policies.PLACEMENT_POLICIES ("round-robin" | "least-loaded") or a
    # policy instance
    placement: Any = "round-robin"
    # deterministic modeled cost per prompt/decode token on one unit —
    # what the per-unit clocks charge (benches compare makespans across
    # unit topologies, so costs must not depend on wall-clock noise)
    prefill_sec_per_token: float = 1e-4
    decode_sec_per_token: float = 1e-4
    # wall-clock device-speed handicap: sleep this long after every
    # non-idle step. Emulates serving on a slower device (an edge
    # endpoint tier vs a server tier sharing one host, as in the
    # hierarchical-serving bench) — token content is untouched, only
    # real elapsed time stretches.
    step_delay_s: float = 0.0
    # assert slot/block accounting invariants at every step boundary
    debug: bool = False


@dataclass
class SchedEvent:
    """Observable admission/eviction trace (asserted on by tests).
    ``kind`` is "admit" | "evict" | "fail" | "preempt" | "cancel" |
    "shed" (deadline enforcement timed the request out)."""
    t_s: float
    kind: str
    request_id: int
    slot: int
    step: int                   # decode-step counter at event time


@dataclass(frozen=True)
class SlotFailure:
    """Injected loss of decode slots at a step boundary — the scheduler-
    level view of a processing-unit failure (the unit hosting those KV
    slots went away). ``slots=None`` means every active slot: whole-unit
    loss, the companion fault-tolerance paper's server-loss scenario."""
    step: int
    slots: Optional[Tuple[int, ...]] = None


@dataclass(eq=False)                    # identity semantics: list/backlog
class _Ticket:                          # removal must never compare prompts
    req: Request
    arrival_s: float
    submit_seq: int = -1        # submission order (admission tie-break)
    slot: int = -1
    emitted: List[int] = field(default_factory=list)
    prefill_s: float = 0.0
    first_token_s: float = 0.0
    admit_seq: int = -1         # admission order (preemption input)
    restarts: int = 0           # failure/preemption re-queues so far
    cancelled: bool = False     # set via request_cancel()
    retired: bool = False       # completed while a stale heap entry remains
    where: str = "backlog"      # backlog | queued | active | chunking | done
    handle: Any = None          # RequestHandle, when served via Engine
    # observability bookkeeping (scheduler-clock seconds)
    queued_at_s: float = 0.0    # last _enqueue instant (queue-wait metric)
    last_emit_s: float = 0.0    # last token instant (inter-token metric)


@dataclass
class _ChunkedPrefill:
    """A prompt mid-way through chunked admission: its slot (and, paged,
    its prompt blocks) are reserved; K/V accumulates in a batch=1 scratch
    cache that is inserted into the shared cache once the prompt is
    done."""
    ticket: _Ticket
    slot: int
    cache: Any
    pos: int = 0                # prompt tokens consumed so far
