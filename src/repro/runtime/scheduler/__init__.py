"""Continuous-batching scheduler package.

Formerly the single module ``repro.runtime.scheduler``; now split by
concern around the multi-unit execution core:

* ``types`` — ``Request`` / ``Completion`` / ``SchedulerConfig`` /
  ``SchedEvent`` / ``SlotFailure`` and the admission-time validators;
* ``allocator`` — the refcounted fixed-pool ``BlockAllocator``;
* ``layouts`` — ``SlottedLayout`` / ``PagedLayout`` KV-cache surgery
  (block tables, prefix sharing, copy-on-write);
* ``prefix_pool`` — ``VictimCache``: retention of released prefix
  chains (tenant quotas, weighted-LRU eviction) and its checkpoint
  save/restore;
* ``prefill`` — one-shot / prefix-resume / chunked prompt admission;
* ``units`` — ``ExecutionCore``: unit-aware executors on modeled
  clocks (prefill/decode disaggregation, pipelined in-flight decode);
* ``core`` — ``ContinuousScheduler``, the loop tying them together.

**Migration note:** every name the old module exported is re-exported
here, so ``from repro.runtime.scheduler import ContinuousScheduler``
(and every other pre-split import) keeps working unchanged. New code
can import from the submodules directly.
"""
from repro.runtime.policies import sample_tokens
from repro.runtime.scheduler.allocator import BlockAllocator
from repro.runtime.scheduler.core import ContinuousScheduler
from repro.runtime.scheduler.layouts import (PagedLayout, SlottedLayout,
                                             _PagedReservation)
from repro.runtime.scheduler.prefix_pool import (VictimCache,
                                                 restore_victim_cache,
                                                 save_victim_cache)
from repro.runtime.scheduler.types import (COUNTER_KEYS, FINISH_REASONS,
                                           Completion, Request, SchedEvent,
                                           SchedulerConfig, SlotFailure,
                                           _ChunkedPrefill, _Ticket,
                                           validate_request_fits)
from repro.runtime.scheduler.units import (DecodeExecutor, ExecutionCore,
                                           PrefillExecutor, UnitExecutor,
                                           UnitSpec)

__all__ = [
    # pre-split surface (unchanged)
    "Request", "Completion", "SchedulerConfig", "SchedEvent", "SlotFailure",
    "BlockAllocator", "SlottedLayout", "PagedLayout", "ContinuousScheduler",
    "sample_tokens", "validate_request_fits", "FINISH_REASONS",
    "COUNTER_KEYS",
    # multi-unit execution core
    "UnitSpec", "UnitExecutor", "PrefillExecutor", "DecodeExecutor",
    "ExecutionCore",
    # prefix-cache service
    "VictimCache", "save_victim_cache", "restore_victim_cache",
]
