"""Refcounted fixed-pool block allocator for the paged KV cache.

The allocator is the ownership ledger the whole multi-unit story hangs
off: prefill writes a slot's K/V into blocks held at refcount >= 1,
prefix sharing maps one physical block into several tables (``share``),
and the prefill→decode handoff is *zero-copy* precisely because the
blocks never move — the decode units read the same pool pages the
prefill unit wrote, and the refcount books don't change at the handoff
(tests/test_kv_handoff_props.py pins this under arbitrary
handoff/preemption/failure interleavings).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

__all__ = ["BlockAllocator"]


class BlockAllocator:
    """Fixed pool of KV-cache blocks with per-block reference counts.

    Physical block 0 is reserved as the null block: free slots and
    unallocated block-table entries point at it, so their (masked,
    never-read) decode writes land somewhere harmless; it is never
    allocated and never freed. ``alloc`` hands out blocks at refcount 1
    and returns None when the request can't be satisfied — the scheduler
    queues or preempts instead of over-committing. ``share`` adds a
    reference to an already-held block (prefix sharing maps one physical
    block into several requests' tables); ``release`` drops one
    reference per block and returns a block to the free pool only when
    its count reaches zero. Releasing a block that isn't held raises, so
    a double-free is an error, not silent pool corruption (``free`` is
    the legacy alias of ``release``). ``alloc(n, watermark=w)``
    additionally refuses to dip into the last ``w`` free blocks — the
    admission-time damper that keeps headroom for the running requests'
    decode growth."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError(f"need >= 2 blocks (one is the reserved null "
                             f"block), got {num_blocks}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._refs: Dict[int, int] = {}     # block -> reference count
        self.hwm = 0                    # high-water mark, blocks in use

    @property
    def capacity(self) -> int:
        return self.num_blocks - 1      # block 0 reserved

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._refs)

    def refcount(self, block: int) -> int:
        """Current reference count of ``block`` (0 = not held)."""
        return self._refs.get(block, 0)

    def alloc(self, n: int, watermark: int = 0,
              reclaim: Optional[Callable[[int], None]] = None,
              ) -> Optional[List[int]]:
        """``reclaim``, when given, is invoked with the block shortfall
        before giving up — the victim-cache hook: the layout evicts up
        to that many reclaimable (refcount-1, request-completed) prefix
        blocks back into the free pool, and the allocation is retried.
        Victim blocks therefore never block an admission, but are only
        ever evicted under exactly this allocation pressure."""
        if n + watermark > len(self._free) and reclaim is not None:
            reclaim(n + watermark - len(self._free))
        if n + watermark > len(self._free):
            return None
        blocks = [self._free.pop() for _ in range(n)]
        for b in blocks:
            self._refs[b] = 1
        self.hwm = max(self.hwm, len(self._refs))
        return blocks

    def share(self, blocks: List[int]) -> None:
        """Add one reference to each (already-held) block — the prefix-
        sharing path, mapping a resident chain into another table."""
        for b in blocks:
            if b not in self._refs:
                raise ValueError(f"block {b} shared but not held")
            self._refs[b] += 1

    def reset_hwm(self) -> None:
        """Restart high-water tracking from the current occupancy (e.g.
        between a warmup drain and a measured run)."""
        self.hwm = len(self._refs)

    def release(self, blocks: List[int]) -> List[int]:
        """Drop one reference per block; blocks whose count reaches zero
        return to the free pool. Returns the blocks actually freed (the
        caller invalidates prefix-index entries for exactly those)."""
        freed: List[int] = []
        for b in blocks:
            count = self._refs.get(b)
            if count is None:
                raise ValueError(f"block {b} freed but not held "
                                 f"(double free or foreign block)")
            if count == 1:
                del self._refs[b]
                self._free.append(b)
                freed.append(b)
            else:
                self._refs[b] = count - 1
        return freed

    # legacy name: without share() every refcount is 1 and release ==
    # the old free-exactly-once semantics
    free = release

    def check(self) -> None:
        assert len(self._free) + len(self._refs) == self.capacity, \
            (len(self._free), len(self._refs), self.capacity)
        assert 0 not in self._refs and 0 not in self._free
        assert all(c >= 1 for c in self._refs.values()), \
            "refcount dropped below 1 while held"
