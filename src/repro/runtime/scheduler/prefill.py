"""Prompt prefill paths: one-shot, prefix-resume, and chunked admission.

Free functions over the ``ContinuousScheduler`` (they are the prefill
half of its admission machinery, split out so the core loop module stays
within the runtime module-size budget). Every compute burst here is also
charged to the execution core's placement-chosen prefill unit
(``sched.core.prefill``) — the modeled clock side of prefill/decode
disaggregation; the real compute below is what the clocks model.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.runtime.scheduler.types import _Ticket

__all__ = ["admit_one_shot", "admit_prefix_resume", "advance_chunked"]


def admit_one_shot(sched, ticket: _Ticket, slot: int, t0: float) -> None:
    """Whole-prompt prefill at admission: compute, insert into the
    shared cache, register the prefix, sample the first token."""
    r = ticket.req
    batch = {"tokens": jnp.asarray(r.prompt[None])}
    if r.embeds is not None:
        batch["embeds"] = jnp.asarray(r.embeds[None])
    tp = time.perf_counter()
    logits, req_cache, clen = jax.block_until_ready(
        sched._prefill_fn(sched.params, batch))
    sched.layout.insert(req_cache, slot)
    if sched._prefix and r.embeds is None:
        sched.layout.register_prefix(slot, r.prompt)
    dt = time.perf_counter() - tp
    ticket.prefill_s += dt
    sched.core.prefill(slot, len(r.prompt))
    if sched.obs is not None:
        sched._obs_prefill(slot, "prefill", tp, dt, len(r.prompt))
    first = int(sched.sampler(logits)[0])
    sched._activate(ticket, slot, first, int(clen[0]), t0)


def admit_prefix_resume(sched, ticket: _Ticket, slot: int, res,
                        matched: int, t0: float) -> None:
    """Prefix-cache hit on the one-shot path: the matched prompt rows'
    K/V already sit in resident pool blocks (now mapped into this slot's
    table), so prefill runs only over the unmatched tail — a scratch
    cache is seeded with the matched rows and one ``prefill_extend``
    resumes mid-prompt. The insert then writes only the private pages
    (shared pages keep the resident blocks). Greedy tokens are
    bit-identical to a full prefill: the seeded rows are exactly what
    this prompt's prefill would recompute."""
    r = ticket.req
    tp = time.perf_counter()
    scratch = T.init_cache(sched.cfg, 1, sched._scratch_len)
    scratch = sched.layout.seed_scratch(scratch, res, matched)
    tail = jnp.asarray(np.ascontiguousarray(r.prompt[matched:],
                                            np.int32)[None])
    logits, scratch, _ = jax.block_until_ready(sched._extend_fn(
        sched.params, tail, scratch,
        jnp.full((1,), matched, jnp.int32)))
    sched.layout.insert_scratch(scratch, slot)
    sched.layout.register_prefix(slot, r.prompt)
    dt = time.perf_counter() - tp
    ticket.prefill_s += dt
    sched.core.prefill(slot, len(r.prompt) - matched)
    if sched.obs is not None:
        sched._obs_prefill(slot, "prefill (prefix resume)", tp, dt,
                           len(r.prompt) - matched)
    sched.prefill_tokens_saved += matched
    first = int(sched.sampler(logits[:, -1])[0])
    sched._activate(ticket, slot, first, len(r.prompt), t0)


def advance_chunked(sched, t0: float) -> None:
    """Run ONE prefill chunk of the in-flight chunked admission, so
    prefill work interleaves with decode steps instead of stalling
    them. On the last chunk the scratch K/V is inserted into the
    shared cache and the request joins the decode batch."""
    st = sched._chunking
    if st is None:
        return
    r = st.ticket.req
    c = sched._chunk
    real = min(c, len(r.prompt) - st.pos)
    chunk = np.zeros((c,), np.int32)
    chunk[:real] = r.prompt[st.pos:st.pos + real]
    tp = time.perf_counter()
    logits, st.cache, _ = jax.block_until_ready(sched._extend_fn(
        sched.params, jnp.asarray(chunk[None]), st.cache,
        jnp.full((1,), st.pos, jnp.int32)))
    dt = time.perf_counter() - tp
    st.ticket.prefill_s += dt
    sched.core.prefill(st.slot, real, label="prefill chunk")
    if sched.obs is not None:
        sched._obs_prefill(st.slot, "prefill chunk", tp, dt, real)
    st.pos += real
    if st.pos < len(r.prompt):
        return
    sched.layout.insert_scratch(st.cache, st.slot)
    if sched._prefix and r.embeds is None:
        sched.layout.register_prefix(st.slot, r.prompt)
    first = int(sched.sampler(logits[:, real - 1])[0])
    sched._chunking = None
    sched._activate(st.ticket, st.slot, first, len(r.prompt), t0)
