"""Victim cache for the paged prefix index, plus its restart persistence.

The prefix index in ``layouts.PagedLayout`` maps token prefixes to
resident block chains; without this module an index entry dies the
moment its block's refcount reaches zero, so a shared prompt prefix is
gone as soon as the requests using it complete — every cold admission
(and every new drain epoch) re-prefills system prompts that thousands
of tenants share. ``VictimCache`` is the retention half of the cache
service: a completed request's refcount-1 indexed blocks transfer
ownership here (the pool holds their single reference, K/V stays
resident, the index entries stay valid) instead of freeing, and are
evicted — weighted-LRU order, quota-aware — only under allocation
pressure. ``save_victim_cache``/``restore_victim_cache`` serialize the
resident index (tokens + pool K/V rows) through ``runtime.checkpoint``
so a restarted engine starts warm: the fault-tolerant Edge-PRUNE
companion (arXiv:2206.08152) motivates cache state surviving restarts
the same way unacked frames do.

Ownership invariant (pinned by tests/test_prefix_cache_props.py): a
block is never simultaneously live (in a slot's table) and in the
victim pool. Admission happens only when the releasing slot held the
last reference; revival removes the block from the pool and hands that
reference to the matching slot.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, NamedTuple, Optional, Set, Tuple

import jax.numpy as jnp
import numpy as np

from repro.runtime import checkpoint
from repro.runtime.policies import make_victim_eviction

__all__ = ["VictimCache", "save_victim_cache", "restore_victim_cache",
           "export_chains", "gather_block_rows", "scatter_block_rows"]

CHECKPOINT_FORMAT = "prefix-victim-v1"


class EvictionView(NamedTuple):
    """What a victim-eviction policy sees per block (see
    policies.VICTIM_EVICTION_POLICIES): re-match count, admission stamp
    (monotonic per admitted chain), page depth within its chain, and
    owning tenant."""
    hits: int
    stamp: int
    page: int
    tenant: str


@dataclass
class _Entry:
    tenant: str
    page: int
    stamp: int


class VictimCache:
    """Reclaimable pool of refcount-1 prefix blocks.

    Every block here is still *held* in the allocator (refcount exactly
    1, owned by this pool), so its K/V rows and prefix-index entries
    stay valid; it just doesn't belong to any request. The layout moves
    blocks in at release time (``admit``), hands them back to a matching
    admission (``revive`` — the pool's reference becomes the slot's,
    with no allocator traffic), and evicts them (``pick``/``drop``)
    only when an allocation actually comes up short.

    Per-block hit counts persist across revive/re-admit cycles (a
    chain that keeps getting matched stays hot) and are forgotten only
    when the block is truly freed — ``forget`` guards block-id reuse.
    Quotas are per-tenant byte budgets over pool occupancy: a tenant
    over budget evicts its own least-valuable blocks, never another
    tenant's (``over_quota``)."""

    def __init__(self, block_bytes: int, policy: Any = "weighted-lru",
                 quotas: Optional[Dict[str, int]] = None):
        self.block_bytes = int(block_bytes)
        self.policy = make_victim_eviction(policy)
        self.quotas: Dict[str, int] = dict(quotas or {})
        self.blocks: Dict[int, _Entry] = {}
        self._hits: Dict[int, int] = {}
        self._clock = 0

    def __len__(self) -> int:
        return len(self.blocks)

    def __contains__(self, block: int) -> bool:
        return block in self.blocks

    @property
    def total_bytes(self) -> int:
        return len(self.blocks) * self.block_bytes

    def tenant_bytes(self, tenant: str) -> int:
        return sum(self.block_bytes for e in self.blocks.values()
                   if e.tenant == tenant)

    def per_tenant_bytes(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.blocks.values():
            out[e.tenant] = out.get(e.tenant, 0) + self.block_bytes
        return out

    def hits(self, block: int) -> int:
        return self._hits.get(block, 0)

    def _order(self, block: int):
        e = self.blocks[block]
        return self.policy.key(EvictionView(self._hits.get(block, 0),
                                            e.stamp, e.page, e.tenant))

    def admit(self, pairs: Iterable[Tuple[str, int, int]]) -> None:
        """Take ownership of ``(tenant, page, block)`` entries — one
        released chain, one shared LRU stamp."""
        self._clock += 1
        for tenant, page, block in pairs:
            assert block not in self.blocks, \
                f"block {block} admitted to the victim pool twice"
            self.blocks[block] = _Entry(tenant, page, self._clock)

    def admit_restored(self, block: int, tenant: str, page: int,
                       stamp: int, hits: int) -> None:
        """Checkpoint-restore admission: preserves the saved LRU stamp
        and hit count so eviction priority survives the restart."""
        self.blocks[block] = _Entry(tenant, page, stamp)
        if hits:
            self._hits[block] = hits
        self._clock = max(self._clock, stamp)

    def record_match(self, blocks: Iterable[int]) -> None:
        """A prefix match touched these blocks (live or pooled): bump
        their persistent hit counts — the weight in weighted-LRU."""
        for b in blocks:
            self._hits[b] = self._hits.get(b, 0) + 1

    def revive(self, block: int) -> None:
        """A matching admission takes the block back: the pool's single
        reference becomes the slot's. Hit counts persist."""
        self.blocks.pop(block, None)

    def pick(self, n: int, exclude: Set[int] = frozenset()) -> List[int]:
        """Up to ``n`` blocks in eviction order (policy key ascending =
        least valuable first), skipping ``exclude`` — the blocks the
        in-flight admission is about to share or seed from."""
        order = sorted((b for b in self.blocks if b not in exclude),
                       key=self._order)
        return order[:n]

    def drop(self, blocks: Iterable[int]) -> None:
        """Evict: forget the entries (the caller releases the blocks —
        the pool's reference — back to the allocator)."""
        for b in blocks:
            self.blocks.pop(b, None)

    def forget(self, freed: Iterable[int]) -> None:
        """Blocks were truly freed: clear their persistent hit counts so
        a reused block id doesn't inherit a dead chain's heat."""
        for b in freed:
            self._hits.pop(b, None)
            self.blocks.pop(b, None)

    def over_quota(self, tenant: str) -> List[int]:
        """The tenant's pooled blocks to evict — its own, least valuable
        first — to get back under its byte budget. Empty for unbudgeted
        tenants; never names another tenant's blocks."""
        budget = self.quotas.get(tenant)
        if budget is None:
            return []
        mine = sorted((b for b, e in self.blocks.items()
                       if e.tenant == tenant), key=self._order)
        spill = len(mine) * self.block_bytes - budget
        take = max(0, -(-spill // self.block_bytes)) if spill > 0 else 0
        return mine[:take]


# -- checkpoint serialization ---------------------------------------------
#
# The saved artifact is the *resident prefix index*, chain by chain:
# each maximal root-to-leaf path through a tenant's chained-hash index
# (full pages, plus one chain per partial-tail entry) with the token
# text and every page's pool K/V rows. Tokens are stored because the
# hash chain is not invertible; restore re-registers each page through
# the same ``_chain`` hashing, reusing already-restored entries where
# paths share a prefix, so shared preambles deduplicate on the way back
# in exactly as they did live.


def _pool_leaves(cfg, cache):
    """(flat name, part, layer index, leaf key) for every pool-shaped
    leaf: global-attention K/V is the only state the paged pool holds
    (scan leaves (P, N, bs, Hk, hd), remainder leaves (N, bs, Hk, hd))."""
    for i, kind in enumerate(cfg.layer_pattern):
        if kind == "attn":
            for key in ("k", "v"):
                yield f"s{i}.{key}", "scan", i, key
    for i, kind in enumerate(cfg.remainder_kinds):
        if kind == "attn":
            for key in ("k", "v"):
                yield f"r{i}.{key}", "rem", i, key


def gather_block_rows(cfg, cache, block: int) -> Dict[str, np.ndarray]:
    """One block's K/V rows out of every pool leaf, as host arrays."""
    out = {}
    for name, part, i, key in _pool_leaves(cfg, cache):
        leaf = cache[part][i][key]
        out[name] = np.asarray(leaf[:, block] if part == "scan"
                               else leaf[block])
    return out


def scatter_block_rows(cfg, cache, block: int,
                       rows: Dict[str, np.ndarray]):
    """Write ``gather_block_rows`` output back into ``block`` of a
    (possibly different) pool; returns the updated cache pytree."""
    parts = {"scan": [dict(d) for d in cache["scan"]],
             "rem": [dict(d) for d in cache["rem"]]}
    for name, part, i, key in _pool_leaves(cfg, cache):
        leaf = parts[part][i][key]
        val = jnp.asarray(rows[name], leaf.dtype)
        parts[part][i][key] = (leaf.at[:, block].set(val) if part == "scan"
                               else leaf.at[block].set(val))
    return parts


def export_chains(layout) -> List[Tuple[str, List[np.ndarray], List[int]]]:
    """Walk the tenant-scoped prefix index into maximal chains:
    ``(tenant, per-page token arrays, per-page blocks)``. Every indexed
    block is resident by construction (entries die with their block),
    and indexed rows are never overwritten (decode writes strictly
    above the registered prompt), so live and pooled blocks export
    alike."""
    chains: List[Tuple[str, List[np.ndarray], List[int]]] = []
    tenants = set(layout._prefix_full) | set(layout._prefix_partial)
    for tenant in sorted(tenants):
        full = layout._prefix_full.get(tenant, {})
        partial = layout._prefix_partial.get(tenant, {})
        children: Dict[int, List[int]] = {}
        for key, (_, _, parent) in full.items():
            children.setdefault(parent, []).append(key)
        stack: List[Tuple[int, List[np.ndarray], List[int]]] = [(0, [], [])]
        while stack:
            key, toks, blks = stack.pop()
            kids = children.get(key, ())
            tails = partial.get(key, ())
            for blk, _, tail in tails:
                chains.append((tenant, toks + [tail], blks + [blk]))
            if blks and not kids and not tails:
                chains.append((tenant, toks, blks))
            for k in kids:
                blk, page, _ = full[k]
                stack.append((k, toks + [page], blks + [blk]))
    return chains


def save_victim_cache(path: str, layout, cfg) -> int:
    """Serialize the resident prefix index + victim-pool LRU state to a
    ``checkpoint.save`` artifact (path-flattened .npz + JSON meta).
    Returns the number of chains saved."""
    if layout.victim is None:
        raise ValueError("victim cache not enabled on this layout "
                         "(EngineConfig(victim_cache=True))")
    chains = export_chains(layout)
    tree: Dict[str, np.ndarray] = {}
    meta_chains = []
    for ci, (tenant, parts, blks) in enumerate(chains):
        tokens = np.concatenate([np.asarray(p, np.int32) for p in parts])
        tree[f"c{ci}/tokens"] = tokens
        stamps, hits = [], []
        for p, blk in enumerate(blks):
            entry = layout.victim.blocks.get(blk)
            stamps.append(entry.stamp if entry is not None else 0)
            hits.append(layout.victim.hits(blk))
            for name, rows in gather_block_rows(cfg, layout.cache,
                                                blk).items():
                tree[f"c{ci}/p{p}/{name}"] = rows
        meta_chains.append({"tenant": tenant, "len": int(tokens.size),
                            "pages": len(blks), "stamps": stamps,
                            "hits": hits})
    checkpoint.save(path, tree, meta={
        "format": CHECKPOINT_FORMAT, "model": cfg.name,
        "block_size": layout.block_size, "chains": meta_chains})
    return len(chains)


def restore_victim_cache(path: str, layout, cfg) -> int:
    """Load a ``save_victim_cache`` artifact into a (typically fresh)
    layout: allocate pool blocks, write their K/V rows, re-register the
    index entries under the saved tenants, and admit everything to the
    victim pool with the saved LRU stamps/hit counts. Stops a chain
    early if the pool fills (the remaining pages simply stay cold).
    Returns the number of blocks restored."""
    victim = layout.victim
    if victim is None:
        raise ValueError("victim cache not enabled on this layout "
                         "(EngineConfig(victim_cache=True))")
    meta = checkpoint.load_meta(path)
    if meta.get("format") != CHECKPOINT_FORMAT:
        raise ValueError(f"{path}: not a {CHECKPOINT_FORMAT} artifact")
    if meta["model"] != cfg.name or meta["block_size"] != layout.block_size:
        raise ValueError(
            f"{path}: saved for model={meta['model']} "
            f"block_size={meta['block_size']}, engine runs {cfg.name} "
            f"block_size={layout.block_size}")
    flat = checkpoint.load_flat(path)
    bs = layout.block_size
    restored = 0
    for ci, cm in enumerate(meta["chains"]):
        tenant = cm["tenant"]
        tokens = np.asarray(flat[f"c{ci}/tokens"], np.int32)
        full_pages = len(tokens) // bs
        key = 0
        dead = False
        for p in range(full_pages):
            page = tokens[p * bs:(p + 1) * bs]
            nxt = layout._chain(key, page)
            entry = layout._prefix_full.get(tenant, {}).get(nxt)
            if entry is not None:
                if not np.array_equal(entry[1], page):
                    dead = True     # hash collision: drop the rest
                    break
            else:
                got = layout.alloc.alloc(1)
                if got is None:
                    dead = True     # pool full: remaining pages stay cold
                    break
                blk = got[0]
                rows = {name: flat[f"c{ci}/p{p}/{name}"]
                        for name, *_ in _pool_leaves(cfg, layout.cache)}
                layout.cache = scatter_block_rows(cfg, layout.cache, blk,
                                                  rows)
                layout._prefix_full.setdefault(tenant, {})[nxt] = \
                    (blk, page.copy(), key)
                layout._block_keys.setdefault(blk, []).append(
                    ("full", tenant, nxt))
                layout._block_tenant[blk] = tenant
                victim.admit_restored(blk, tenant, page=p,
                                      stamp=cm["stamps"][p],
                                      hits=cm["hits"][p])
                restored += 1
            key = nxt
        if dead or not len(tokens) % bs:
            continue
        tail = tokens[full_pages * bs:]
        bucket = layout._prefix_partial.setdefault(
            tenant, {}).setdefault(key, [])
        if not any(length == len(tokens) and np.array_equal(t, tail)
                   for _, length, t in bucket):
            got = layout.alloc.alloc(1)
            if got is None:
                if not bucket:      # undo the empty bucket we created
                    del layout._prefix_partial[tenant][key]
                    if not layout._prefix_partial[tenant]:
                        del layout._prefix_partial[tenant]
                continue
            blk = got[0]
            rows = {name: flat[f"c{ci}/p{full_pages}/{name}"]
                    for name, *_ in _pool_leaves(cfg, layout.cache)}
            layout.cache = scatter_block_rows(cfg, layout.cache, blk, rows)
            bucket.append((blk, len(tokens), tail.copy()))
            layout._block_keys.setdefault(blk, []).append(
                ("partial", tenant, key))
            layout._block_tenant[blk] = tenant
            victim.admit_restored(blk, tenant, page=full_pages,
                                  stamp=cm["stamps"][full_pages],
                                  hits=cm["hits"][full_pages])
            restored += 1
    for tenant in {cm["tenant"] for cm in meta["chains"]}:
        layout.enforce_quota(tenant)
    return restored
