"""Continuous-batching core loop: mechanism under pluggable policies.

The scheduler is the *mechanism* half of the serving stack (the policy
half lives in ``runtime.policies``; the user-facing facade is
``runtime.engine.Engine``). The package splits it by concern —
``types`` (request/config dataclasses), ``allocator`` (the refcounted
block pool), ``layouts`` (slotted / paged KV surgery), ``prefill``
(one-shot / prefix-resume / chunked admission compute), ``units`` (the
multi-unit execution core) — and this module owns the loop that ties
them together:

* the decode loop — one decode function compiled ONCE at a fixed slot
  count ``max_slots``; requests join and leave the running batch between
  steps without recompiling;
* the waiting set — *which* request is admitted next, *who* is
  preempted under pool pressure, *how* logits become tokens, and
  *where* prompt bursts land are the injected policies' calls;
* the request lifecycle — per-token streaming to a ``RequestHandle``,
  cancellation, injected ``SlotFailure`` re-queue/terminate, wall-clock
  deadline shedding, and a ``finish_reason`` on every ``Completion``;
* the **execution core** (``units.ExecutionCore``): every prompt burst,
  K/V handoff and batched decode step is also charged to modeled
  per-unit clocks, giving each drain a deterministic multi-unit
  timeline — prefill/decode disaggregation and pipelined in-flight
  decode — without touching token content (``units=1``, the default,
  is the degenerate case: one clock, makespan == total work).

Per-slot ``cache_len`` makes the shared batch sound (decode attention
masks rows at position >= cache_len[slot], so mixed-length contexts
coexist in one step), and greedy decoding is per-request deterministic,
so every layout/policy/unit combination emits tokens bit-identical to
the static-bucket path (tests/test_conformance_matrix.py).
"""
from __future__ import annotations

import heapq
import time
from collections import Counter
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.observability import (SIZE_BUCKETS, TIME_BUCKETS_S,
                                         Observability)
from repro.runtime.policies import (BatchAdmission, EvictLatest,
                                    FifoAdmission, Sampler, make_admission,
                                    make_preemption, request_due_s)
from repro.runtime.scheduler import prefill as _prefill
from repro.runtime.scheduler.allocator import BlockAllocator
from repro.runtime.scheduler.layouts import PagedLayout, SlottedLayout
from repro.runtime.scheduler.types import (Completion, Request, SchedEvent,
                                           SchedulerConfig, SlotFailure,
                                           _ChunkedPrefill, _Ticket,
                                           validate_request_fits)
from repro.runtime.scheduler.units import ExecutionCore

__all__ = ["ContinuousScheduler"]


class ContinuousScheduler:
    """Admission queue + shared decode batch over a slot/paged KV cache.

    Policies are injected (``admission``, ``preemption``, ``sampler``) —
    names or instances from ``runtime.policies``; the defaults (FIFO,
    evict-latest, greedy) reproduce the pre-policy scheduler exactly."""

    def __init__(self, cfg: ModelConfig, params: Any,
                 sched: Optional[SchedulerConfig] = None, *,
                 failures: Optional[List[SlotFailure]] = None,
                 admission: Any = None, preemption: Any = None,
                 sampler: Optional[Sampler] = None,
                 obs: Optional[Observability] = None):
        self.cfg = cfg
        self.params = params
        self.sched = s = sched or SchedulerConfig()
        self.admission = make_admission(admission) if admission is not None \
            else FifoAdmission()
        if isinstance(self.admission, BatchAdmission):
            raise ValueError(
                "batch admission is the Engine's static-bucket path; the "
                "continuous scheduler needs an ordering policy "
                "(fifo | priority | edf)")
        self.preemption = make_preemption(preemption) \
            if preemption is not None else EvictLatest()
        self.sampler = sampler or Sampler(greedy=s.greedy,
                                          temperature=s.temperature,
                                          seed=s.seed)
        # Injected slot failures, applied at decode-step boundaries. A
        # cursor (not destructive pops) tracks what has been applied, so
        # run() is re-entrant: a second run() with new submissions still
        # sees failures the first drain never reached.
        self.failures = sorted(failures or [], key=lambda f: f.step)
        self._failure_pos = 0
        # paged mode wants a whole number of blocks per slot
        self.max_len = s.max_len if not s.paged else \
            -(-s.max_len // s.block_size) * s.block_size
        max_len = self.max_len
        self._prefill_fn = jax.jit(
            lambda p, b: T.prefill(p, cfg, b, max_len=max_len))
        # chunked prefill (gated to configs the extend path supports)
        self._chunk = s.prefill_chunk \
            if (s.prefill_chunk > 0 and T.supports_chunked_prefill(cfg)) \
            else 0
        self._scratch_len = -(-max_len // self._chunk) * self._chunk \
            if self._chunk else max_len
        self._chunking: Optional[_ChunkedPrefill] = None
        layout_cls = PagedLayout if s.paged else SlottedLayout
        self.layout = layout_cls(cfg, s, max_len, self._scratch_len)
        # prefix sharing resumes prefill mid-prompt through the same
        # extend path chunked prefill uses (the layout re-checks config
        # support, so the flag is the effective one)
        self._prefix = getattr(self.layout, "prefix_cache", False)
        if self._chunk or self._prefix:
            self._extend_fn = jax.jit(
                lambda p, tok, c, cl: T.prefill_extend(p, cfg, tok, c, cl))
        # prefill-work accounting for the serving bench: prompt tokens
        # admitted vs prompt tokens whose K/V came from a shared prefix
        self.prefill_tokens_total = 0
        self.prefill_tokens_saved = 0
        # Persistent slot state. cache_len/tokens (and the layout's block
        # tables) are host-side mirrors so admission/eviction never
        # touches device state beyond the insert.
        self.cache_len = np.zeros((s.max_slots,), np.int32)
        self.tokens = np.zeros((s.max_slots,), np.int32)
        self.free: List[int] = list(range(s.max_slots))[::-1]  # pop() -> 0,1,..
        self.active: Dict[int, _Ticket] = {}
        # waiting set: a heap keyed by the admission policy's (static,
        # total-order) key, so each admission is O(log n) instead of a
        # min-scan + remove. Cancelled entries are retired in place and
        # skipped lazily at the top; _queue_stale counts them.
        self.queue: List[tuple] = []
        self._queue_stale = 0
        self.backlog: List[_Ticket] = []  # submitted, not yet "arrived"
        self._backlog_pos = 0           # consumed-prefix cursor into backlog
        self._backlog_dirty = False
        self._admit_seq = 0
        self._submit_seq = 0
        self.events: List[SchedEvent] = []
        self.step_count = 0
        self._t0: Optional[float] = None
        self._cancel_requests: List[_Ticket] = []   # via request_cancel()
        # deadline enforcement: min-heap of (due_s, submit_seq, ticket)
        # over live deadline-carrying tickets, so the per-boundary shed
        # check is O(expired log n), not a scan of the waiting set.
        # Entries for finished tickets are skipped lazily at the top.
        self._deadline_heap: List[tuple] = []
        self.tokens_generated = 0
        # Observability (None = disabled; the hot path pays one `is None`
        # test per hook). Trace timestamps run on a *construction-epoch*
        # clock (`_obs_now`) rather than the scheduler's per-drain `_t0`:
        # `_t0` resets between drains, and a trace track's timestamps
        # must never go backwards. Metric *durations* are differences of
        # scheduler-clock stamps, so they are epoch-independent.
        self.obs = obs if (obs is not None and obs.enabled) else None
        if self.obs is not None:
            self._obs_epoch = time.perf_counter()
            self._phase: Dict[str, float] = {}
            r = self.obs.registry
            self._m = {
                "ttft": r.histogram(
                    "repro_ttft_seconds", TIME_BUCKETS_S,
                    help="arrival to first token (admission wait + prefill)"),
                "inter_token": r.histogram(
                    "repro_inter_token_seconds", TIME_BUCKETS_S,
                    help="steady-state gap between consecutive tokens "
                         "of one request"),
                "step": r.histogram(
                    "repro_step_duration_seconds", TIME_BUCKETS_S,
                    help="one scheduler iteration, boundary to boundary"),
                "queue_wait": r.histogram(
                    "repro_queue_wait_seconds", TIME_BUCKETS_S,
                    help="enqueue to admission pop"),
                "chunk": r.histogram(
                    "repro_prefill_chunk_tokens", SIZE_BUCKETS,
                    help="prompt tokens prefilled per admission/chunk step"),
                "blocks": r.histogram(
                    "repro_blocks_in_use", SIZE_BUCKETS,
                    help="paged KV blocks held, sampled each step"),
            }
            for ph in ("admission", "prefill", "decode", "sampling", "kv"):
                self._m["step_" + ph] = r.histogram(
                    f"repro_step_{ph}_seconds", TIME_BUCKETS_S,
                    help=f"per-step time inside the {ph} phase")
        # Multi-unit execution core: every prompt burst / handoff /
        # decode step is mirrored onto modeled per-unit clocks. Built
        # after obs so its per-unit tracks share the tracer.
        self.core = ExecutionCore(s, obs=self.obs)

    # -- legacy attribute surface (tests/benches reach for these) -----------

    @property
    def alloc(self) -> Optional[BlockAllocator]:
        return getattr(self.layout, "alloc", None)

    @property
    def block_tables(self) -> Optional[np.ndarray]:
        return getattr(self.layout, "block_tables", None)

    @property
    def cache(self):
        return self.layout.cache

    @property
    def key(self) -> jax.Array:
        return self.sampler.key

    @key.setter
    def key(self, k: jax.Array) -> None:
        self.sampler.key = k

    # -- public API ---------------------------------------------------------

    def submit(self, req: Request, arrival_s: float = 0.0) -> _Ticket:
        """Queue a request for admission at ``arrival_s`` (seconds from
        drain start). Returns the internal ticket — the Engine wraps it
        in a ``RequestHandle``; direct callers can ignore it."""
        validate_request_fits(self.cfg, req, self.max_len)
        self.layout.validate(req)
        if self.done:
            # a fresh drain after a completed one starts a fresh arrival
            # epoch, whichever drive path (run() or step_once()) follows
            self._t0 = None
        ticket = _Ticket(req=req, arrival_s=arrival_s,
                         submit_seq=self._submit_seq)
        self._submit_seq += 1
        self.backlog.append(ticket)
        self._backlog_dirty = True
        if self.sched.enforce_deadlines and req.deadline_s is not None:
            heapq.heappush(self._deadline_heap,
                           (request_due_s(ticket), ticket.submit_seq, ticket))
        return ticket

    def request_cancel(self, ticket: _Ticket) -> None:
        """Flag a ticket for cancellation (the RequestHandle's path).
        Only flips a flag and records the ticket — retirement happens
        at the next step boundary (or inside the admission loop, for a
        cancel from another stream's token callback mid-pass), so this
        is token-callback safe; the list keeps the purge O(#cancelled)."""
        ticket.cancelled = True
        self._cancel_requests.append(ticket)

    @property
    def done(self) -> bool:
        """True when nothing is queued, active, mid-prefill, or pending
        arrival — a step_once() now would be a no-op."""
        return (self._backlog_pos >= len(self.backlog)
                and self._waiting() == 0
                and not self.active and self._chunking is None)

    # -- waiting-set heap ---------------------------------------------------

    def _waiting(self) -> int:
        return len(self.queue) - self._queue_stale

    def _enqueue(self, ticket: _Ticket) -> None:
        """Push into the waiting heap under the admission policy's key
        (computed once — policy inputs are static per ticket); the
        submit_seq tiebreak keeps entries totally ordered without ever
        comparing tickets."""
        ticket.where = "queued"
        heapq.heappush(self.queue, (self.admission.key(ticket),
                                    ticket.submit_seq, ticket))
        if self.obs is not None:
            # only ever called while stepping, so _t0 is set
            ticket.queued_at_s = time.perf_counter() - self._t0
            self.obs.tracer.async_begin(
                "engine", "queue", f"req {ticket.req.id} queued",
                ticket.req.id, self._obs_now(),
                args={"restarts": ticket.restarts})

    def _queue_head(self) -> Optional[_Ticket]:
        """The policy's next pick, skipping entries retired by
        cancellation (lazy deletion)."""
        while self.queue and self.queue[0][2].retired:
            heapq.heappop(self.queue)
            self._queue_stale -= 1
        return self.queue[0][2] if self.queue else None

    def run(self, on_completion: Optional[Callable[[Completion], None]] = None
            ) -> List[Completion]:
        """Drain every submitted request; returns completions by id.
        ``on_completion`` (streaming mode) is invoked with each completion
        the moment its request finishes, before the drain returns.
        Re-entrant: a later run() continues from the same step counter and
        failure cursor, serving anything submitted since (arrivals are
        measured from *this* call when the scheduler is idle; a drain
        resumed mid-flight — e.g. after step-driven streaming — keeps
        the original epoch so in-flight timestamps stay coherent)."""
        if self._t0 is None or (self._waiting() == 0 and not self.active
                                and self._chunking is None):
            self._t0 = time.perf_counter()
        self._sort_pending()
        out: List[Completion] = []
        while not self.done:
            out.extend(self.step_once(on_completion))
        return sorted(out, key=lambda c: c.id)

    def step_once(self, on_completion: Optional[
            Callable[[Completion], None]] = None) -> List[Completion]:
        """One scheduler iteration: deliver arrivals, purge cancellations,
        apply due failures, advance the in-flight chunked prefill, admit,
        and (if anything is active) run one decode step. Returns the
        completions this iteration produced. Drives the step-wise Engine
        API (``RequestHandle.stream()`` pulls this between tokens)."""
        if self.obs is None:
            return self._step_impl(on_completion)
        self._phase = {}
        w0 = time.perf_counter()
        out = self._step_impl(on_completion)
        self._obs_step_done(w0, time.perf_counter())
        return out

    def _step_impl(self, on_completion: Optional[
            Callable[[Completion], None]] = None) -> List[Completion]:
        if self._t0 is None:
            self._t0 = time.perf_counter()
        if self._backlog_dirty:
            self._sort_pending()
        t0 = self._t0
        obs = self.obs
        done: List[Completion] = []
        now = time.perf_counter() - t0
        while (self._backlog_pos < len(self.backlog)
               and self.backlog[self._backlog_pos].arrival_s <= now):
            self._enqueue(self.backlog[self._backlog_pos])
            self._backlog_pos += 1
        done.extend(self._purge_cancelled(t0))
        done.extend(self._shed_expired(t0))
        if (self._waiting() == 0 and not self.active
                and self._chunking is None):
            if obs is not None:
                # an arrival-gap sleep (or a no-op boundary) is not an
                # engine step — keep it out of the step histograms
                self._phase["idle"] = 1.0
            if self._backlog_pos < len(self.backlog):
                # idle until the next arrival (virtual clock = wall
                # clock). Failures due at this step boundary still apply
                # — they must not be silently deferred past the gap.
                done.extend(self._apply_failures(t0))
                time.sleep(max(
                    0.0, self.backlog[self._backlog_pos].arrival_s - now))
            return self._deliver(done, on_completion)
        wa = time.perf_counter()
        done.extend(self._apply_failures(t0))
        self._advance_chunked(t0)
        done.extend(self._admit(t0))
        if obs is not None:
            # admission machinery = this whole region minus the prefill
            # compute the leaf helpers attributed to their own phase
            self._phase["admission"] = (
                time.perf_counter() - wa - self._phase.get("prefill", 0.0))
        if self.active:
            done.extend(self._decode_step(t0))
        if self.sched.debug:
            self._check_invariants()
        if self.sched.step_delay_s:
            time.sleep(self.sched.step_delay_s)   # device-speed handicap
        return self._deliver(done, on_completion)

    # -- observability hooks (self.obs is not None on every call) -----------

    def _obs_now(self) -> float:
        return time.perf_counter() - self._obs_epoch

    def _obs_step_done(self, w0: float, w1: float) -> None:
        ph = self._phase
        if "idle" in ph:
            return
        m = self._m
        m["step"].observe(w1 - w0)
        for k in ("admission", "prefill", "decode", "sampling", "kv"):
            if k in ph:
                m["step_" + k].observe(ph[k])
        alloc = self.alloc
        if alloc is not None:
            m["blocks"].observe(alloc.in_use)
        args = {k: round(v * 1e3, 4) for k, v in ph.items()}
        args.update(active=len(self.active), queued=self._waiting())
        self.obs.tracer.complete(
            "engine", "steps", f"step {self.step_count}",
            w0 - self._obs_epoch, w1 - w0, args=args)

    def _obs_dequeue(self, ticket: _Ticket) -> None:
        """Close the request's queued span (admission pop, queue-side
        shed/cancel — every way a ticket leaves the waiting set)."""
        self.obs.tracer.async_end(
            "engine", "queue", ticket.req.id, self._obs_now())

    def _obs_slot_begin(self, ticket: _Ticket, slot: int,
                        matched: int) -> None:
        ts = self._obs_now()
        tr = self.obs.tracer
        tr.begin("engine", f"slot {slot}", f"req {ticket.req.id}", ts,
                 args={"prompt_tokens": len(ticket.req.prompt),
                       "restarts": ticket.restarts})
        if matched:
            tr.instant("engine", f"slot {slot}", "prefix-hit", ts,
                       args={"request": ticket.req.id,
                             "matched_rows": matched})

    def _obs_prefill(self, slot: int, name: str, tp: float, dt: float,
                     tokens: int) -> None:
        """Attribute one prefill compute burst: phase accounting, the
        chunk-size histogram, and an X span nested in the slot track.
        ``tp`` is the raw perf_counter() start stamp."""
        self._phase["prefill"] = self._phase.get("prefill", 0.0) + dt
        self._m["chunk"].observe(tokens)
        self.obs.tracer.complete("engine", f"slot {slot}", name,
                                 tp - self._obs_epoch, dt,
                                 args={"tokens": tokens})

    def kv_stats(self) -> Dict[str, float]:
        """KV-memory accounting for the serving bench: what a dense
        slotted cache reserves vs what the paged pool holds / has ever
        held (high-water mark), in bytes of global-attention K/V."""
        return self.layout.kv_stats(self.sched, self.cfg)

    def stats(self) -> Dict[str, int]:
        """Lifecycle counters accumulated so far (the serving bench
        reports preemptions when sweeping the admission watermark)."""
        c, lay = Counter(e.kind for e in self.events), self.layout
        return {"requests_submitted": self._submit_seq,
                "admissions": c["admit"], "evictions": c["evict"],
                "preemptions": c["preempt"], "slot_failures": c["fail"],
                "cancellations": c["cancel"], "sheds": c["shed"],
                "steps": self.step_count,
                "tokens_generated": self.tokens_generated,
                "prefix_hits": getattr(lay, "prefix_hits", 0),
                "victim_hits": getattr(lay, "victim_hits", 0),
                "victim_evictions": getattr(lay, "victim_evictions", 0),
                "prefill_tokens_total": self.prefill_tokens_total,
                "prefill_tokens_saved": self.prefill_tokens_saved}

    def unit_stats(self) -> Dict[str, Any]:
        """The execution core's modeled multi-unit timeline: unit roster,
        per-unit busy seconds, makespan, and the speedup over serializing
        the same work on one unit (serving bench / snapshot surface)."""
        return self.core.summary()

    # -- internals ----------------------------------------------------------

    def _sort_pending(self) -> None:
        pending = sorted(self.backlog[self._backlog_pos:],
                         key=lambda t: t.arrival_s)
        self.backlog[self._backlog_pos:] = pending
        self._backlog_dirty = False

    @staticmethod
    def _deliver(done: List[Completion],
                 on_completion: Optional[Callable[[Completion], None]]
                 ) -> List[Completion]:
        if on_completion is not None:
            for c in done:
                on_completion(c)
        return done

    def _event(self, t_s: float, kind: str, rid: int, slot: int) -> None:
        """Record a lifecycle event; disruptions (preempt/fail/shed/
        cancel) additionally land as instant markers on the trace track
        of the slot (or the queue, for never-admitted requests)."""
        self.events.append(SchedEvent(t_s, kind, rid, slot, self.step_count))
        if self.obs is not None and kind in ("preempt", "fail",
                                             "shed", "cancel"):
            thread = f"slot {slot}" if slot >= 0 else "queue"
            self.obs.tracer.instant("engine", thread, kind, self._obs_now(),
                                    args={"request": rid})

    def _emit(self, ticket: _Ticket, tok: int) -> None:
        """Append a token and stream it to the handle. After a failure
        re-queue the greedy re-decode re-produces the already-streamed
        prefix; the handle dedups by index: each token seen once."""
        ticket.emitted.append(tok)
        self.tokens_generated += 1
        if ticket.handle is not None:
            ticket.handle._emit(len(ticket.emitted) - 1, tok)

    def _finish(self, ticket: _Ticket, reason: str, t0: float) -> Completion:
        now = time.perf_counter() - t0
        decode_s = now - ticket.first_token_s if ticket.first_token_s > 0.0 \
            else 0.0
        c = Completion(
            ticket.req.id, ticket.emitted, ticket.prefill_s, decode_s,
            arrival_s=ticket.arrival_s, first_token_s=ticket.first_token_s,
            finish_s=now, finish_reason=reason, restarts=ticket.restarts)
        ticket.where = "done"
        if ticket.handle is not None:
            ticket.handle._complete(c)
        return c

    def _release_slot(self, slot: int) -> None:
        """Return a slot (and, paged, its blocks — exactly once) to the
        free pool, zeroing every host-side mirror so no stale state
        outlives the occupancy."""
        self.free.append(slot)
        self.cache_len[slot] = 0
        self.tokens[slot] = 0
        self.layout.release(slot)
        self.core.release(slot)
        if self.obs is not None:
            # every occupied slot opened its span at admission; closing
            # here covers every exit path (finish/evict/preempt/fail/
            # shed/cancel, mid-chunking included)
            self.obs.tracer.end("engine", f"slot {slot}", self._obs_now())

    @staticmethod
    def _reset_ticket(ticket: _Ticket) -> None:
        ticket.slot = -1
        ticket.emitted = []
        ticket.prefill_s = 0.0
        ticket.first_token_s = 0.0
        ticket.admit_seq = -1

    def _purge_cancelled(self, t0: float) -> List[Completion]:
        """Retire every cancelled request at this step boundary: waiting
        and not-yet-arrived requests complete with no tokens, an active
        slot or in-flight chunked prefill is released. cancel() itself
        only flips a flag, so a request cancelled *during* a decode step
        (from another stream's token callback) is caught before its next
        token is emitted. O(#cancelled): dispatches over the recorded
        cancel requests by ticket state, never scanning the waiting set
        (waiting entries are retired in place in the heap)."""
        out: List[Completion] = []
        if not self._cancel_requests:
            return out
        requests, self._cancel_requests = self._cancel_requests, []
        for ticket in requests:
            if ticket.where == "done":      # raced a finish; nothing to do
                continue
            if ticket.where == "backlog":
                self.backlog.remove(ticket)     # always at index >= cursor
                out.append(self._cancel_ticket(ticket, t0))
            elif ticket.where == "queued":
                ticket.retired = True           # lazy heap deletion
                self._queue_stale += 1
                if self.obs is not None:
                    self._obs_dequeue(ticket)
                out.append(self._cancel_ticket(ticket, t0))
            elif ticket.where == "active":
                out.append(self._evict(ticket.slot, t0, "cancelled",
                                       kind="cancel"))
            elif ticket.where == "chunking":
                st = self._chunking
                self._chunking = None
                self._release_slot(st.slot)
                out.append(self._cancel_ticket(ticket, t0, slot=st.slot))
        return out

    def _cancel_ticket(self, ticket: _Ticket, t0: float,
                       slot: int = -1) -> Completion:
        now = time.perf_counter() - t0
        self._event(now, "cancel", ticket.req.id, slot)
        return self._finish(ticket, "cancelled", t0)

    def _shed_expired(self, t0: float) -> List[Completion]:
        """Deadline enforcement at a step boundary: complete every
        live request whose due instant has passed with
        ``finish_reason="timeout"``. A waiting request is retired in
        place (never prefilled); an active one is evicted mid-decode —
        its slot and (paged) block references are released, and with the
        shed happening *before* the decode step, not one token is
        emitted after it. A ticket mid-chunked-prefill releases its slot
        and reserved blocks the same way. No-op unless the scheduler was
        built with ``enforce_deadlines=True`` (the heap is only fed
        then), so the conformance-matrix identity paths never pay for
        this."""
        out: List[Completion] = []
        if not self._deadline_heap:
            return out
        now = time.perf_counter() - t0
        while self._deadline_heap and self._deadline_heap[0][0] <= now:
            _, _, ticket = heapq.heappop(self._deadline_heap)
            if ticket.where == "done" or ticket.cancelled:
                continue                    # finished/cancelled first
            if ticket.where == "backlog":
                # due <= now implies arrival_s <= now, so arrivals have
                # normally been delivered already — defensive only
                self.backlog.remove(ticket)
                out.append(self._shed_ticket(ticket, t0))
            elif ticket.where == "queued":
                ticket.retired = True       # lazy heap deletion
                self._queue_stale += 1
                if self.obs is not None:
                    self._obs_dequeue(ticket)
                out.append(self._shed_ticket(ticket, t0))
            elif ticket.where == "active":
                out.append(self._evict(ticket.slot, t0, "timeout",
                                       kind="shed"))
            elif ticket.where == "chunking":
                st = self._chunking
                self._chunking = None
                self._release_slot(st.slot)
                out.append(self._shed_ticket(ticket, t0, slot=st.slot))
        return out

    def _shed_ticket(self, ticket: _Ticket, t0: float,
                     slot: int = -1) -> Completion:
        now = time.perf_counter() - t0
        self._event(now, "shed", ticket.req.id, slot)
        return self._finish(ticket, "timeout", t0)

    def _retire_from_admission(self, ticket: _Ticket,
                               t0: float) -> Completion:
        """A cancel issued mid-admission-pass (from an earlier admitted
        request's token callback) reaches the ticket before the purge
        does: complete it here so it is never prefilled — the 'not one
        more token after cancel() returns' contract covers the first
        token too."""
        heapq.heappop(self.queue)
        if self.obs is not None:
            self._obs_dequeue(ticket)
        return self._cancel_ticket(ticket, t0)

    def _requeue_or_fail(self, victims: List[_Ticket],
                         t0: float) -> List[Completion]:
        """Post-failure/preemption routing: re-queue (restart from the
        prompt) while the request has restart budget, else complete as
        "failed" with the tokens already streamed."""
        out: List[Completion] = []
        for ticket in sorted(victims, key=lambda t: t.arrival_s):
            mr = ticket.req.max_restarts
            if mr is not None and ticket.restarts >= mr:
                if ticket.handle is not None:
                    # after earlier restarts, this attempt's replay may be
                    # shorter than what was already streamed — the handle
                    # holds the longest (deduped) history, and "failed"
                    # reports the tokens streamed before the loss
                    ticket.emitted = list(ticket.handle.tokens)
                out.append(self._finish(ticket, "failed", t0))
                continue
            ticket.restarts += 1
            self._reset_ticket(ticket)
            if ticket.handle is not None and not self.sampler.greedy:
                # a stochastic re-decode can't replay the streamed prefix
                # (the key advanced), so the handle's index dedup would
                # splice two different runs — restart its stream instead
                ticket.handle._restart()
            self._enqueue(ticket)
        return out

    def _apply_failures(self, t0: float) -> List[Completion]:
        """Apply injected slot failures due at the current step boundary:
        every request on a failed slot is *re-queued, not dropped* — its
        KV state (and paged blocks) is gone, so it goes back into the
        admission queue (where its original arrival keys it ahead of
        younger work under FIFO) and is re-prefilled from its original
        prompt. A prompt mid-way through chunked prefill on a failed slot
        restarts the same way. Greedy decoding makes the re-run
        deterministic, so its final tokens — and those of every
        unaffected request, whose slots are untouched — are bit-identical
        to a failure-free run. Requests whose ``max_restarts`` budget is
        exhausted complete as "failed" instead."""
        out: List[Completion] = []
        while (self._failure_pos < len(self.failures)
               and self.failures[self._failure_pos].step <= self.step_count):
            f = self.failures[self._failure_pos]
            self._failure_pos += 1
            slots = list(self.active) if f.slots is None \
                else [s for s in f.slots if s in self.active]
            now = time.perf_counter() - t0
            victims = []
            for slot in slots:
                ticket = self.active.pop(slot)
                self._release_slot(slot)
                self._event(now, "fail", ticket.req.id, slot)
                victims.append(ticket)
            st = self._chunking
            if st is not None and (f.slots is None or st.slot in f.slots):
                self._chunking = None
                self._release_slot(st.slot)
                self._event(now, "fail", st.ticket.req.id, st.slot)
                victims.append(st.ticket)
            out.extend(self._requeue_or_fail(victims, t0))
        return out

    def _admit(self, t0: float) -> List[Completion]:
        """Admit waiting requests into free slots, in the admission
        policy's order, until slots or (paged) blocks run out. When the
        policy's next pick can't be served, admission stops — no head-of-
        line bypass, so the policy order is also the service order.
        Returns completions of requests cancelled mid-pass (by an
        earlier admission's token callback) before they were prefilled."""
        out: List[Completion] = []
        while self.free:
            ticket = self._queue_head()
            if ticket is None:
                break
            if ticket.cancelled:
                out.append(self._retire_from_admission(ticket, t0))
                continue
            if (self.sched.enforce_deadlines
                    and request_due_s(ticket) <= time.perf_counter() - t0):
                # expired while queued behind this pass's earlier
                # prefills: shed before prefill, not after
                heapq.heappop(self.queue)
                if self.obs is not None:
                    self._obs_dequeue(ticket)
                out.append(self._shed_ticket(ticket, t0))
                continue
            r = ticket.req
            chunked = self._chunk > 0 and r.embeds is None
            if chunked and self._chunking is not None:
                break           # one chunked prefill in flight at a time
            res = self.layout.try_reserve(r)
            if res is None:
                break           # pool exhausted: wait, don't over-commit
            heapq.heappop(self.queue)
            slot = self.free.pop()
            ticket.admit_seq = self._admit_seq
            self._admit_seq += 1
            self.layout.bind(slot, res)
            self.prefill_tokens_total += len(r.prompt)
            matched = getattr(res, "matched_rows", 0)
            if self.obs is not None:
                self._m["queue_wait"].observe(
                    time.perf_counter() - t0 - ticket.queued_at_s)
                self._obs_dequeue(ticket)
                self._obs_slot_begin(ticket, slot, matched)
            if chunked:
                # resume at the last chunk boundary inside the matched
                # region, so every extend step keeps the compiled chunk
                # shape (shared pages beyond the resume point still save
                # memory; their recomputed rows are dropped at insert)
                resume = (matched // self._chunk) * self._chunk
                scratch = T.init_cache(self.cfg, 1, self._scratch_len)
                if resume:
                    scratch = self.layout.seed_scratch(scratch, res, resume)
                    self.prefill_tokens_saved += resume
                ticket.slot = slot
                ticket.where = "chunking"
                self._chunking = _ChunkedPrefill(
                    ticket=ticket, slot=slot, cache=scratch, pos=resume)
            elif matched:
                _prefill.admit_prefix_resume(self, ticket, slot, res,
                                             matched, t0)
            else:
                _prefill.admit_one_shot(self, ticket, slot, t0)
        return out

    def _advance_chunked(self, t0: float) -> None:
        _prefill.advance_chunked(self, t0)

    def _activate(self, ticket: _Ticket, slot: int, first: int, clen: int,
                  t0: float) -> None:
        ticket.first_token_s = time.perf_counter() - t0
        ticket.slot = slot
        ticket.where = "active"
        self._emit(ticket, first)
        self.cache_len[slot] = clen
        self.tokens[slot] = first
        self.active[slot] = ticket
        # prefill -> decode handoff: the slot's K/V joins the decode
        # batch in place (zero-copy — same pool blocks, same refcounts)
        self.core.handoff(slot, blocks=len(
            getattr(self.layout, "_slot_blocks", {}).get(slot, ())))
        self._event(ticket.first_token_s, "admit", ticket.req.id, slot)
        if self.obs is not None:
            self._m["ttft"].observe(ticket.first_token_s - ticket.arrival_s)
            ticket.last_emit_s = ticket.first_token_s

    def _finished(self, ticket: _Ticket) -> bool:
        return len(ticket.emitted) >= ticket.req.max_new_tokens

    def _pick_preempt_victim(self, exclude: int) -> Optional[int]:
        """Ask the preemption policy for a victim among current block
        holders other than ``exclude`` — an in-flight chunked prefill
        counts (it holds its prompt blocks), so a pool dried out by a
        half-prefilled prompt can still be reclaimed."""
        cands = [tk for s, tk in self.active.items() if s != exclude]
        if self._chunking is not None and self._chunking.slot != exclude:
            cands.append(self._chunking.ticket)
        if not cands:
            return None
        return self.preemption.pick(cands).slot

    def _preempt(self, slot: int, t0: float) -> Optional[Completion]:
        """Evict-and-requeue to reclaim blocks for another request's
        decode growth: the victim restarts from its prompt (greedy decode
        makes the re-run bit-identical) — or completes as "failed" if its
        restart budget is spent (the returned Completion)."""
        if self._chunking is not None and self._chunking.slot == slot:
            ticket = self._chunking.ticket
            self._chunking = None
        else:
            ticket = self.active.pop(slot)
        self._release_slot(slot)
        now = time.perf_counter() - t0
        self._event(now, "preempt", ticket.req.id, slot)
        out = self._requeue_or_fail([ticket], t0)
        return out[0] if out else None

    def _grow_blocks(self, t0: float) -> List[Completion]:
        """Paged decode growth: before a decode step, every active slot
        whose next KV write position falls in an unallocated page gets one
        fresh block; when the pool runs dry the preemption policy picks a
        victim to evict-and-requeue. Guaranteed to terminate because
        submit() validates that any single request's worst case fits the
        pool. Returns completions of victims that ran out of restart
        budget."""
        out: List[Completion] = []
        if not self.layout.paged:
            return out
        for slot in sorted(self.active,
                           key=lambda s: self.active[s].admit_seq):
            if slot not in self.active:     # preempted earlier this pass
                continue
            pos = int(self.cache_len[slot])
            if not self.layout.needs_block(slot, pos):
                continue
            while not self.layout.grow_one(slot, pos):
                victim = self._pick_preempt_victim(exclude=slot)
                if victim is None:
                    raise RuntimeError(
                        f"paged KV pool exhausted growing slot {slot} with "
                        f"no other active request to preempt")
                c = self._preempt(victim, t0)
                if c is not None:
                    out.append(c)
        return out

    def _decode_step(self, t0: float) -> List[Completion]:
        done: List[Completion] = []
        obs = self.obs
        # Requests satisfied by the prefill token alone never decode.
        for slot in [s for s, tk in self.active.items() if self._finished(tk)]:
            done.append(self._evict(slot, t0, "length"))
        if not self.active:
            return done
        wk = time.perf_counter()
        done.extend(self._grow_blocks(t0))
        if obs is not None:
            wd = time.perf_counter()
            self._phase["kv"] = self._phase.get("kv", 0.0) + (wd - wk)
        logits = self.layout.decode(self.params, jnp.asarray(self.tokens),
                                    jnp.asarray(self.cache_len))
        if obs is not None:
            # force the async dispatch so decode vs sampling attribution
            # is real; values are untouched, so greedy identity holds
            logits = jax.block_until_ready(logits)
            ws = time.perf_counter()
            self._phase["decode"] = self._phase.get("decode", 0.0) + (ws - wd)
        toks = np.asarray(self.sampler(logits))
        if obs is not None:
            now_s = time.perf_counter()
            self._phase["sampling"] = \
                self._phase.get("sampling", 0.0) + (now_s - ws)
            now_s -= t0
        self.step_count += 1
        # mirror the batched step onto the modeled decode pipeline
        # (sorted: lane membership must not depend on dict order)
        self.core.decode_step(sorted(self.active))
        for slot in self.active:     # free slots keep cache_len == 0
            self.cache_len[slot] += 1
        for slot, ticket in list(self.active.items()):
            if ticket.cancelled:
                # cancelled mid-step by another stream's token callback:
                # this step's token is dropped, nothing was emitted after
                # cancel() returned
                done.append(self._evict(slot, t0, "cancelled",
                                        kind="cancel"))
                continue
            t = int(toks[slot])
            if ticket.req.eos is not None and t == ticket.req.eos:
                done.append(self._evict(slot, t0, "eos"))
                continue
            self._emit(ticket, t)
            if obs is not None:
                self._m["inter_token"].observe(now_s - ticket.last_emit_s)
                ticket.last_emit_s = now_s
            self.tokens[slot] = t
            if self._finished(ticket):
                done.append(self._evict(slot, t0, "length"))
        return done

    def _evict(self, slot: int, t0: float, reason: str,
               kind: str = "evict") -> Completion:
        ticket = self.active.pop(slot)
        self._release_slot(slot)
        now = time.perf_counter() - t0
        self._event(now, kind, ticket.req.id, slot)
        return self._finish(ticket, reason, t0)

    def _check_invariants(self) -> None:
        """Step-boundary slot/block accounting (SchedulerConfig(debug=
        True)): a free slot has no residual length/token/table state, and
        the layout's books balance — every held block is named by exactly
        one table entry of exactly one occupied slot."""
        free = set(self.free)
        occupied = set(self.active)
        if self._chunking is not None:
            occupied.add(self._chunking.slot)
        assert not (free & occupied), (free, occupied)
        for slot in range(self.sched.max_slots):
            if slot in free:
                assert self.cache_len[slot] == 0, f"slot {slot}: stale len"
                assert self.tokens[slot] == 0, f"slot {slot}: stale token"
        self.layout.check(occupied, self.sched.max_slots)
