"""Observability: metrics registry, lifecycle tracing, Chrome-trace export.

Three cooperating pieces, all stdlib-only and safe to import anywhere in
the runtime:

* :class:`MetricsRegistry` — named counters, gauges, and fixed-bucket
  histograms behind one lock.  Histograms track count/sum/min/max plus
  per-bucket counts and answer p50/p90/p99 by linear interpolation
  inside the owning bucket; :meth:`MetricsRegistry.render` emits the
  Prometheus text exposition format served at ``GET /metrics``.

* :class:`Tracer` — an append-only Chrome trace-event recorder.  Tracks
  are ``(process, thread)`` pairs; each track is pinned to exactly one
  clock domain (``"wall"`` for the engine drain path, ``"modeled"`` for
  Simulator / failover-controller timelines) at first use, and mixing
  clocks on a track raises.  :meth:`Tracer.chrome_trace` snapshots the
  event list into ``{"traceEvents": [...]}`` JSON that loads directly in
  Perfetto / ``chrome://tracing``; spans still open at snapshot time are
  closed in the *copy* so a mid-run ``GET /trace`` always validates.

* :class:`Observability` — the bundle the engine threads through the
  scheduler and resilience layers.  When the ``EngineConfig.observability``
  knob is off the scheduler holds ``None`` instead, so the hot path pays
  a single ``is None`` test.

Helpers at the bottom export already-recorded modeled timelines
(`SimResult` firings, `FailoverReport` events) into a tracer, and
:func:`validate_chrome_trace` / :func:`parse_prometheus` give tests and
benches a schema gate without external dependencies.
"""
from __future__ import annotations

import bisect
import json
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "Tracer", "Observability",
    "TIME_BUCKETS_S", "SIZE_BUCKETS",
    "validate_chrome_trace", "parse_prometheus",
    "simulator_trace", "failover_trace",
]

# Log-spaced latency buckets: 100 µs resolution at the bottom (a tiny
# CPU decode step), a minute at the top (a stalled request is still
# countable).  Shared by every duration histogram so /metrics panels
# line up.
TIME_BUCKETS_S: Tuple[float, ...] = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)

# Power-of-two-ish buckets for token / block counts.
SIZE_BUCKETS: Tuple[float, ...] = (
    1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096)


# ---------------------------------------------------------------------------
# metrics


class Counter:
    """Monotonic counter. ``sync`` lets the engine mirror an externally
    maintained total (scheduler event counts) without double counting."""

    __slots__ = ("name", "help", "_lock", "_value")

    def __init__(self, name: str, help: str, lock: threading.Lock):
        self.name = name
        self.help = help
        self._lock = lock
        self._value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self._value += amount

    def sync(self, total: float) -> None:
        """Set the counter to an externally tracked monotone total."""
        with self._lock:
            if total > self._value:
                self._value = float(total)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge:
    """A value that can go up and down (queue depth, blocks in use)."""

    __slots__ = ("name", "help", "_lock", "_value")

    def __init__(self, name: str, help: str, lock: threading.Lock):
        self.name = name
        self.help = help
        self._lock = lock
        self._value = 0.0

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount

    def dec(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value -= amount

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Fixed-bucket histogram with interpolated percentiles.

    ``bounds`` are inclusive upper edges; one implicit +Inf overflow
    bucket sits past the last bound.  Percentiles interpolate linearly
    inside the owning bucket and clamp to the observed min/max, so a
    histogram fed a single value reports that value exactly at every
    quantile.
    """

    __slots__ = ("name", "help", "bounds", "_lock", "_counts",
                 "count", "sum", "min", "max")

    def __init__(self, name: str, help: str,
                 bounds: Sequence[float], lock: threading.Lock):
        if list(bounds) != sorted(bounds) or len(set(bounds)) != len(bounds):
            raise ValueError(f"histogram bounds must be strictly "
                             f"increasing: {bounds!r}")
        self.name = name
        self.help = help
        self.bounds = tuple(float(b) for b in bounds)
        self._lock = lock
        self._counts = [0] * (len(self.bounds) + 1)     # +1 overflow
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, value: float) -> None:
        v = float(value)
        i = bisect.bisect_left(self.bounds, v)
        with self._lock:
            self._counts[i] += 1
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    def reset(self) -> None:
        """Drop every recorded sample (benchmark window scoping — e.g.
        excluding a compile warmup from the measured summaries).
        Prometheus histograms never reset in production; scrapers rely
        on monotone cumulative buckets."""
        with self._lock:
            self._counts = [0] * (len(self.bounds) + 1)
            self.count = 0
            self.sum = 0.0
            self.min = float("inf")
            self.max = float("-inf")

    def percentile(self, q: float) -> float:
        """Interpolated q-th percentile (q in [0, 100])."""
        if not 0 <= q <= 100:
            raise ValueError(f"percentile out of range: {q}")
        with self._lock:
            if self.count == 0:
                return 0.0
            rank = (q / 100.0) * self.count
            cum = 0
            lo = 0.0
            for ub, c in zip(self.bounds, self._counts):
                if cum + c >= rank and c > 0:
                    frac = (rank - cum) / c
                    lo_c = min(max(lo, self.min), self.max)
                    hi_c = max(min(ub, self.max), self.min)
                    return lo_c + frac * (hi_c - lo_c)
                cum += c
                lo = ub
            return self.max                 # rank lands in the overflow

    def buckets(self) -> List[Tuple[float, int]]:
        """Cumulative (upper_bound, count) pairs, +Inf last."""
        with self._lock:
            out, cum = [], 0
            for ub, c in zip(self.bounds, self._counts):
                cum += c
                out.append((ub, cum))
            out.append((float("inf"), cum + self._counts[-1]))
            return out

    def summary(self) -> Dict[str, float]:
        with self._lock:
            if self.count == 0:
                return {"count": 0, "sum": 0.0}
        return {
            "count": self.count,
            "sum": self.sum,
            "min": self.min,
            "max": self.max,
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
        }


class MetricsRegistry:
    """Get-or-create registry for the three metric kinds.

    One lock serializes registration *and* every sample — simple,
    correct under concurrent ``Engine.submit``, and cheap at the rates a
    Python scheduler step loop reaches.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, Any] = {}

    def _get(self, name: str, kind, factory):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = factory()
                self._metrics[name] = m
            elif not isinstance(m, kind):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter,
                         lambda: Counter(name, help, self._lock))

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, Gauge, lambda: Gauge(name, help, self._lock))

    def histogram(self, name: str, bounds: Sequence[float] = TIME_BUCKETS_S,
                  help: str = "") -> Histogram:
        return self._get(name, Histogram,
                         lambda: Histogram(name, help, bounds, self._lock))

    def reset_histograms(self) -> None:
        """Reset every histogram's samples (counters and gauges keep
        their values).  Benchmark window scoping only — see
        ``Histogram.reset``."""
        with self._lock:
            items = list(self._metrics.values())
        for m in items:
            if isinstance(m, Histogram):
                m.reset()

    def snapshot(self) -> Dict[str, Any]:
        """Plain-dict view: counters/gauges as numbers, histograms as
        p50/p90/p99 summaries.  Safe to json-serialize."""
        with self._lock:
            items = list(self._metrics.items())
        out: Dict[str, Any] = {"counters": {}, "gauges": {}, "histograms": {}}
        for name, m in items:
            if isinstance(m, Counter):
                out["counters"][name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][name] = m.value
            else:
                out["histograms"][name] = m.summary()
        return out

    def render(self) -> str:
        """Prometheus text exposition (version 0.0.4)."""
        with self._lock:
            items = sorted(self._metrics.items())
        lines: List[str] = []
        for name, m in items:
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            if isinstance(m, Counter):
                lines.append(f"# TYPE {name} counter")
                lines.append(f"{name} {_fmt(m.value)}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {name} gauge")
                lines.append(f"{name} {_fmt(m.value)}")
            else:
                lines.append(f"# TYPE {name} histogram")
                for ub, cum in m.buckets():
                    le = "+Inf" if ub == float("inf") else _fmt(ub)
                    lines.append(f'{name}_bucket{{le="{le}"}} {cum}')
                lines.append(f"{name}_sum {_fmt(m.sum)}")
                lines.append(f"{name}_count {m.count}")
        return "\n".join(lines) + "\n"


def _fmt(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def parse_prometheus(text: str) -> Dict[str, Any]:
    """Parse the subset of the text exposition format ``render`` emits.

    Returns ``{"counters": {name: v}, "gauges": {name: v},
    "histograms": {name: {"buckets": [(le, cum)], "sum": s, "count": n}}}``.
    Used by the tests and benches to cross-check /metrics against
    ``Engine.snapshot()``.
    """
    out: Dict[str, Any] = {"counters": {}, "gauges": {}, "histograms": {}}
    types: Dict[str, str] = {}
    for ln in text.splitlines():
        ln = ln.strip()
        if not ln:
            continue
        if ln.startswith("#"):
            parts = ln.split(None, 3)
            if len(parts) >= 3 and parts[1] == "TYPE":
                types[parts[2]] = parts[3] if len(parts) > 3 else ""
            continue
        name_part, _, val = ln.rpartition(" ")
        value = float(val)
        if "{" in name_part:
            base, _, label = name_part.partition("{")
            label = label.rstrip("}")
            if base.endswith("_bucket") and label.startswith('le="'):
                hname = base[: -len("_bucket")]
                le_s = label[4:-1]
                le = float("inf") if le_s == "+Inf" else float(le_s)
                h = out["histograms"].setdefault(
                    hname, {"buckets": [], "sum": 0.0, "count": 0})
                h["buckets"].append((le, value))
            continue
        if name_part.endswith("_sum") and name_part[:-4] in out["histograms"]:
            out["histograms"][name_part[:-4]]["sum"] = value
        elif (name_part.endswith("_count")
              and name_part[:-6] in out["histograms"]):
            out["histograms"][name_part[:-6]]["count"] = int(value)
        elif types.get(name_part) == "gauge":
            out["gauges"][name_part] = value
        else:
            out["counters"][name_part] = value
    return out


# ---------------------------------------------------------------------------
# tracing

WALL = "wall"
MODELED = "modeled"
_CLOCKS = (WALL, MODELED)


@dataclass
class _Track:
    pid: int
    tid: int
    clock: str
    stack: List[Tuple[str, float]] = field(default_factory=list)
    last_ts: float = 0.0


class Tracer:
    """Chrome trace-event recorder with per-track clock discipline.

    Timestamps come in as *seconds* on the track's clock and are stored
    in microseconds (the trace-event unit).  Duration events (``B``/``E``)
    keep a per-track stack so exports always have matched pairs; async
    spans (``b``/``e``) are matched per ``(pid, id)`` and model the
    overlapping request-queued intervals that don't nest.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._events: List[Dict[str, Any]] = []
        self._pids: Dict[str, int] = {}
        self._tracks: Dict[Tuple[str, str], _Track] = {}
        self._open_async: Dict[Tuple[int, str], List[str]] = {}

    # -- track bookkeeping --------------------------------------------------

    def _track(self, process: str, thread: str, clock: str) -> _Track:
        if clock not in _CLOCKS:
            raise ValueError(f"unknown clock {clock!r}")
        key = (process, thread)
        tr = self._tracks.get(key)
        if tr is None:
            pid = self._pids.get(process)
            if pid is None:
                pid = len(self._pids) + 1
                self._pids[process] = pid
                self._events.append({
                    "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                    "args": {"name": process}})
            tid = 1 + sum(1 for k in self._tracks if k[0] == process)
            tr = _Track(pid=pid, tid=tid, clock=clock)
            self._tracks[key] = tr
            self._events.append({
                "name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
                "args": {"name": thread}})
        elif tr.clock != clock:
            raise ValueError(
                f"track {process}/{thread} is on the {tr.clock!r} clock; "
                f"refusing to mix in {clock!r} events")
        return tr

    def _push(self, tr: _Track, ev: Dict[str, Any]) -> None:
        tr.last_ts = max(tr.last_ts, ev["ts"])
        self._events.append(ev)

    # -- duration spans -----------------------------------------------------

    def begin(self, process: str, thread: str, name: str, ts_s: float,
              *, clock: str = WALL,
              args: Optional[Dict[str, Any]] = None) -> None:
        with self._lock:
            tr = self._track(process, thread, clock)
            ts = ts_s * 1e6
            tr.stack.append((name, ts))
            ev = {"name": name, "cat": clock, "ph": "B",
                  "ts": ts, "pid": tr.pid, "tid": tr.tid}
            if args:
                ev["args"] = args
            self._push(tr, ev)

    def end(self, process: str, thread: str, ts_s: float,
            *, clock: str = WALL,
            args: Optional[Dict[str, Any]] = None) -> None:
        with self._lock:
            tr = self._track(process, thread, clock)
            if not tr.stack:
                raise RuntimeError(
                    f"end() with no open span on {process}/{thread}")
            name, begin_ts = tr.stack.pop()
            ts = max(ts_s * 1e6, begin_ts)
            ev = {"name": name, "cat": clock, "ph": "E",
                  "ts": ts, "pid": tr.pid, "tid": tr.tid}
            if args:
                ev["args"] = args
            self._push(tr, ev)

    def complete(self, process: str, thread: str, name: str, ts_s: float,
                 dur_s: float, *, clock: str = WALL,
                 args: Optional[Dict[str, Any]] = None) -> None:
        """A self-contained ``X`` event (start + duration)."""
        with self._lock:
            tr = self._track(process, thread, clock)
            ev = {"name": name, "cat": clock, "ph": "X",
                  "ts": ts_s * 1e6, "dur": max(dur_s, 0.0) * 1e6,
                  "pid": tr.pid, "tid": tr.tid}
            if args:
                ev["args"] = args
            tr.last_ts = max(tr.last_ts, ev["ts"] + ev["dur"])
            self._events.append(ev)

    def instant(self, process: str, thread: str, name: str, ts_s: float,
                *, clock: str = WALL,
                args: Optional[Dict[str, Any]] = None) -> None:
        with self._lock:
            tr = self._track(process, thread, clock)
            ev = {"name": name, "cat": clock, "ph": "i", "s": "t",
                  "ts": ts_s * 1e6, "pid": tr.pid, "tid": tr.tid}
            if args:
                ev["args"] = args
            self._push(tr, ev)

    # -- async (non-nesting) spans ------------------------------------------

    def async_begin(self, process: str, thread: str, name: str,
                    span_id: Any, ts_s: float, *, clock: str = WALL,
                    args: Optional[Dict[str, Any]] = None) -> None:
        with self._lock:
            tr = self._track(process, thread, clock)
            sid = str(span_id)
            ev = {"name": name, "cat": clock, "ph": "b", "id": sid,
                  "ts": ts_s * 1e6, "pid": tr.pid, "tid": tr.tid}
            if args:
                ev["args"] = args
            self._open_async.setdefault((tr.pid, sid), []).append(name)
            self._push(tr, ev)

    def async_end(self, process: str, thread: str, span_id: Any,
                  ts_s: float, *, clock: str = WALL,
                  args: Optional[Dict[str, Any]] = None) -> None:
        with self._lock:
            tr = self._track(process, thread, clock)
            sid = str(span_id)
            open_names = self._open_async.get((tr.pid, sid))
            if not open_names:
                raise RuntimeError(
                    f"async_end() with no open span id={sid} in {process}")
            name = open_names.pop()
            if not open_names:
                del self._open_async[(tr.pid, sid)]
            ev = {"name": name, "cat": clock, "ph": "e", "id": sid,
                  "ts": ts_s * 1e6, "pid": tr.pid, "tid": tr.tid}
            if args:
                ev["args"] = args
            self._push(tr, ev)

    # -- export -------------------------------------------------------------

    def chrome_trace(self) -> Dict[str, Any]:
        """Snapshot into a Perfetto-loadable dict.

        Spans still open at snapshot time (a live engine mid-request)
        are closed *in the copy* at the track's latest timestamp, so the
        export always validates; the live stacks are untouched and a
        later snapshot sees the spans still running.
        """
        with self._lock:
            events = [dict(ev) for ev in self._events]
            for (process, thread), tr in self._tracks.items():
                for name, _begin_ts in reversed(tr.stack):
                    events.append({
                        "name": name, "cat": tr.clock, "ph": "E",
                        "ts": tr.last_ts, "pid": tr.pid, "tid": tr.tid,
                        "args": {"snapshot_closed": True}})
            for (pid, sid), names in self._open_async.items():
                last = max((t.last_ts for t in self._tracks.values()
                            if t.pid == pid), default=0.0)
                for name in reversed(names):
                    events.append({
                        "name": name, "cat": WALL, "ph": "e", "id": sid,
                        "ts": last, "pid": pid, "tid": 0,
                        "args": {"snapshot_closed": True}})
        meta = [ev for ev in events if ev["ph"] == "M"]
        rest = [ev for ev in events if ev["ph"] != "M"]
        rest.sort(key=lambda ev: ev["ts"])          # stable: ties keep order
        return {"traceEvents": meta + rest, "displayTimeUnit": "ms"}

    def event_count(self) -> int:
        with self._lock:
            return len(self._events)


def validate_chrome_trace(trace: Dict[str, Any]) -> int:
    """Schema-check a Chrome trace dict; returns the event count.

    Raises ``ValueError`` on: missing required fields, per-track
    timestamps out of order, unmatched ``B``/``E`` pairs, unmatched
    async ``b``/``e`` pairs, negative ``X`` durations, or two clock
    domains (``cat``) sharing one ``(pid, tid)`` track.
    """
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        raise ValueError("trace must be a dict with a 'traceEvents' list")
    events = trace["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    last_ts: Dict[Tuple[Any, Any], float] = {}
    clocks: Dict[Tuple[Any, Any], str] = {}
    stacks: Dict[Tuple[Any, Any], List[str]] = {}
    async_open: Dict[Tuple[Any, str], int] = {}
    for i, ev in enumerate(events):
        for k in ("name", "ph", "pid", "tid"):
            if k not in ev:
                raise ValueError(f"event {i} missing {k!r}: {ev!r}")
        ph = ev["ph"]
        if ph == "M":
            continue
        if "ts" not in ev or not isinstance(ev["ts"], (int, float)):
            raise ValueError(f"event {i} has no numeric ts: {ev!r}")
        key = (ev["pid"], ev["tid"])
        ts = ev["ts"]
        if ts < last_ts.get(key, float("-inf")):
            raise ValueError(
                f"event {i} out of order on track {key}: "
                f"{ts} < {last_ts[key]}")
        last_ts[key] = ts
        cat = ev.get("cat", "")
        if cat:
            prev = clocks.setdefault(key, cat)
            if prev != cat:
                raise ValueError(
                    f"track {key} mixes clocks {prev!r} and {cat!r}")
        if ph == "B":
            stacks.setdefault(key, []).append(ev["name"])
        elif ph == "E":
            st = stacks.get(key)
            if not st:
                raise ValueError(f"event {i}: E without matching B on {key}")
            st.pop()
        elif ph == "X":
            if ev.get("dur", 0) < 0:
                raise ValueError(f"event {i}: negative dur")
        elif ph == "b":
            akey = (ev["pid"], str(ev.get("id")))
            async_open[akey] = async_open.get(akey, 0) + 1
        elif ph == "e":
            akey = (ev["pid"], str(ev.get("id")))
            if async_open.get(akey, 0) <= 0:
                raise ValueError(
                    f"event {i}: async 'e' without open 'b' (id={akey[1]})")
            async_open[akey] -= 1
        elif ph == "i":
            pass
        else:
            raise ValueError(f"event {i}: unknown phase {ph!r}")
    leftovers = [k for k, st in stacks.items() if st]
    if leftovers:
        raise ValueError(f"unclosed B spans on tracks {leftovers}")
    dangling = [k for k, n in async_open.items() if n > 0]
    if dangling:
        raise ValueError(f"unclosed async spans {dangling}")
    return len(events)


# ---------------------------------------------------------------------------
# the bundle the engine wires through


class Observability:
    """Registry + tracer pair handed to scheduler / resilience layers."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.registry = MetricsRegistry()
        self.tracer = Tracer()

    def snapshot(self) -> Dict[str, Any]:
        return self.registry.snapshot()

    def write_trace(self, path: str) -> int:
        trace = self.tracer.chrome_trace()
        n = validate_chrome_trace(trace)
        with open(path, "w") as fh:
            json.dump(trace, fh)
        return n


# ---------------------------------------------------------------------------
# exporters for already-recorded modeled timelines


def simulator_trace(tracer: Tracer, result: Any,
                    *, process: str = "simulator") -> int:
    """Export ``SimResult.firings`` as one modeled-clock track per unit.

    Each firing becomes an ``X`` event spanning its modeled
    ``start_s → finish_s`` window on the unit that executed it.
    Returns the number of events added.
    """
    n = 0
    for f in getattr(result, "firings", ()):
        unit = f.unit or "local"
        tracer.complete(
            process, unit, f.actor, f.start_s, f.finish_s - f.start_s,
            clock=MODELED,
            args={"firing": f.firing_index, "modeled_s": f.modeled_s})
        n += 1
    return n


def pipeline_trace(tracer: Tracer, schedule: Any,
                   *, process: str = "pipeline") -> int:
    """Export a ``PipelineSchedule`` (``run_pipelined``) as modeled-clock
    unit tracks: each ``StageExec`` becomes an ``X`` event spanning its
    ``start_s → finish_s`` window, so the frame-overlap that produces
    the pipelining speedup is visible as staggered slices across units.
    Returns the number of events added.
    """
    n = 0
    for ex in getattr(schedule, "entries", ()):
        tracer.complete(
            process, ex.unit or "local", f"frame {ex.frame}",
            ex.start_s, ex.finish_s - ex.start_s, clock=MODELED,
            args={"frame": ex.frame})
        n += 1
    return n


def failover_trace(tracer: Tracer, events: Sequence[Any],
                   *, process: str = "failover",
                   thread: str = "controller") -> int:
    """Export ``FailoverEvent`` records as modeled-clock spans.

    Per event: a ``detection`` span (fail → detect), a ``resynthesis``
    span (detect → detect + resynth), and a ``failover`` instant carrying
    the mapping change.  Returns the number of trace events added.
    """
    n = 0
    for ev in events:
        tracer.complete(
            process, thread, "detection", ev.t_fail_s,
            ev.t_detect_s - ev.t_fail_s, clock=MODELED,
            args={"dead_units": list(ev.dead_units),
                  "dead_links": [list(l) for l in ev.dead_links]})
        tracer.complete(
            process, thread, "resynthesis", ev.t_detect_s, ev.resynth_s,
            clock=MODELED,
            args={"mapping_from": ev.mapping_from, "mapping_to": ev.mapping_to})
        tracer.instant(
            process, thread, "failover",
            ev.t_detect_s + ev.resynth_s, clock=MODELED,
            args={"recovery_latency_s": ev.recovery_latency_s,
                  "replayed_frames": ev.replayed_frames})
        n += 3
    return n
