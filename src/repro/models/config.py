"""Model configuration: one dataclass covering all 10 assigned architectures.

The architecture zoo spans six families (dense, MoE, SSM, hybrid, VLM,
audio enc-dec); a single config describes any of them through the
``layer_pattern`` — a repeating period of block kinds — plus family-
specific fields. Edge-PRUNE's technique (dataflow partitioning) is
architecture-agnostic, so every config here can also be exported as a
VR-PRUNE actor graph (see ``models.transformer.to_actor_graph``).

Block kinds
-----------
``attn``        global causal self-attention (GQA + RoPE)
``attn_local``  sliding-window causal self-attention (window = cfg.window)
``rglru``       RG-LRU gated linear recurrence block (RecurrentGemma)
``mlstm``       xLSTM matrix-memory LSTM block (linear-attention family)
``slstm``       xLSTM scalar-memory LSTM block (sequential exponential gating)
``enc_attn``    bidirectional encoder self-attention (enc-dec only)

``layer_pattern`` is tiled over ``n_layers``: e.g. gemma3's 5:1
local:global ratio is ``("attn_local",)*5 + ("attn",)`` and 26 layers =
4 full periods + 2 remainder layers. The remainder is unrolled; full
periods are executed under one ``lax.scan`` with stacked params, which
keeps HLO size (and therefore dry-run compile time) independent of depth.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    # Token-choice routing with fixed per-token-group capacity
    # (Switch-Transformer style dense dispatch; see models/moe.py).
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None    # default d_model // n_heads
    layer_pattern: Tuple[str, ...] = ("attn",)
    window: int = 0                   # sliding-window width for attn_local
    moe: Optional[MoEConfig] = None

    # attention details
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rope_theta_global: Optional[float] = None   # gemma3: 1e6 on global layers
    rope_fraction: float = 1.0        # chatglm "RoPE 2d": rotary on half dims

    # encoder-decoder (audio): n_encoder_layers > 0 enables the encoder
    # stack + cross-attention in every decoder layer.
    n_encoder_layers: int = 0

    # multimodal frontend stub: the frontend (ViT / mel+conv codec) is NOT
    # implemented (the allowed carve-out) — input_specs() provides
    # precomputed embeddings of shape (batch, frontend_tokens, frontend_dim)
    # and the in-model projector maps frontend_dim -> d_model.
    frontend: Optional[str] = None    # "vision" | "audio"
    frontend_dim: int = 0
    frontend_tokens: int = 0

    # ssm / hybrid
    rglru_conv_width: int = 4         # RG-LRU temporal conv width
    mlstm_proj_factor: float = 2.0    # xLSTM mLSTM up-projection factor
    slstm_proj_factor: float = 4.0 / 3.0

    norm_eps: float = 1e-6
    act: str = "silu"                 # silu | gelu
    tie_embeddings: bool = False
    dtype: str = "bfloat16"           # activation/compute dtype
    param_dtype: str = "float32"      # parameter dtype

    # implementation switches
    # "einsum" (Switch dense dispatch) wins on the collective-bound TPU
    # mesh; "gather" (index dispatch) trades 25% lower flops for 2.2x the
    # collective bytes under GSPMD — kept for ablation (§Perf iter 4).
    moe_impl: str = "einsum"
    attn_impl: str = "xla"            # "xla" (chunked lax flash) | "pallas"
    attn_chunk: int = 1024            # flash q/kv block size (xla impl)
    remat: bool = True                # checkpoint each scan period in train
    # Sub-quadratic decode support: archs whose every layer's decode cost
    # is O(window) or O(1) can run long_500k. Derived, but overridable.
    max_cache_len: int = 0            # 0 = no cap (full attention layers)

    def __post_init__(self):
        if self.n_heads % max(self.n_kv_heads, 1) != 0:
            raise ValueError(f"{self.name}: n_heads must be divisible by n_kv_heads")
        for k in self.layer_pattern:
            if k not in ("attn", "attn_local", "rglru", "mlstm", "slstm"):
                raise ValueError(f"{self.name}: unknown block kind {k}")
        if any(k == "attn_local" for k in self.layer_pattern) and self.window <= 0:
            raise ValueError(f"{self.name}: attn_local requires window > 0")

    # ------------------------------------------------------------------
    @property
    def padded_vocab_size(self) -> int:
        """Vocab padded to a multiple of 128 (MXU lane alignment AND mesh
        divisibility: 256206 % 16 != 0 left seamless' logits unsharded —
        3 x 16.8 GB fp32 buffers; §Perf notes). Pad ids are masked to
        -1e30 in the head, so they are unsampleable and contribute
        nothing to the loss."""
        return -(-self.vocab_size // 128) * 128

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def period(self) -> Tuple[str, ...]:
        return self.layer_pattern

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.layer_pattern)

    @property
    def remainder_kinds(self) -> Tuple[str, ...]:
        r = self.n_layers % len(self.layer_pattern)
        return self.layer_pattern[:r]

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """The full, flattened per-layer kind list (length n_layers)."""
        return self.layer_pattern * self.n_periods + self.remainder_kinds

    @property
    def is_subquadratic_decode(self) -> bool:
        """True iff per-token decode memory is bounded independently of the
        context length on every layer: recurrent blocks are O(1); local
        attention is O(window). Pure-full-attention archs are quadratic-
        family and skip long_500k (see DESIGN.md §4)."""
        return all(k != "attn" for k in self.layer_kinds)

    @property
    def decode_cache_token_bytes(self) -> int:
        """KV/state bytes per cached token per layer-average — used by the
        explorer's link model for decode partition points."""
        kd = self.resolved_head_dim * self.n_kv_heads
        itemsize = 2 if self.dtype == "bfloat16" else 4
        return 2 * kd * itemsize

    def param_count(self) -> int:
        """Analytic total parameter count N (for 6·N·D MODEL_FLOPS)."""
        d, hd = self.d_model, self.resolved_head_dim
        qkv_out = (self.n_heads + 2 * self.n_kv_heads) * hd
        n = self.vocab_size * d                      # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d                 # lm head
        if self.frontend:
            n += self.frontend_dim * d + d * d       # 2-layer projector
        per_kind = {}
        per_kind["attn"] = d * qkv_out + self.n_heads * hd * d + 2 * d
        per_kind["attn_local"] = per_kind["attn"]
        per_kind["rglru"] = (d * (2 * d) + self.rglru_conv_width * d + 3 * d
                             + d * d + 2 * d)
        dm = int(self.mlstm_proj_factor * d)
        per_kind["mlstm"] = d * 2 * dm + 3 * dm * dm // max(self.n_heads, 1) \
            + dm * d + 2 * d
        ds = int(self.slstm_proj_factor * d)
        per_kind["slstm"] = 4 * d * d + 4 * d * d // max(self.n_heads, 1) \
            + d * ds + ds * d + 2 * d
        mlp = 3 * d * self.d_ff if self.d_ff else 0
        if self.moe:
            shared = 3 * d * self.moe.d_ff_expert * self.moe.n_shared_experts
            routed = 3 * d * self.moe.d_ff_expert * self.moe.n_experts
            router = d * self.moe.n_experts
            mlp = shared + routed + router
        for k in self.layer_kinds:
            n += per_kind[k]
            if k in ("attn", "attn_local", "rglru"):
                n += mlp
        # encoder stack (self-attn + mlp) + cross-attn in decoder layers
        if self.n_encoder_layers:
            enc = per_kind["attn"] + 3 * d * self.d_ff
            n += self.n_encoder_layers * enc
            n += self.n_layers * (per_kind["attn"])   # cross-attention
        return int(n)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k + shared experts)."""
        if not self.moe:
            return self.param_count()
        full = self.param_count()
        routed_all = self.n_layers * 3 * self.d_model * self.moe.d_ff_expert \
            * self.moe.n_experts
        routed_active = self.n_layers * 3 * self.d_model * self.moe.d_ff_expert \
            * self.moe.top_k
        return int(full - routed_all + routed_active)

    def smoke(self) -> "ModelConfig":
        """Reduced same-family variant for CPU smoke tests: 2 layers (one
        full period truncated to <=2 kinds), d_model <= 512, <= 4 experts."""
        pat = self.layer_pattern
        if len(pat) > 2:
            # keep kind diversity: one of each distinct kind, max 2
            kinds = list(dict.fromkeys(pat))[:2]
            pat = tuple(kinds) if len(kinds) == 2 else (kinds[0], kinds[0])
        elif len(pat) == 1:
            pat = pat * 2
        d = min(self.d_model, 256)
        heads = min(self.n_heads, 4)
        kv = max(1, min(self.n_kv_heads, heads))
        while heads % kv:
            kv -= 1
        moe = None
        if self.moe:
            # capacity_factor high enough that no token ever drops: keeps
            # the smoke decode-vs-forward consistency check exact.
            moe = replace(self.moe, n_experts=4, top_k=min(self.moe.top_k, 2),
                          d_ff_expert=64, capacity_factor=4.0,
                          n_shared_experts=min(self.moe.n_shared_experts, 1))
        return replace(
            self, name=self.name + "-smoke", n_layers=2, d_model=d,
            n_heads=heads, n_kv_heads=kv, head_dim=d // heads,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512), layer_pattern=pat,
            window=min(self.window, 8) if self.window else 0, moe=moe,
            n_encoder_layers=2 if self.n_encoder_layers else 0,
            frontend_dim=min(self.frontend_dim, 64) if self.frontend else 0,
            frontend_tokens=min(self.frontend_tokens, 8) if self.frontend else 0,
            dtype="float32", param_dtype="float32", attn_chunk=8,
            remat=False)
