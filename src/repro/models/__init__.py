"""Model definitions: the paper's CNN applications and the assigned
architecture zoo (dense / MoE / SSM / hybrid / enc-dec / VLM / audio)."""
