"""Mixture-of-Experts MLP: token-choice top-k routing with fixed capacity.

Covers qwen2-moe (4 shared + 60 routed, top-4) and qwen3-moe (128 routed,
top-8). The production formulation is Switch-Transformer-style *dense
dispatch*: a one-hot dispatch tensor (T, E, C) routes each token to its
top-k experts' capacity slots; expert FFNs run as one batched einsum over
the expert dimension, which shards cleanly over the mesh "model" axis
(expert parallelism) — the dispatch/combine einsums lower to all-to-all-
like collectives under SPMD. Tokens beyond an expert's capacity are
dropped (their residual passes through), the standard trade-off.

``load_balance_loss`` is the usual Switch aux loss: E * sum(frac_tokens *
frac_router_prob); a router z-loss keeps logits bounded.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import act_fn, dense_init, mlp_apply, mlp_init, rms_norm


def moe_init(key, cfg, dtype) -> dict:
    d = cfg.d_model
    m = cfg.moe
    ks = jax.random.split(key, 5)
    p = {
        "ln": jnp.zeros((d,), dtype),
        "router": dense_init(ks[0], d, (m.n_experts,), jnp.float32),
        # stacked expert FFNs: (E, d, f) / (E, f, d)
        "w_gate": dense_init(ks[1], d, (m.n_experts, m.d_ff_expert),
                             dtype).transpose(1, 0, 2),
        "w_up": dense_init(ks[2], d, (m.n_experts, m.d_ff_expert),
                           dtype).transpose(1, 0, 2),
        "w_down": dense_init(ks[3], m.d_ff_expert, (m.n_experts, d),
                             dtype).transpose(1, 0, 2),
    }
    if m.n_shared_experts:
        p["shared"] = mlp_init(ks[4], d, m.d_ff_expert * m.n_shared_experts,
                               dtype)
    return p


def _capacity(tokens_per_group: int, cfg) -> int:
    m = cfg.moe
    c = int(tokens_per_group * m.top_k * m.capacity_factor / m.n_experts)
    # multiple of 32 so the capacity axis stays mesh-shardable (the
    # fallback expert-tensor layout for non-dividing expert counts)
    return max(c - c % -32, 32)


def route(router_logits: jax.Array, cfg, capacity: int, *,
          compute_dtype=jnp.float32
          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """router_logits: (G, T, E). Returns (dispatch (G,T,E,C) bool,
    combine (G,T,E,C) compute_dtype, aux_loss scalar).

    The (G,T,E,C) tensors are the memory/collective hot spot of the MoE
    layer (94 x 32 GB of fp32 all-gathers in the qwen3 train_4k baseline
    — see EXPERIMENTS.md §Perf). Two structural choices keep them cheap:
    * everything per-expert (one-hot, position-in-queue, capacity mask)
      is ELEMENTWISE in E, so an E-sharded ("model"-axis) constraint
      applied by the caller propagates through the whole routing calc —
      only the (G,T,K) top-k selection sees the full expert dim;
    * ``combine`` is produced in the caller's compute dtype (bf16), and
      the capacity-slot one-hot is wrapped in stop_gradient (it is
      piecewise constant), so AD never rebuilds fp32 (G,T,E,C) tensors.
    """
    g, t, e = router_logits.shape
    m = cfg.moe
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    top_p, top_idx = jax.lax.top_k(probs, m.top_k)           # (G, T, K)
    # renormalize the selected probabilities (qwen-style norm_topk_prob)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # expert one-hot per k-slot: (G, T, K, E)
    onehot = jax.nn.one_hot(top_idx, e, dtype=jnp.int32)
    # position of each (token, k) within its expert's queue, in token order
    # priority: lower k first, then token order (standard Switch ordering
    # flattens k-major so that 1st choices win capacity over 2nd choices).
    pos = jnp.cumsum(onehot.transpose(0, 2, 1, 3).reshape(g, t * m.top_k, e),
                     axis=1) - 1                              # (G, K*T, E)
    pos = pos.reshape(g, m.top_k, t, e).transpose(0, 2, 1, 3)  # (G, T, K, E)
    pos = (pos * onehot).sum(-1)                              # (G, T, K)
    keep = pos < capacity
    disp_k = (onehot * keep[..., None]).astype(jnp.bool_)     # (G, T, K, E)
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, capacity), capacity + 1,
                            dtype=compute_dtype)[..., :capacity]  # (G,T,K,C)
    pos_oh = jax.lax.stop_gradient(pos_oh)
    disp_f = jax.lax.stop_gradient(disp_k.astype(compute_dtype))
    # (G, T, E, C)
    dispatch = jnp.einsum("gtke,gtkc->gtec", disp_f, pos_oh) > 0
    combine = jnp.einsum("gtke,gtkc,gtk->gtec", disp_f, pos_oh,
                         top_p.astype(compute_dtype))

    # Switch aux loss + router z-loss
    frac_tokens = jnp.mean(onehot.astype(jnp.float32).sum(2), axis=1)  # (G,E)
    frac_probs = probs.mean(axis=1)                                    # (G,E)
    aux = e * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))
    z = jnp.mean(jax.nn.logsumexp(router_logits, axis=-1) ** 2)
    return dispatch, combine, aux + 1e-3 * z


def route_indices(router_logits: jax.Array, cfg, capacity: int):
    """Index-form routing: returns (top_idx (G,T,K) expert ids,
    pos (G,T,K) slot-in-expert, keep (G,T,K) bool, top_p (G,T,K) f32,
    aux_loss). Shares the exact assignment semantics of ``route`` (k-major
    first-choice-wins capacity) without materializing (G,T,E,C)."""
    g, t, e = router_logits.shape
    m = cfg.moe
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    top_p, top_idx = jax.lax.top_k(probs, m.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(top_idx, e, dtype=jnp.int32)
    pos = jnp.cumsum(onehot.transpose(0, 2, 1, 3).reshape(g, t * m.top_k, e),
                     axis=1) - 1
    pos = pos.reshape(g, m.top_k, t, e).transpose(0, 2, 1, 3)
    pos = (pos * onehot).sum(-1)
    keep = pos < capacity
    frac_tokens = jnp.mean(onehot.astype(jnp.float32).sum(2), axis=1)
    aux = e * jnp.mean(jnp.sum(frac_tokens * probs.mean(axis=1), axis=-1))
    z = jnp.mean(jax.nn.logsumexp(router_logits, axis=-1) ** 2)
    return top_idx, pos, keep, top_p, aux + 1e-3 * z


def moe_apply_gather(p, x, cfg, ctx=None) -> Tuple[jax.Array, jax.Array]:
    """Gather/scatter dispatch: the beyond-einsum formulation.

    The Switch-style dense dispatch spends two (T x E x C) x D einsums —
    pure masked data movement executed as matmuls — and their (G,T,E,C)
    operands dominated both collectives and temp memory in the qwen3
    train_4k dry-run. Here dispatch is one take-along gather into the
    (G, E, C, D) expert buffers (slot->token indices built by a tiny int32
    scatter) and combine is a (G, T, K, D) gather + weighted sum. AD gives
    the scatter-add transposes. No (G,T,E,C) tensor ever exists.
    See EXPERIMENTS.md §Perf iteration 4.
    """
    b, s, d = x.shape
    m = cfg.moe
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    logits = (h @ p["router"].astype(h.dtype)).astype(jnp.float32)
    if ctx is not None:
        logits = ctx.batch_only(logits)
    cap = _capacity(s, cfg)
    top_idx, pos, keep, top_p, aux = route_indices(logits, cfg, cap)

    # slot -> token index table (G, E, C); sentinel = s (the zero pad row).
    # dropped (token, k) pairs write to out-of-bounds slot c=cap and are
    # discarded by mode="drop".
    gi = jnp.arange(b)[:, None, None]
    ti = jnp.broadcast_to(jnp.arange(s)[None, :, None], top_idx.shape)
    idx_token = jnp.full((b, m.n_experts, cap), s, jnp.int32)
    idx_token = idx_token.at[gi, top_idx,
                             jnp.where(keep, pos, cap)].set(ti, mode="drop")
    if ctx is not None:
        idx_token = ctx.expert_tensor(idx_token, expert_axis=1)

    h_pad = jnp.concatenate([h, jnp.zeros((b, 1, d), h.dtype)], axis=1)
    xe = jax.vmap(lambda hh, ii: hh[ii])(h_pad, idx_token)  # (G, E, C, D)
    if ctx is not None:
        xe = ctx.expert_tensor(xe, expert_axis=1)
    act = act_fn(cfg.act)
    hidden = act(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])) \
        * jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    ye = jnp.einsum("gecf,efd->gecd", hidden, p["w_down"])
    if ctx is not None:
        ye = ctx.expert_tensor(ye, expert_axis=1)

    # combine: gather each token's K slots and weight (dropped -> w=0)
    flat_slot = top_idx * cap + jnp.where(keep, pos, 0)       # (G, T, K)
    ye_flat = ye.reshape(b, m.n_experts * cap, d)
    yk = jax.vmap(lambda yy, ii: yy[ii])(ye_flat, flat_slot)  # (G, T, K, D)
    w = (top_p * keep).astype(h.dtype)                        # (G, T, K)
    y = jnp.einsum("gtk,gtkd->gtd", w, yk)
    if m.n_shared_experts:
        sh = p["shared"]
        gx = act(h @ sh["w_gate"]) * (h @ sh["w_up"])
        y = y + gx @ sh["w_down"]
    return x + y.astype(x.dtype), aux


def moe_apply(p, x, cfg, ctx=None) -> Tuple[jax.Array, jax.Array]:
    """Dispatch on cfg.moe_impl: "gather" (default, index-form dispatch)
    or "einsum" (Switch-style dense dispatch, kept as the reference
    production path and for ablation)."""
    if getattr(cfg, "moe_impl", "gather") == "gather":
        return moe_apply_gather(p, x, cfg, ctx=ctx)
    return moe_apply_einsum(p, x, cfg, ctx=ctx)


def moe_apply_einsum(p, x, cfg, ctx=None) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (B, S, D), aux_loss. Groups = batch rows, so the
    capacity is per-row — this keeps the dispatch tensor's token dim
    shardable along the batch/data axis. ``ctx`` (ShardCtx) pins the
    (G,T,E,C) routing tensors and (G,E,C,D) expert buffers to
    expert-on-"model" sharding — expert parallelism — so the dispatch/
    combine einsums lower to all-to-all-sized transfers instead of
    full-tensor all-gathers."""
    b, s, d = x.shape
    m = cfg.moe
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    # router matmul in compute dtype; only the small (G,T,E) logits go f32
    logits = (h @ p["router"].astype(h.dtype)).astype(jnp.float32)
    if ctx is not None:
        # keep the (G,T,E) logits batch-sharded: otherwise the top_k /
        # aux-loss reductions pull a full-batch gather into every layer
        # (2 x 537 MB/layer observed; §Perf iteration 3)
        logits = ctx.batch_only(logits)
    cap = _capacity(s, cfg)
    dispatch, combine, aux = route(logits, cfg, cap, compute_dtype=h.dtype)
    if ctx is not None:
        dispatch = ctx.expert_tensor(dispatch, expert_axis=2)
        combine = ctx.expert_tensor(combine, expert_axis=2)
    # dispatch tokens into (G, E, C, D) expert buffers
    xe = jnp.einsum("gtec,gtd->gecd", dispatch.astype(h.dtype), h)
    if ctx is not None:
        xe = ctx.expert_tensor(xe, expert_axis=1)
    act = act_fn(cfg.act)
    hidden = act(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])) \
        * jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    ye = jnp.einsum("gecf,efd->gecd", hidden, p["w_down"])
    if ctx is not None:
        ye = ctx.expert_tensor(ye, expert_axis=1)
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(h.dtype), ye)
    if m.n_shared_experts:
        # shared experts run densely for every token (qwen2-moe)
        sh = p["shared"]
        g = act(h @ sh["w_gate"]) * (h @ sh["w_up"])
        y = y + g @ sh["w_down"]
    return x + y.astype(x.dtype), aux


def moe_apply_dense_oracle(p, x, cfg) -> Tuple[jax.Array, jax.Array]:
    """Reference: compute EVERY expert for every token, weight by the
    (renormalized) top-k router probs. No capacity, no dropping — the
    oracle that ``moe_apply`` approaches as capacity_factor -> inf.
    O(E/k) overcompute; tests only."""
    m = cfg.moe
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    logits = jnp.einsum("gtd,de->gte", h.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_idx = jax.lax.top_k(probs, m.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    weights = jnp.zeros_like(probs)
    weights = jax.vmap(jax.vmap(lambda w, i, v: w.at[i].set(v)))(
        weights, top_idx, top_p)                              # (G, T, E)
    act = act_fn(cfg.act)
    hidden = act(jnp.einsum("gtd,edf->gtef", h, p["w_gate"])) \
        * jnp.einsum("gtd,edf->gtef", h, p["w_up"])
    ye = jnp.einsum("gtef,efd->gted", hidden, p["w_down"])
    y = jnp.einsum("gte,gted->gtd", weights.astype(h.dtype), ye)
    if m.n_shared_experts:
        sh = p["shared"]
        g = act(h @ sh["w_gate"]) * (h @ sh["w_up"])
        y = y + g @ sh["w_down"]
    onehot = jax.nn.one_hot(top_idx, m.n_experts).sum(2)
    frac_tokens = onehot.mean(1)
    aux = m.n_experts * jnp.mean(jnp.sum(frac_tokens * probs.mean(1), -1))
    return x + y.astype(x.dtype), aux
