"""Generic multi-family model: assembles any ModelConfig into init /
forward / prefill / decode functions, and exports the model as a VR-PRUNE
actor graph so Edge-PRUNE's partitioning applies to every architecture.

Depth handling: the ``layer_pattern`` period is executed under one
``jax.lax.scan`` over stacked per-period parameters (n_periods repeats),
with the remainder layers unrolled. HLO size — and dry-run compile time —
is therefore O(period), not O(n_layers). The decode path carries the
per-layer caches through the same scan.

Layer = block (attn / attn_local / rglru / mlstm / slstm) + optional
MLP/MoE sublayer (attention and rglru kinds only; xLSTM blocks embed
their own projections, d_ff == 0).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import ssm as S
from repro.models.config import ModelConfig

_HAS_MLP = ("attn", "attn_local", "rglru")


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# gate / router / state-decay leaves stay fp32 for numerical stability
_KEEP_F32 = ("lam", "router", "b_if", "w_if", "b")


def cast_params_for_compute(params, cfg: ModelConfig):
    """Master params (fp32) -> compute dtype (bf16) at step entry, the
    standard mixed-precision scheme: optimizer state and updates stay
    fp32; matmuls run on the MXU in bf16."""
    ct = _dtype(cfg.dtype)

    def one(path, leaf):
        name = str(getattr(path[-1], "key", getattr(path[-1], "idx", "")))
        if name in _KEEP_F32 or not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        return leaf.astype(ct)
    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _block_init(key, kind: str, cfg: ModelConfig, dtype) -> dict:
    if kind in ("attn", "attn_local"):
        return L.attn_init(key, cfg, dtype)
    if kind == "rglru":
        return R.rglru_init(key, cfg, dtype)
    if kind == "mlstm":
        return S.mlstm_init(key, cfg, dtype)
    if kind == "slstm":
        return S.slstm_init(key, cfg, dtype)
    raise ValueError(kind)


def _layer_init(key, kind: str, cfg: ModelConfig, dtype, *,
                cross: bool = False) -> dict:
    ks = jax.random.split(key, 3)
    p = {"block": _block_init(ks[0], kind, cfg, dtype)}
    if kind in _HAS_MLP:
        if cfg.moe is not None:
            p["moe"] = M.moe_init(ks[1], cfg, dtype)
        elif cfg.d_ff:
            p["mlp"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype)
    if cross:
        p["cross"] = L.cross_attn_init(ks[2], cfg, dtype)
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    dtype = _dtype(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    d = cfg.d_model
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(keys[0],
                                    (cfg.padded_vocab_size, d), jnp.float32)
                  * 0.02).astype(dtype),
        "final_norm": jnp.zeros((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(keys[1], d,
                                         (cfg.padded_vocab_size,), dtype)
    if cfg.frontend:
        params["frontend_proj"] = {
            "w1": L.dense_init(keys[2], cfg.frontend_dim, (d,), dtype),
            "w2": L.dense_init(keys[3], d, (d,), dtype),
        }
    cross = cfg.n_encoder_layers > 0

    # decoder stack: stacked periods + remainder
    period = cfg.layer_pattern
    nrep = cfg.n_periods

    def stack_init(k, kind):
        return jax.vmap(lambda kk: _layer_init(kk, kind, cfg, dtype,
                                               cross=cross))(
            jax.random.split(k, nrep))

    pk = jax.random.split(keys[4], len(period))
    params["scan"] = [stack_init(pk[i], kind) if nrep else None
                      for i, kind in enumerate(period)]
    rk = jax.random.split(keys[5], max(len(cfg.remainder_kinds), 1))
    params["rem"] = [_layer_init(rk[i], kind, cfg, dtype, cross=cross)
                     for i, kind in enumerate(cfg.remainder_kinds)]

    if cfg.n_encoder_layers:
        ek = jax.random.split(keys[6], cfg.n_encoder_layers)
        params["encoder"] = [
            {"block": L.attn_init(ek[i], cfg, dtype),
             "mlp": L.mlp_init(jax.random.fold_in(ek[i], 1), d, cfg.d_ff,
                               dtype)}
            for i in range(cfg.n_encoder_layers)]
        params["enc_norm"] = jnp.zeros((d,), dtype)
    return params


# ---------------------------------------------------------------------------
# forward (train / no-cache)
# ---------------------------------------------------------------------------

def _layer_apply(p, x, kind: str, cfg: ModelConfig, *, positions,
                 enc_out=None, ctx=None) -> Tuple[jax.Array, jax.Array]:
    if ctx is not None:
        p = ctx.layer(p)
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "attn_local"):
        x = L.attn_apply(p["block"], x, cfg, kind=kind, positions=positions)
    elif kind == "rglru":
        x = R.rglru_apply(p["block"], x, cfg)
    elif kind == "mlstm":
        x = S.mlstm_apply(p["block"], x, cfg)
    elif kind == "slstm":
        x = S.slstm_apply(p["block"], x, cfg)
    if "cross" in p and enc_out is not None:
        x = L.cross_attn_apply(p["cross"], x, enc_out, cfg)
    if "moe" in p:
        x, aux = M.moe_apply(p["moe"], x, cfg, ctx=ctx)
    elif "mlp" in p:
        x = L.mlp_apply(p["mlp"], x, cfg)
    return x, aux


def _run_stack(params, x, cfg: ModelConfig, *, positions, enc_out=None,
               train: bool = True, ctx=None) -> Tuple[jax.Array, jax.Array]:
    period = cfg.layer_pattern
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.n_periods:
        def body(carry, slice_params):
            x, aux = carry
            for i, kind in enumerate(period):
                x, a = _layer_apply(slice_params[i], x, kind, cfg,
                                    positions=positions, enc_out=enc_out,
                                    ctx=ctx)
                aux = aux + a
            if ctx is not None:
                # shard the scan carry: this is the residual AD saves per
                # period for the backward pass
                x = ctx.act(x)
            return (x, aux), None
        body_fn = jax.checkpoint(body) if (cfg.remat and train) else body
        (x, aux_total), _ = jax.lax.scan(body_fn, (x, aux_total),
                                         params["scan"])
    for i, kind in enumerate(cfg.remainder_kinds):
        x, a = _layer_apply(params["rem"][i], x, kind, cfg,
                            positions=positions, enc_out=enc_out, ctx=ctx)
        aux_total = aux_total + a
    return x, aux_total


def _head_logits(x, params, cfg: ModelConfig):
    """LM head with vocab padding masked to -1e30 (never sampled, zero
    loss contribution). x: (..., D) -> (..., padded_vocab)."""
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, head.astype(x.dtype))
    else:
        logits = x @ head.astype(x.dtype)
    if cfg.padded_vocab_size != cfg.vocab_size:
        pad = jnp.arange(cfg.padded_vocab_size) >= cfg.vocab_size
        logits = jnp.where(pad, -1e30, logits.astype(jnp.float32)).astype(
            logits.dtype)
    return logits


def _embed_inputs(params, cfg: ModelConfig, tokens, embeds):
    dtype = _dtype(cfg.dtype)
    parts = []
    if embeds is not None and cfg.frontend and cfg.n_encoder_layers == 0:
        fp = params["frontend_proj"]
        e = jax.nn.gelu(embeds.astype(dtype) @ fp["w1"]) @ fp["w2"]
        parts.append(e)
    if tokens is not None:
        parts.append(params["embed"].astype(dtype)[tokens]
                     * math.sqrt(cfg.d_model))
    return jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]


def encode(params, cfg: ModelConfig, embeds: jax.Array, *,
           ctx=None) -> jax.Array:
    """Encoder stack over frontend embeddings (enc-dec archs)."""
    dtype = _dtype(cfg.dtype)
    fp = params["frontend_proj"]
    x = jax.nn.gelu(embeds.astype(dtype) @ fp["w1"]) @ fp["w2"]
    positions = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32),
                                 x.shape[:2])

    def enc_layer(x, lp):
        if ctx is not None:
            lp = ctx.layer(lp)
        x = L.attn_encoder_apply(lp["block"], x, cfg, positions=positions)
        x = L.mlp_apply(lp["mlp"], x, cfg)
        if ctx is not None:
            x = ctx.act(x)
        return x

    # rematerialize encoder layers like the decoder periods: without this
    # the 12-layer encoder at 4k dominates train temp (224 GB observed)
    if cfg.remat:
        enc_layer = jax.checkpoint(enc_layer)
    for lp in params["encoder"]:
        x = enc_layer(x, lp)
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward(params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            train: bool = True, ctx=None) -> Tuple[jax.Array, jax.Array]:
    """batch: {"tokens": (B,S) int32, optional "embeds": (B,F,fd)}.
    Returns (logits (B, S_total, V), moe_aux_loss)."""
    params = cast_params_for_compute(params, cfg)
    tokens = batch.get("tokens")
    embeds = batch.get("embeds")
    enc_out = None
    if cfg.n_encoder_layers:
        enc_out = encode(params, cfg, embeds, ctx=ctx)
        x = _embed_inputs(params, cfg, tokens, None)
    else:
        x = _embed_inputs(params, cfg, tokens, embeds)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32),
                                 x.shape[:2])
    x, aux = _run_stack(params, x, cfg, positions=positions, enc_out=enc_out,
                        train=train, ctx=ctx)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if ctx is not None:
        x = ctx.batch_only(x)   # avoid model-axis conflict with the vocab dim
    return _head_logits(x, params, cfg), aux


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            ctx=None) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token cross-entropy. ``labels`` (B, S_total) with -1 = masked
    (e.g. image-patch positions in VLMs)."""
    logits, aux = forward(params, cfg, batch, train=True, ctx=ctx)
    if ctx is not None:
        # keep the (B, S, V) logits sharded (batch x vocab-on-"model")
        # through the loss: without this GSPMD sometimes replicates the
        # vocab dim to simplify take_along_axis (68 GB/device on gemma3)
        logits = ctx.act(logits)
    labels = batch["labels"]
    logits = logits[:, :-1].astype(jnp.float32)
    targets = labels[:, 1:]
    mask = (targets >= 0).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    # elementwise one-hot contraction instead of take_along_axis: the
    # gather (and its scatter transpose) over a sharded vocab dim makes
    # GSPMD replicate the (B, S, V) logits; the iota comparison stays
    # sharded in both passes and fuses to nothing.
    onehot = (targets[..., None]
              == jnp.arange(logits.shape[-1])[None, None]).astype(jnp.float32)
    picked = jnp.sum(logits * onehot, axis=-1)
    nll = (lse - picked) * mask
    loss = nll.sum() / jnp.maximum(mask.sum(), 1.0)
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_weight * aux
    return loss, {"ce": nll.sum() / jnp.maximum(mask.sum(), 1.0), "aux": aux}


# ---------------------------------------------------------------------------
# prefill / decode with caches
# ---------------------------------------------------------------------------

def _cache_size_for(kind: str, cfg: ModelConfig, max_len: int) -> int:
    if kind == "attn_local":
        return min(cfg.window, max_len)
    if kind == "attn":
        return cfg.max_cache_len or max_len
    return 0  # recurrent kinds have fixed-size state


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               src_len: int = 0) -> Dict[str, Any]:
    """Allocate the decode cache pytree (shapes only depend on config).
    ``src_len``: encoder length for enc-dec archs — each decoder layer
    caches the precomputed cross-attention K/V."""
    dtype = _dtype(cfg.dtype)
    hd, hk = cfg.resolved_head_dim, cfg.n_kv_heads
    d = cfg.d_model

    def one(kind):
        if kind in ("attn", "attn_local"):
            s = _cache_size_for(kind, cfg, max_len)
            c = {"k": jnp.zeros((batch, s, hk, hd), dtype),
                 "v": jnp.zeros((batch, s, hk, hd), dtype)}
            if cfg.n_encoder_layers:
                c["cross_k"] = jnp.zeros((batch, src_len, hk, hd), dtype)
                c["cross_v"] = jnp.zeros((batch, src_len, hk, hd), dtype)
            return c
        if kind == "rglru":
            return {"h": jnp.zeros((batch, d)),
                    "conv": jnp.zeros((batch, cfg.rglru_conv_width - 1, d),
                                      dtype)}
        if kind == "mlstm":
            dm = int(cfg.mlstm_proj_factor * d)
            nh = cfg.n_heads
            dh = dm // nh
            return {"C": jnp.zeros((batch, nh, dh, dh)),
                    "n": jnp.zeros((batch, nh, dh)),
                    "m": jnp.full((batch, nh), -1e30),
                    "conv": jnp.zeros((batch, 3, dm), dtype)}
        if kind == "slstm":
            nh = cfg.n_heads
            dh = d // nh
            z = jnp.zeros((batch, nh, dh))
            return {"c": z, "n": jnp.ones((batch, nh, dh)),
                    "m": jnp.full((batch, nh, dh), -1e30), "h": z}
        raise ValueError(kind)

    def stacked(kind):
        c = one(kind)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_periods,) + a.shape).copy(), c)

    return {
        "scan": [stacked(k) for k in cfg.layer_pattern] if cfg.n_periods else [],
        "rem": [one(k) for k in cfg.remainder_kinds],
    }


def supports_chunked_prefill(cfg: ModelConfig) -> bool:
    """Chunked prefill extends a position-indexed global-attention cache
    chunk by chunk. Ring-wrapping caches (attn_local windows,
    max_cache_len caps), recurrent state (whose prefill starts from the
    zero state, not a carried one), and encoder-decoder archs are served
    by the one-shot path instead."""
    return (all(k == "attn" for k in cfg.layer_kinds)
            and cfg.n_encoder_layers == 0 and not cfg.max_cache_len)


def kv_row_bytes(cfg: ModelConfig) -> int:
    """Bytes of global-attention K+V cached per token row — the paged
    pool's per-row footprint. Local-window and recurrent state are
    fixed-size per slot and excluded (they are identical between the
    paged and slotted layouts)."""
    n_global = sum(1 for k in cfg.layer_kinds if k == "attn")
    nbytes = 2 if cfg.dtype in ("bfloat16", "float16") else 4
    return n_global * 2 * cfg.n_kv_heads * cfg.resolved_head_dim * nbytes


def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                     max_slots: int, *, max_len: int,
                     src_len: int = 0) -> Dict[str, Any]:
    """Paged variant of ``init_cache``: global-attention K/V live in one
    shared pool of ``num_blocks`` blocks of ``block_size`` rows (leaf
    shape (N, bs, Hk, hd)) addressed through per-slot block tables,
    instead of a dense (max_slots, max_len, ...) buffer. Physical block 0
    is reserved as the null block. Everything that is fixed-size per
    sequence — local-attention windows, recurrent state, cross-attention
    K/V — stays slot-indexed exactly as in ``init_cache`` at ``max_len``,
    so only the layout of global-attention K/V changes (and the dense
    global buffers are never materialized)."""
    dtype = _dtype(cfg.dtype)
    hd, hk = cfg.resolved_head_dim, cfg.n_kv_heads
    proto = init_cache(cfg, 1, max_len, src_len=src_len)

    def widen(kind, one_slot, stacked: bool):
        """Re-batch a batch=1 cache dict: pool layout for global-attn K/V,
        max_slots batch for every other leaf."""
        def leaf(path_key, a):
            batch_axis = 1 if stacked else 0
            if kind == "attn" and path_key in ("k", "v"):
                shape = a.shape[:batch_axis] \
                    + (num_blocks, block_size) + a.shape[batch_axis + 2:]
                return jnp.zeros(shape, dtype)
            shape = a.shape[:batch_axis] + (max_slots,) \
                + a.shape[batch_axis + 1:]
            return jnp.broadcast_to(
                jnp.take(a, 0, axis=batch_axis)[
                    (slice(None),) * batch_axis + (None,)], shape).copy()
        return {key: leaf(key, a) for key, a in one_slot.items()}

    return {
        "scan": [widen(k, c, True) for k, c in zip(cfg.layer_pattern,
                                                   proto["scan"])],
        "rem": [widen(k, c, False) for k, c in zip(cfg.remainder_kinds,
                                                   proto["rem"])],
    }


def _layer_prefill(p, x, kind, cfg, *, positions, cache_size, enc_out,
                   ctx=None):
    if ctx is not None:
        p = ctx.layer(p)
    if kind in ("attn", "attn_local"):
        x, c = L.attn_prefill_cache(p["block"], x, cfg, kind=kind,
                                    positions=positions,
                                    cache_size=cache_size)
    elif kind == "rglru":
        x, c = R.rglru_prefill_cache(p["block"], x, cfg)
    elif kind == "mlstm":
        x, c = S.mlstm_prefill_cache(p["block"], x, cfg)
    elif kind == "slstm":
        x, c = S.slstm_prefill_cache(p["block"], x, cfg)
    if "cross" in p and enc_out is not None:
        x = L.cross_attn_apply(p["cross"], x, enc_out, cfg)
        # precompute + cache the cross-attention K/V so decode never
        # touches the encoder output again
        cp = p["cross"]
        ck = jnp.einsum("bsd,dhk->bshk", enc_out, cp["wk"])
        cv = jnp.einsum("bsd,dhk->bshk", enc_out, cp["wv"])
        if cfg.qkv_bias:
            ck, cv = ck + cp["bk"], cv + cp["bv"]
        c = {**c, "cross_k": ck.astype(x.dtype), "cross_v": cv.astype(x.dtype)}
    if "moe" in p:
        x, _ = M.moe_apply(p["moe"], x, cfg, ctx=ctx)
    elif "mlp" in p:
        x = L.mlp_apply(p["mlp"], x, cfg)
    return x, c


def prefill(params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            max_len: int, ctx=None
            ) -> Tuple[jax.Array, Dict[str, Any], jax.Array]:
    """Run the prompt through the model, materializing the decode cache.
    Returns (last-position logits (B, V), cache, cache_len (B,))."""
    params = cast_params_for_compute(params, cfg)
    tokens = batch.get("tokens")
    embeds = batch.get("embeds")
    enc_out = None
    if cfg.n_encoder_layers:
        enc_out = encode(params, cfg, embeds, ctx=ctx)
        x = _embed_inputs(params, cfg, tokens, None)
    else:
        x = _embed_inputs(params, cfg, tokens, embeds)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    period = cfg.layer_pattern
    cache: Dict[str, Any] = {"scan": [], "rem": []}

    if cfg.n_periods:
        def body(x, slice_params):
            caches = []
            for i, kind in enumerate(period):
                x, c = _layer_prefill(
                    slice_params[i], x, kind, cfg, positions=positions,
                    cache_size=_cache_size_for(kind, cfg, max_len),
                    enc_out=enc_out, ctx=ctx)
                caches.append(c)
            if ctx is not None:
                x = ctx.act(x)
            return x, caches
        x, caches = jax.lax.scan(body, x, params["scan"])
        cache["scan"] = caches
    for i, kind in enumerate(cfg.remainder_kinds):
        x, c = _layer_prefill(params["rem"][i], x, kind, cfg,
                              positions=positions,
                              cache_size=_cache_size_for(kind, cfg, max_len),
                              enc_out=enc_out, ctx=ctx)
        cache["rem"].append(c)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _head_logits(x[:, -1], params, cfg)
    return logits, cache, jnp.full((b,), s, jnp.int32)


def _layer_decode(p, x, kind, cfg, *, cache, cache_len, enc_out,
                  tables=None, ctx=None):
    if ctx is not None:
        p = ctx.layer(p)
    if kind == "attn" and tables is not None:
        # paged layout: K/V in a shared block pool behind per-slot tables
        x, kp, vp = L.attn_decode_paged(p["block"], x, cfg, k_pool=cache["k"],
                                        v_pool=cache["v"], tables=tables,
                                        cache_len=cache_len)
        c = {**cache, "k": kp, "v": vp}
    elif kind in ("attn", "attn_local"):
        x, c = L.attn_decode(p["block"], x, cfg, kind=kind, cache=cache,
                             cache_len=cache_len)
    elif kind == "rglru":
        x, c = R.rglru_decode(p["block"], x, cfg, cache=cache)
    elif kind == "mlstm":
        x, c = S.mlstm_decode(p["block"], x, cfg, cache=cache)
    elif kind == "slstm":
        x, c = S.slstm_decode(p["block"], x, cfg, cache=cache)
    if "cross" in p and "cross_k" in cache:
        b = x.shape[0]
        ck, cv = cache["cross_k"], cache["cross_v"]
        h = L.rms_norm(x, p["cross"]["ln"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, p["cross"]["wq"])
        if cfg.qkv_bias:
            q = q + p["cross"]["bq"]
        o = L.decode_attention_xla(
            q[:, 0], ck, cv, jnp.full((b,), ck.shape[1], jnp.int32))
        x = x + jnp.einsum("bhk,hkd->bd", o, p["cross"]["wo"])[:, None]
        c = {**c, "cross_k": ck, "cross_v": cv}
    if "moe" in p:
        x, _ = M.moe_apply(p["moe"], x, cfg, ctx=ctx)
    elif "mlp" in p:
        x = L.mlp_apply(p["mlp"], x, cfg)
    return x, c


def decode_step(params, cfg: ModelConfig, token: jax.Array,
                cache: Dict[str, Any], cache_len: jax.Array, *,
                block_tables: Optional[jax.Array] = None, ctx=None
                ) -> Tuple[jax.Array, Dict[str, Any], jax.Array]:
    """One serving step: next-token logits for one new token per sequence.
    token: (B,) int32; cache_len: (B,) current context length.
    ``block_tables`` (B, nb) switches global-attention layers to the paged
    cache layout (``init_paged_cache``): they stream only the live blocks
    the tables name, while every slot-indexed leaf (local windows,
    recurrent state, cross K/V) behaves exactly as on the dense path."""
    params = cast_params_for_compute(params, cfg)
    x = params["embed"].astype(_dtype(cfg.dtype))[token][:, None] \
        * math.sqrt(cfg.d_model)
    enc_out = None   # cross K/V live inside each layer's cache
    period = cfg.layer_pattern
    new_cache: Dict[str, Any] = {"scan": [], "rem": []}

    if cfg.n_periods:
        def body(x, scanned):
            slice_params, slice_cache = scanned
            new_cs = []
            for i, kind in enumerate(period):
                x, c = _layer_decode(slice_params[i], x, kind, cfg,
                                     cache=slice_cache[i],
                                     cache_len=cache_len, enc_out=enc_out,
                                     tables=block_tables, ctx=ctx)
                new_cs.append(c)
            return x, new_cs
        x, ncs = jax.lax.scan(body, x, (params["scan"], cache["scan"]))
        new_cache["scan"] = ncs
    for i, kind in enumerate(cfg.remainder_kinds):
        x, c = _layer_decode(params["rem"][i], x, kind, cfg,
                             cache=cache["rem"][i], cache_len=cache_len,
                             enc_out=enc_out, tables=block_tables, ctx=ctx)
        new_cache["rem"].append(c)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _head_logits(x[:, 0], params, cfg)
    return logits, new_cache, cache_len + 1


def decode_stage_bounds(cfg: ModelConfig, num_stages: int) -> list:
    """Contiguous near-even partition of the decode depth (scan periods
    first, then remainder layers) into ``num_stages`` groups: returns
    ``num_stages + 1`` monotone boundaries over
    ``n_periods + len(remainder_kinds)`` depth units. A stage may be
    empty when there are more stages than depth units."""
    total = cfg.n_periods + len(cfg.remainder_kinds)
    return [s * total // num_stages for s in range(num_stages + 1)]


def decode_step_staged(params, cfg: ModelConfig, token: jax.Array,
                       cache: Dict[str, Any], cache_len: jax.Array, *,
                       num_stages: int,
                       block_tables: Optional[jax.Array] = None, ctx=None
                       ) -> Tuple[jax.Array, Dict[str, Any], jax.Array]:
    """``decode_step`` with the layer stack partitioned into
    ``num_stages`` contiguous depth groups (``decode_stage_bounds``) —
    the stage-partitioned step behind pipelined decode: the execution
    core models stage k of one microbatch overlapping stage k−1 of the
    next, and this function is the matching computation split. Each
    stage runs its slice of the scanned periods (``jax.lax.scan`` over a
    leading-axis slice of the stacked params/cache) and its remainder
    layers; the embed feeds the first stage and the head reads the last.
    The per-layer math is unchanged and runs in the same order on the
    same values, so logits and the reassembled cache are bit-identical
    to the unstaged step (tests/test_multi_unit.py pins this, and the
    conformance matrix pins greedy token identity end to end)."""
    if num_stages <= 1:
        return decode_step(params, cfg, token, cache, cache_len,
                           block_tables=block_tables, ctx=ctx)
    params = cast_params_for_compute(params, cfg)
    x = params["embed"].astype(_dtype(cfg.dtype))[token][:, None] \
        * math.sqrt(cfg.d_model)
    enc_out = None
    period = cfg.layer_pattern
    n_scan = cfg.n_periods
    cuts = decode_stage_bounds(cfg, num_stages)

    def body(x, scanned):
        slice_params, slice_cache = scanned
        new_cs = []
        for i, kind in enumerate(period):
            x, c = _layer_decode(slice_params[i], x, kind, cfg,
                                 cache=slice_cache[i], cache_len=cache_len,
                                 enc_out=enc_out, tables=block_tables,
                                 ctx=ctx)
            new_cs.append(c)
        return x, new_cs

    scan_parts = []
    new_rem = []
    for s in range(num_stages):
        lo, hi = cuts[s], cuts[s + 1]
        slo, shi = min(lo, n_scan), min(hi, n_scan)
        if shi > slo:
            part = (jax.tree.map(lambda a: a[slo:shi], params["scan"]),
                    jax.tree.map(lambda a: a[slo:shi], cache["scan"]))
            x, ncs = jax.lax.scan(body, x, part)
            scan_parts.append(ncs)
        for i in range(max(lo - n_scan, 0), max(hi - n_scan, 0)):
            x, c = _layer_decode(params["rem"][i], x,
                                 cfg.remainder_kinds[i], cfg,
                                 cache=cache["rem"][i], cache_len=cache_len,
                                 enc_out=enc_out, tables=block_tables,
                                 ctx=ctx)
            new_rem.append(c)
    new_cache: Dict[str, Any] = {"scan": [], "rem": new_rem}
    if len(scan_parts) == 1:
        new_cache["scan"] = scan_parts[0]
    elif scan_parts:
        new_cache["scan"] = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *scan_parts)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _head_logits(x[:, 0], params, cfg)
    return logits, new_cache, cache_len + 1


def prefill_extend(params, cfg: ModelConfig, tokens: jax.Array,
                   cache: Dict[str, Any], cache_len: jax.Array, *, ctx=None
                   ) -> Tuple[jax.Array, Dict[str, Any], jax.Array]:
    """Chunked prefill: run a (B, C) token chunk through the model against
    an existing decode cache, appending its K/V rows at positions
    [cache_len, cache_len + C). Compiled once per chunk shape, so a long
    prompt is admitted as a sequence of identical extend steps interleaved
    with decode steps instead of one monolithic prefill.

    Requires ``supports_chunked_prefill(cfg)``. Returns per-position
    logits (B, C, V) — the caller samples at the last *real* (unpadded)
    position — plus the updated cache and cache_len + C."""
    assert supports_chunked_prefill(cfg), cfg.name
    params = cast_params_for_compute(params, cfg)
    x = _embed_inputs(params, cfg, tokens, None)
    b, c = x.shape[:2]
    period = cfg.layer_pattern
    new_cache: Dict[str, Any] = {"scan": [], "rem": []}

    def layer(p, x, kind, lc):
        if ctx is not None:
            p = ctx.layer(p)
        x, nc = L.attn_extend(p["block"], x, cfg, kind=kind, cache=lc,
                              cache_len=cache_len)
        if "moe" in p:
            x, _ = M.moe_apply(p["moe"], x, cfg, ctx=ctx)
        elif "mlp" in p:
            x = L.mlp_apply(p["mlp"], x, cfg)
        return x, nc

    if cfg.n_periods:
        def body(x, scanned):
            slice_params, slice_cache = scanned
            ncs = []
            for i, kind in enumerate(period):
                x, nc = layer(slice_params[i], x, kind, slice_cache[i])
                ncs.append(nc)
            return x, ncs
        x, ncs = jax.lax.scan(body, x, (params["scan"], cache["scan"]))
        new_cache["scan"] = ncs
    for i, kind in enumerate(cfg.remainder_kinds):
        x, nc = layer(params["rem"][i], x, kind, cache["rem"][i])
        new_cache["rem"].append(nc)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _head_logits(x, params, cfg), new_cache, cache_len + c


def paged_insert(cfg: ModelConfig, cache: Dict[str, Any],
                 req_cache: Dict[str, Any], block_ids: jax.Array,
                 slot: jax.Array, *, block_size: int) -> Dict[str, Any]:
    """Write a batch=1 prefill cache into the paged cache: global-attn K/V
    rows are scattered page-wise into the physical blocks named by
    ``block_ids`` (one per logical page; 0 = null-block padding for pages
    past the allocation), every other leaf is written at ``slot`` exactly
    like the slotted insert. ``req_cache`` may be longer than the slot's
    page span (e.g. a chunk-rounded scratch cache) — extra rows are
    dropped; they are beyond ``max_len`` and never valid."""
    pages = block_ids.shape[0]
    sg = pages * block_size

    def ins_pool(pool, small, stacked):
        if stacked:  # (P, N, bs, hk, hd) <- (P, 1, S, hk, hd)
            rows = small[:, 0, :sg]
            blocks = rows.reshape(rows.shape[0], pages, block_size,
                                  *rows.shape[2:])
            return pool.at[:, block_ids].set(blocks)
        rows = small[0, :sg]
        blocks = rows.reshape(pages, block_size, *rows.shape[1:])
        return pool.at[block_ids].set(blocks)

    def ins_slot(big, small, stacked):
        if stacked:
            return big.at[:, slot].set(small[:, 0])
        return big.at[slot].set(small[0])

    def one(kind, c, r, stacked):
        if kind != "attn":
            return jax.tree.map(
                lambda big, small: ins_slot(big, small, stacked), c, r)
        out = {}
        for key in c:
            ins = ins_pool if key in ("k", "v") else ins_slot
            out[key] = ins(c[key], r[key], stacked)
        return out

    return {
        "scan": [one(k, c, r, True) for k, c, r in
                 zip(cfg.layer_pattern, cache["scan"], req_cache["scan"])],
        "rem": [one(k, c, r, False) for k, c, r in
                zip(cfg.remainder_kinds, cache["rem"], req_cache["rem"])],
    }


def paged_seed(cfg: ModelConfig, scratch: Dict[str, Any],
               cache: Dict[str, Any], block_ids: jax.Array
               ) -> Dict[str, Any]:
    """Inverse of ``paged_insert`` for a shared prompt prefix: gather the
    global-attention K/V rows of the pool blocks named by ``block_ids``
    (one per logical page, in page order) into the head of a batch=1
    dense scratch cache, so ``prefill_extend`` can resume mid-prompt
    against them. Whole pages are copied; rows past the true match in
    the last page are either recomputed by the extend or sit beyond the
    prompt where causal masking never reads them. Only used for
    ``supports_chunked_prefill`` configs, whose every cache leaf is
    global-attention K/V."""
    pages = block_ids.shape[0]

    def one(kind, sc, c, stacked):
        if kind != "attn":
            return sc
        out = dict(sc)
        for key in ("k", "v"):
            pool, s = c[key], sc[key]
            if stacked:     # pool (P, N, bs, hk, hd) -> scratch (P, 1, S, ...)
                rows = pool[:, block_ids]
                rows = rows.reshape(rows.shape[0],
                                    pages * pool.shape[2], *rows.shape[3:])
                out[key] = s.at[:, 0, :rows.shape[1]].set(rows)
            else:           # pool (N, bs, hk, hd) -> scratch (1, S, ...)
                rows = pool[block_ids].reshape(pages * pool.shape[1],
                                               *pool.shape[2:])
                out[key] = s.at[0, :rows.shape[0]].set(rows)
        return out

    return {
        "scan": [one(k, sc, c, True) for k, sc, c in
                 zip(cfg.layer_pattern, scratch["scan"], cache["scan"])],
        "rem": [one(k, sc, c, False) for k, sc, c in
                zip(cfg.remainder_kinds, scratch["rem"], cache["rem"])],
    }


def paged_copy_block(cfg: ModelConfig, cache: Dict[str, Any],
                     src: jax.Array, dst: jax.Array) -> Dict[str, Any]:
    """Copy one physical block's global-attention K/V rows to another —
    the device half of copy-on-write, giving a writer a private copy of
    a block whose other references must keep reading the original."""
    def one(kind, c, stacked):
        if kind != "attn":
            return c
        out = dict(c)
        for key in ("k", "v"):
            pool = c[key]
            if stacked:     # (P, N, bs, hk, hd)
                out[key] = pool.at[:, dst].set(pool[:, src])
            else:           # (N, bs, hk, hd)
                out[key] = pool.at[dst].set(pool[src])
        return out

    return {
        "scan": [one(k, c, True) for k, c in
                 zip(cfg.layer_pattern, cache["scan"])],
        "rem": [one(k, c, False) for k, c in
                zip(cfg.remainder_kinds, cache["rem"])],
    }


# ---------------------------------------------------------------------------
# VR-PRUNE actor-graph export (the Edge-PRUNE integration)
# ---------------------------------------------------------------------------

def to_actor_graph(cfg: ModelConfig, params: Optional[Dict[str, Any]] = None,
                   *, batch: int = 1, seq: int = 8,
                   group_size: int = 1):
    """Export the model as a VR-PRUNE dataflow graph: one actor per group
    of ``group_size`` layers (plus Input / Embed / Head actors), each edge
    annotated with its real token size — exactly how the paper expresses
    SSD-Mobilenet as 53 actors. When ``params`` is given the actors carry
    real fire functions, so the Simulator/Explorer can execute and
    partition the actual model (see examples/distributed_serving.py)."""
    from repro.core.graph import Actor, ActorType, Graph, Port, PortDir

    g = Graph(cfg.name)
    d = cfg.d_model
    act_bytes = 2 if cfg.dtype == "bfloat16" else 4
    tok_shape = (batch, seq, d)
    hd = cfg.resolved_head_dim
    qkv_out = (cfg.n_heads + 2 * cfg.n_kv_heads) * hd

    def block_flops(kind):
        f = 0.0
        if kind in ("attn", "attn_local"):
            ctx = min(seq, cfg.window) if kind == "attn_local" else seq
            f = 2.0 * seq * d * (qkv_out + cfg.n_heads * hd) \
                + 4.0 * seq * ctx * cfg.n_heads * hd
        elif kind == "rglru":
            f = 2.0 * seq * d * (2 * d + 2 * d + d) + 10.0 * seq * d
        elif kind == "mlstm":
            dm = int(cfg.mlstm_proj_factor * d)
            f = 2.0 * seq * d * 2 * dm + 2.0 * seq * dm * d \
                + 4.0 * seq * min(seq, 256) * dm
        elif kind == "slstm":
            ds = int(cfg.slstm_proj_factor * d)
            f = 2.0 * seq * d * (4 * d + 2 * ds) + 2.0 * seq * 4 * d * (d // max(cfg.n_heads, 1))
        if kind in _HAS_MLP:
            if cfg.moe:
                f += 2.0 * seq * d * 3 * cfg.moe.d_ff_expert \
                    * (cfg.moe.top_k + cfg.moe.n_shared_experts)
            else:
                f += 2.0 * seq * d * 3 * cfg.d_ff
        return batch * f

    kinds = cfg.layer_kinds
    groups = [list(range(i, min(i + group_size, len(kinds))))
              for i in range(0, len(kinds), group_size)]

    def flat_layer_params(idx):
        if params is None:
            return None
        period = len(cfg.layer_pattern)
        if idx < cfg.n_periods * period:
            pos, rep = idx % period, idx // period
            return jax.tree.map(lambda a: a[rep], params["scan"][pos])
        return params["rem"][idx - cfg.n_periods * period]

    # Input -> Embed -> LayerGroup_i ... -> Head
    inp = Actor("Input", ActorType.SPA,
                [], [Port("out", PortDir.OUT, token_shape=(batch, seq),
                          token_dtype="int32")],
                fire_fn=lambda inputs, st, atr: (
                    {"out": [inputs["__feed__"][0]]}, st))
    g.add_actor(inp)

    def embed_fire(inputs, st, atr):
        (tok,) = inputs["in"]
        x = _embed_inputs(params, cfg, tok, None)
        return {"out": [x]}, st

    emb = Actor("Embed", ActorType.SPA,
                [Port("in", PortDir.IN, token_shape=(batch, seq),
                      token_dtype="int32")],
                [Port("out", PortDir.OUT, token_shape=tok_shape,
                      token_dtype=cfg.dtype)],
                fire_fn=embed_fire if params is not None else None,
                cost_flops=2.0 * batch * seq * d)
    g.add_actor(emb)
    g.connect(inp.port("out"), emb.port("in"))

    prev = emb
    for gi, idxs in enumerate(groups):
        def make_fire(idxs):
            def fire(inputs, st, atr):
                (x,) = inputs["in"]
                positions = jnp.broadcast_to(
                    jnp.arange(x.shape[1], dtype=jnp.int32), x.shape[:2])
                for li in idxs:
                    x, _ = _layer_apply(flat_layer_params(li), x, kinds[li],
                                        cfg, positions=positions)
                return {"out": [x]}, st
            return fire

        a = Actor(f"Layers{idxs[0]}-{idxs[-1]}", ActorType.SPA,
                  [Port("in", PortDir.IN, token_shape=tok_shape,
                        token_dtype=cfg.dtype)],
                  [Port("out", PortDir.OUT, token_shape=tok_shape,
                        token_dtype=cfg.dtype)],
                  fire_fn=make_fire(idxs) if params is not None else None,
                  cost_flops=sum(block_flops(kinds[li]) for li in idxs),
                  meta={"layers": idxs})
        g.add_actor(a)
        g.connect(prev.port("out"), a.port("in"))
        prev = a

    def head_fire(inputs, st, atr):
        (x,) = inputs["in"]
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        logits = (jnp.einsum("bsd,vd->bsv", x, head.astype(x.dtype))
                  if cfg.tie_embeddings else x @ head.astype(x.dtype))
        return {"result": logits}, st

    head = Actor("Head", ActorType.SPA,
                 [Port("in", PortDir.IN, token_shape=tok_shape,
                       token_dtype=cfg.dtype)], [],
                 fire_fn=head_fire if params is not None else None,
                 cost_flops=2.0 * batch * seq * d * cfg.vocab_size)
    g.add_actor(head)
    g.connect(prev.port("out"), head.port("in"))
    return g
