"""The paper's two experimental CNN applications as VR-PRUNE graphs.

1. **Vehicle image classification** (Fig 2, [Xie et al. 2016]): actors
   Input, L1, L2, L3, L4-L5. Geometry is pinned by the paper's edge token
   sizes: L1->L2 = 294912 B = 48x48x32 fp32 and L2->L3 = 73728 B =
   24x24x32 fp32 force input 96x96x3 and two (conv 5x5x32 + ReLU +
   maxpool/2) stages, followed by dense 100 -> dense 100 -> dense n + softmax.

2. **SSD-Mobilenet object tracking** (Fig 3, [Howard et al. 2017; Liu et
   al. 2016]): Input, CL1 (3x3 s2 conv), DWCL1..DWCL13 (depthwise +
   pointwise pairs), EL1..EL4 (SSD extra feature blocks), six (LOC, CONF)
   head pairs branching off DWCL11/DWCL13/EL1..EL4, ConcatLoc/ConcatConf,
   NMS, Tracker. The paper groups 129 layers into 53 actors / 69 edges; we
   group into 35 actors / 41 edges (coarser dw+pw grouping — grouping
   granularity is a free parameter of the framework; the partition points
   of Sec IV.B all fall on our actor boundaries).

Both graphs carry real JAX compute in the actor fire functions (the
simulator actually classifies/detects), plus analytic per-actor FLOP and
weight-byte costs for the Explorer's platform model. The SSD actors
additionally pin calibrated per-unit wall times (see
``repro.core.calibration``), because Mali OpenCL depthwise convs / plain-C
NMS / tracking do not follow a single per-device FLOP rate.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import calibration as cal
from repro.core.graph import Actor, ActorType, Graph, Port, PortDir


# ---------------------------------------------------------------------------
# primitive layer helpers (NHWC, fp32)
# ---------------------------------------------------------------------------

def conv2d(x, w, b=None, *, stride=1, padding="SAME", groups=1):
    """x: (H, W, Cin); w: (kh, kw, Cin/groups, Cout)."""
    y = jax.lax.conv_general_dilated(
        x[None], w, window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)[0]
    if b is not None:
        y = y + b
    return y


def maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (2, 2, 1), (2, 2, 1), "VALID")


def dense(x, w, b):
    return x.reshape(-1) @ w + b


def conv_flops(h, w, cout, kh, kw, cin_per_group) -> float:
    return 2.0 * h * w * cout * kh * kw * cin_per_group


# ---------------------------------------------------------------------------
# Vehicle image classification CNN (Fig 2)
# ---------------------------------------------------------------------------

def vehicle_graph(num_classes: int = 4, *, seed: int = 0,
                  input_hw: int = 96) -> Graph:
    """Actors: Input -> L1 -> L2 -> L3 -> L4-L5 (sink). Token sizes for the
    default input_hw=96 match the paper's Fig 2 exactly."""
    rng = np.random.RandomState(seed)
    hw = input_hw

    def pw(*shape, scale=None):
        scale = scale or 1.0 / math.sqrt(np.prod(shape[:-1]))
        return jnp.asarray(rng.uniform(-scale, scale, shape), jnp.float32)

    w1, b1 = pw(5, 5, 3, 32), jnp.zeros((32,), jnp.float32)
    w2, b2 = pw(5, 5, 32, 32), jnp.zeros((32,), jnp.float32)
    h2 = hw // 4
    feat = h2 * h2 * 32
    w3, b3 = pw(feat, 100), jnp.zeros((100,), jnp.float32)
    w4, b4 = pw(100, 100), jnp.zeros((100,), jnp.float32)
    w5, b5 = pw(100, num_classes), jnp.zeros((num_classes,), jnp.float32)

    g = Graph("vehicle_classification")

    # ---- Input (camera / file I/O source)
    def input_fire(inputs, state, atr):
        feed = inputs.get("__feed__")
        img = feed[0] if feed else jnp.asarray(
            rng.rand(hw, hw, 3), jnp.float32)
        return {"out": [img]}, state

    inp = g.add_actor(Actor(
        "Input", ActorType.SPA, [],
        [Port("out", PortDir.OUT, token_shape=(hw, hw, 3))],
        fire_fn=input_fire, cost_flops=0.0,
        meta={"layers": ["camera I/O"]}))

    # ---- L1: conv 5x5x32 + ReLU + maxpool/2
    def l1_fire(inputs, state, atr):
        (x,) = inputs["in"]
        return {"out": [maxpool2(jax.nn.relu(conv2d(x, w1, b1)))]}, state

    l1 = g.add_actor(Actor(
        "L1", ActorType.SPA,
        [Port("in", PortDir.IN, token_shape=(hw, hw, 3))],
        [Port("out", PortDir.OUT, token_shape=(hw // 2, hw // 2, 32))],
        fire_fn=l1_fire,
        cost_flops=conv_flops(hw, hw, 32, 5, 5, 3),
        cost_mem_bytes=w1.size * 4,
        meta={"layers": ["conv5x5x32", "relu", "maxpool2"]}))

    # ---- L2: conv 5x5x32 + ReLU + maxpool/2
    def l2_fire(inputs, state, atr):
        (x,) = inputs["in"]
        return {"out": [maxpool2(jax.nn.relu(conv2d(x, w2, b2)))]}, state

    l2 = g.add_actor(Actor(
        "L2", ActorType.SPA,
        [Port("in", PortDir.IN, token_shape=(hw // 2, hw // 2, 32))],
        [Port("out", PortDir.OUT, token_shape=(h2, h2, 32))],
        fire_fn=l2_fire,
        cost_flops=conv_flops(hw // 2, hw // 2, 32, 5, 5, 32),
        cost_mem_bytes=w2.size * 4,
        meta={"layers": ["conv5x5x32", "relu", "maxpool2"]}))

    # ---- L3: dense 100 + ReLU
    def l3_fire(inputs, state, atr):
        (x,) = inputs["in"]
        return {"out": [jax.nn.relu(dense(x, w3, b3))]}, state

    l3 = g.add_actor(Actor(
        "L3", ActorType.SPA,
        [Port("in", PortDir.IN, token_shape=(h2, h2, 32))],
        [Port("out", PortDir.OUT, token_shape=(100,))],
        fire_fn=l3_fire, cost_flops=2.0 * feat * 100,
        cost_mem_bytes=(feat * 100 + 100) * 4,
        meta={"layers": ["dense100", "relu"]}))

    # ---- L4-L5: dense 100 + ReLU, dense n + softmax (sink)
    def l45_fire(inputs, state, atr):
        (x,) = inputs["in"]
        h = jax.nn.relu(dense(x, w4, b4))
        logits = dense(h, w5, b5)
        return {"result": [jax.nn.softmax(logits)]}, state

    l45 = g.add_actor(Actor(
        "L4-L5", ActorType.SPA,
        [Port("in", PortDir.IN, token_shape=(100,))], [],
        fire_fn=l45_fire,
        cost_flops=2.0 * (100 * 100 + 100 * num_classes),
        cost_mem_bytes=(100 * 100 + 100 + 100 * num_classes + num_classes) * 4,
        meta={"layers": ["dense100", "relu", f"dense{num_classes}", "softmax"]}))

    g.connect(inp.port("out"), l1.port("in"))
    g.connect(l1.port("out"), l2.port("in"))
    g.connect(l2.port("out"), l3.port("in"))
    g.connect(l3.port("out"), l45.port("in"))
    return g


def dual_input_vehicle_graph(num_classes: int = 4, *, seed: int = 0,
                             input_hw: int = 96) -> Graph:
    """Sec IV.C: Input..L3 replicated into two instances joining at a
    two-input L4L5 actor (the Fig 1 heterogeneous scenario)."""
    g1 = vehicle_graph(num_classes, seed=seed, input_hw=input_hw)
    g2 = vehicle_graph(num_classes, seed=seed + 1, input_hw=input_hw)
    g = Graph("dual_input_vehicle")
    for inst, src in ((1, g1), (2, g2)):
        for name in ("Input", "L1", "L2", "L3"):
            a = src.actors[name]
            clone = Actor(f"{name}.{inst}", a.actor_type,
                          [Port(p.name, p.direction, p.lrl, p.url,
                                p.token_shape, p.token_dtype)
                           for p in a.in_ports],
                          [Port(p.name, p.direction, p.lrl, p.url,
                                p.token_shape, p.token_dtype)
                           for p in a.out_ports],
                          fire_fn=a.fire_fn, cost_flops=a.cost_flops,
                          cost_mem_bytes=a.cost_mem_bytes, meta=dict(a.meta))
            g.add_actor(clone)
        g.connect(g.actors[f"Input.{inst}"].port("out"),
                  g.actors[f"L1.{inst}"].port("in"))
        g.connect(g.actors[f"L1.{inst}"].port("out"),
                  g.actors[f"L2.{inst}"].port("in"))
        g.connect(g.actors[f"L2.{inst}"].port("out"),
                  g.actors[f"L3.{inst}"].port("in"))

    rng = np.random.RandomState(seed + 99)
    w4 = jnp.asarray(rng.uniform(-0.1, 0.1, (200, 100)), jnp.float32)
    b4 = jnp.zeros((100,), jnp.float32)
    w5 = jnp.asarray(rng.uniform(-0.1, 0.1, (100, num_classes)), jnp.float32)
    b5 = jnp.zeros((num_classes,), jnp.float32)

    def join_fire(inputs, state, atr):
        x = jnp.concatenate([inputs["in0"][0], inputs["in1"][0]])
        h = jax.nn.relu(x @ w4 + b4)
        return {"result": [jax.nn.softmax(h @ w5 + b5)]}, state

    l45 = g.add_actor(Actor(
        "L4L5", ActorType.SPA,
        [Port("in0", PortDir.IN, token_shape=(100,)),
         Port("in1", PortDir.IN, token_shape=(100,))], [],
        fire_fn=join_fire, cost_flops=2.0 * (200 * 100 + 100 * num_classes),
        cost_mem_bytes=(200 * 100 + 100 * num_classes) * 4))
    g.connect(g.actors["L3.1"].port("out"), l45.port("in0"))
    g.connect(g.actors["L3.2"].port("out"), l45.port("in1"))
    return g


# ---------------------------------------------------------------------------
# SSD-Mobilenet object tracking (Fig 3)
# ---------------------------------------------------------------------------

# Mobilenet-v1 body: (stride, cout) per depthwise-separable block.
_MOBILENET_BLOCKS: List[Tuple[int, int]] = [
    (1, 64), (2, 128), (1, 128), (2, 256), (1, 256), (2, 512),
    (1, 512), (1, 512), (1, 512), (1, 512), (1, 512), (2, 1024), (1, 1024),
]
# SSD extra feature blocks appended after the body: (cmid, cout, stride).
_SSD_EXTRAS: List[Tuple[int, int, int]] = [
    (256, 512, 2), (128, 256, 2), (128, 256, 2), (64, 128, 2),
]
# Detection heads tap these feature sources (actor name resolved later):
# DWCL11 (19x19x512), DWCL13 (10x10x1024), EL1..EL4.
_HEAD_SOURCES = ["DWCL11", "DWCL13", "EL1", "EL2", "EL3", "EL4"]
_HEAD_PRIORS = [3, 6, 6, 6, 6, 6]


def _pinned_times(name: str, flops_conv: float, flops_dw: float,
                  traffic_bytes: float = 0.0,
                  fixed_s: float = 0.0) -> Dict[str, float]:
    """Calibrated per-unit wall time for SSD actors (see calibration.py):
    three-regime Mali OpenCL roofline + fixed plain-C costs."""
    n2 = (max(flops_conv / cal.N2_SSD_CONV_FLOPS,
              flops_dw / cal.N2_SSD_DW_FLOPS,
              traffic_bytes / cal.N2_SSD_MEM_BW)
          + fixed_s + cal.N2_FIRING_OVERHEAD_S)
    return {"endpoint": n2, "server": n2 / cal.I7_SSD_SPEEDUP}


def ssd_mobilenet_graph(num_classes: int = 21, *, seed: int = 0,
                        input_hw: int = 300) -> Graph:
    """SSD-Mobilenet grouped into 35 actors with SSD-head branches, NMS and
    tracking — real depthwise-separable compute in every fire function."""
    rng = np.random.RandomState(seed)

    def pw(*shape):
        scale = 1.0 / math.sqrt(max(int(np.prod(shape[:-1])), 1))
        return jnp.asarray(rng.uniform(-scale, scale, shape), jnp.float32)

    g = Graph("ssd_mobilenet_tracking")
    hw = input_hw

    def input_fire(inputs, state, atr):
        feed = inputs.get("__feed__")
        img = feed[0] if feed else jnp.asarray(rng.rand(hw, hw, 3), jnp.float32)
        return {"out": [img]}, state

    g.add_actor(Actor(
        "Input", ActorType.SPA, [],
        [Port("out", PortDir.OUT, token_shape=(hw, hw, 3))],
        fire_fn=input_fire,
        meta={"layers": ["camera I/O"],
              "unit_time_s": _pinned_times("Input", 0, 0)}))

    # --- CL1: standard conv 3x3 s2 -> 32 channels
    w_cl1, b_cl1 = pw(3, 3, 3, 32), jnp.zeros((32,), jnp.float32)
    h = (hw + 1) // 2

    def cl1_fire(inputs, state, atr, w=w_cl1, b=b_cl1):
        (x,) = inputs["in"]
        return {"out": [jax.nn.relu(conv2d(x, w, b, stride=2))]}, state

    fl = conv_flops(h, h, 32, 3, 3, 3)
    traffic = 4 * (hw * hw * 3 + h * h * 32) + w_cl1.size * 4
    g.add_actor(Actor(
        "CL1", ActorType.SPA,
        [Port("in", PortDir.IN, token_shape=(hw, hw, 3))],
        [Port("out", PortDir.OUT, token_shape=(h, h, 32))],
        fire_fn=cl1_fire, cost_flops=fl, cost_mem_bytes=w_cl1.size * 4,
        meta={"layers": ["conv3x3s2x32", "relu"],
              "unit_time_s": _pinned_times("CL1", fl, 0, traffic)}))
    g.connect(g.actors["Input"].port("out"), g.actors["CL1"].port("in"))

    # --- DWCL1..13: depthwise 3x3 + pointwise 1x1 (+ReLUs), grouped
    cin = 32
    prev = "CL1"
    feat_shapes: Dict[str, Tuple[int, int, int]] = {}
    for i, (stride, cout) in enumerate(_MOBILENET_BLOCKS, start=1):
        name = f"DWCL{i}"
        w_dw = pw(3, 3, 1, cin)
        w_pt, b_pt = pw(1, 1, cin, cout), jnp.zeros((cout,), jnp.float32)
        h_out = (h + stride - 1) // stride

        def dwcl_fire(inputs, state, atr, w_dw=w_dw, w_pt=w_pt, b_pt=b_pt,
                      stride=stride, cin=cin):
            (x,) = inputs["in"]
            y = jax.nn.relu(conv2d(x, w_dw, stride=stride, groups=cin))
            return {"out": [jax.nn.relu(conv2d(y, w_pt, b_pt))]}, state

        fl_dw = conv_flops(h_out, h_out, cin, 3, 3, 1)
        fl_pt = conv_flops(h_out, h_out, cout, 1, 1, cin)
        # activation traffic: read in, write+read dw intermediate, write out
        traffic = 4 * (h * h * cin + 2 * h_out * h_out * cin
                       + h_out * h_out * cout) + (w_dw.size + w_pt.size) * 4
        g.add_actor(Actor(
            name, ActorType.SPA,
            [Port("in", PortDir.IN, token_shape=(h, h, cin))],
            [Port("out", PortDir.OUT, token_shape=(h_out, h_out, cout))],
            fire_fn=dwcl_fire, cost_flops=fl_dw + fl_pt,
            cost_mem_bytes=(w_dw.size + w_pt.size) * 4,
            meta={"layers": [f"dwconv3x3s{stride}", "relu",
                             f"conv1x1x{cout}", "relu"],
                  "unit_time_s": _pinned_times(name, fl_pt, fl_dw, traffic)}))
        g.connect(g.actors[prev].port("out"), g.actors[name].port("in"))
        feat_shapes[name] = (h_out, h_out, cout)
        prev, h, cin = name, h_out, cout

    # --- EL1..EL4: SSD extra feature blocks (1x1 reduce + 3x3 s2)
    for j, (cmid, cout, stride) in enumerate(_SSD_EXTRAS, start=1):
        name = f"EL{j}"
        w_a, b_a = pw(1, 1, cin, cmid), jnp.zeros((cmid,), jnp.float32)
        w_b, b_b = pw(3, 3, cmid, cout), jnp.zeros((cout,), jnp.float32)
        h_out = (h + stride - 1) // stride

        def el_fire(inputs, state, atr, w_a=w_a, b_a=b_a, w_b=w_b, b_b=b_b,
                    stride=stride):
            (x,) = inputs["in"]
            y = jax.nn.relu(conv2d(x, w_a, b_a))
            return {"out": [jax.nn.relu(conv2d(y, w_b, b_b, stride=stride))]}, state

        fl_el = (conv_flops(h, h, cmid, 1, 1, cin)
                 + conv_flops(h_out, h_out, cout, 3, 3, cmid))
        traffic = 4 * (h * h * (cin + 2 * cmid) + h_out * h_out * cout) \
            + (w_a.size + w_b.size) * 4
        g.add_actor(Actor(
            name, ActorType.SPA,
            [Port("in", PortDir.IN, token_shape=(h, h, cin))],
            [Port("out", PortDir.OUT, token_shape=(h_out, h_out, cout))],
            fire_fn=el_fire, cost_flops=fl_el,
            cost_mem_bytes=(w_a.size + w_b.size) * 4,
            meta={"layers": [f"conv1x1x{cmid}", "relu",
                             f"conv3x3s{stride}x{cout}", "relu"],
                  "unit_time_s": _pinned_times(name, fl_el, 0, traffic)}))
        g.connect(g.actors[prev].port("out"), g.actors[name].port("in"))
        feat_shapes[name] = (h_out, h_out, cout)
        prev, h, cin = name, h_out, cout

    # Feature-source actors need an extra out port per tap; instead of
    # multi-port rewiring we insert explicit single-in/dual-out is avoided:
    # heads tap via dedicated fan-out ports added below.
    # --- detection heads: (LOC_k, CONF_k) 3x3 convs on each source
    total_priors = 0
    for k, (src_name, kpriors) in enumerate(zip(_HEAD_SOURCES, _HEAD_PRIORS),
                                            start=1):
        sh, sw, sc = feat_shapes[src_name]
        total_priors += sh * sw * kpriors
        src_actor = g.actors[src_name]
        for kind, cout_mult in (("LOC", 4), ("CONF", num_classes)):
            name = f"{kind}{k}"
            w_h = pw(3, 3, sc, kpriors * cout_mult)
            b_h = jnp.zeros((kpriors * cout_mult,), jnp.float32)

            def head_fire(inputs, state, atr, w_h=w_h, b_h=b_h,
                          kpriors=kpriors, cout_mult=cout_mult):
                (x,) = inputs["in"]
                y = conv2d(x, w_h, b_h)
                return {"out": [y.reshape(-1, cout_mult)]}, state

            fl_head = conv_flops(sh, sw, kpriors * cout_mult, 3, 3, sc)
            traffic_head = 4 * (sh * sw * (sc + kpriors * cout_mult)) \
                + w_h.size * 4
            out_shape = (sh * sw * kpriors, cout_mult)
            # add a tap port on the source actor
            tap = Port(f"tap_{name}", PortDir.OUT, token_shape=(sh, sw, sc))
            tap.actor = src_actor
            src_actor.out_ports.append(tap)
            _augment_fanout(src_actor)
            g.add_actor(Actor(
                name, ActorType.SPA,
                [Port("in", PortDir.IN, token_shape=(sh, sw, sc))],
                [Port("out", PortDir.OUT, token_shape=out_shape)],
                fire_fn=head_fire, cost_flops=fl_head,
                cost_mem_bytes=w_h.size * 4,
                meta={"layers": [f"conv3x3 head {kind.lower()}"],
                      "unit_time_s": _pinned_times(name, fl_head, 0,
                                                   traffic_head)}))
            g.connect(tap, g.actors[name].port("in"))

    # --- Concat + NMS + Tracker tail
    for kind, cols in (("LOC", 4), ("CONF", num_classes)):
        in_ports = [Port(f"in{k}", PortDir.IN,
                         token_shape=g.actors[f"{kind}{k + 1}"]
                         .port("out").token_shape)
                    for k in range(len(_HEAD_SOURCES))]

        def concat_fire(inputs, state, atr):
            toks = [inputs[k][0] for k in sorted(inputs)]
            return {"out": [jnp.concatenate(toks, axis=0)]}, state

        g.add_actor(Actor(
            f"Concat{kind.title()}", ActorType.SPA, in_ports,
            [Port("out", PortDir.OUT, token_shape=(total_priors, cols))],
            fire_fn=concat_fire,
            meta={"unit_time_s": _pinned_times(f"Concat{kind}", 0, 0)}))
        for k in range(len(_HEAD_SOURCES)):
            g.connect(g.actors[f"{kind}{k + 1}"].port("out"),
                      g.actors[f"Concat{kind.title()}"].port(f"in{k}"))

    def nms_fire(inputs, state, atr):
        loc = inputs["loc"][0]
        conf = jax.nn.softmax(inputs["conf"][0], axis=-1)
        # greedy top-k "NMS": keep the 10 highest-confidence non-background
        score = 1.0 - conf[:, 0]
        top = jnp.argsort(-score)[:10]
        return {"out": [jnp.concatenate(
            [loc[top], score[top, None]], axis=-1)]}, state

    g.add_actor(Actor(
        "NMS", ActorType.SPA,
        [Port("loc", PortDir.IN, token_shape=(total_priors, 4)),
         Port("conf", PortDir.IN, token_shape=(total_priors, num_classes))],
        [Port("out", PortDir.OUT, token_shape=(10, 5))],
        fire_fn=nms_fire,
        meta={"unit_time_s": {"endpoint": cal.N2_SSD_NMS_S,
                              "server": cal.N2_SSD_NMS_S / cal.I7_SSD_SPEEDUP}}))
    g.connect(g.actors["ConcatLoc"].port("out"), g.actors["NMS"].port("loc"))
    g.connect(g.actors["ConcatConf"].port("out"), g.actors["NMS"].port("conf"))

    def tracker_fire(inputs, state, atr):
        det = inputs["in"][0]
        prev = state if state is not None else det
        # constant-velocity association stub: smooth boxes across frames
        tracked = 0.7 * det + 0.3 * prev
        return {"result": [tracked]}, tracked

    g.add_actor(Actor(
        "Tracker", ActorType.SPA,
        [Port("in", PortDir.IN, token_shape=(10, 5))], [],
        fire_fn=tracker_fire, init_fn=lambda: None,
        meta={"unit_time_s": {"endpoint": cal.N2_SSD_TRACKER_S,
                              "server": cal.N2_SSD_TRACKER_S / cal.I7_SSD_SPEEDUP}}))
    g.connect(g.actors["NMS"].port("out"), g.actors["Tracker"].port("in"))
    # EL4 is the last chain actor; its chain 'out' port is consumed only by
    # its head taps — drop the unused chain port so the graph is closed.
    el4 = g.actors["EL4"]
    el4.out_ports = [p for p in el4.out_ports
                     if not (p.name == "out" and p.fifo is None)]
    return g


def _augment_fanout(actor: Actor) -> None:
    """Wrap the actor's fire_fn so every out port receives the token that
    the original single-'out' implementation produced (fan-out taps)."""
    if actor.meta.get("_fanout_wrapped"):
        return
    base_fire = actor.fire_fn

    def fanout_fire(inputs, state, atr, _base=base_fire, _actor=actor):
        outputs, state = _base(inputs, state, atr)
        tok = outputs["out"][0]
        for p in _actor.out_ports:
            if p.name != "out":
                outputs[p.name] = [tok]
        return outputs, state

    actor.fire_fn = fanout_fire
    actor.meta["_fanout_wrapped"] = True


def partition_point_after(g: Graph, actor_name: str) -> int:
    """Partition point index such that ``actor_name`` is the last actor on
    the endpoint ('Input ... DWCL9' in Sec IV.B)."""
    prec = g.precedence_index()
    return prec[actor_name] + 1
