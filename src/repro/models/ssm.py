"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, true recurrence with exponential gating).

mLSTM recurrence (per head, state C in R^{dh x dh}, n in R^{dh}, m in R)::

    m_t = max(log f_t + m_{t-1}, log i_t)                 (stabilizer)
    C_t = exp(log f_t + m_{t-1} - m_t) C_{t-1} + exp(log i_t - m_t) v_t k_t^T
    n_t = exp(log f_t + m_{t-1} - m_t) n_{t-1} + exp(log i_t - m_t) k_t
    h_t = (C_t q_t) / max(|n_t . q_t|, exp(-m_t))

Training/prefill uses the CHUNKWISE parallel form (the TPU-native
adaptation of the paper's CUDA kernels): the sequence is split into
chunks of length ``chunk``; within a chunk the contribution is a masked
quadratic "decay attention", across chunks the (C, n, m) state is carried
by ``lax.scan`` — O(S * chunk) work/memory instead of O(S^2), which is
what makes prefill_32k and long_500k tractable for this family.

sLSTM is inherently sequential (h_{t-1} feeds the gates through recurrent
block-diagonal R matrices) and lowers as a ``lax.scan`` over time.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg, dtype) -> dict:
    d = cfg.d_model
    dm = int(cfg.mlstm_proj_factor * d)
    nh = cfg.n_heads
    assert dm % nh == 0
    dh = dm // nh
    ks = jax.random.split(key, 8)
    return {
        "ln": jnp.zeros((d,), dtype),
        "w_up": dense_init(ks[0], d, (2 * dm,), dtype),     # [x | z-gate]
        "conv_w": dense_init(ks[1], 4, (dm,), dtype),
        "conv_b": jnp.zeros((dm,), dtype),
        "wq": dense_init(ks[2], dm, (nh, dh), dtype),
        "wk": dense_init(ks[3], dm, (nh, dh), dtype),
        "wv": dense_init(ks[4], dm, (nh, dh), dtype),
        "w_if": dense_init(ks[5], dm, (2 * nh,), jnp.float32),
        # forget-gate bias init positive -> long memory at init
        "b_if": jnp.concatenate([jnp.zeros((nh,)), 3.0 * jnp.ones((nh,))]),
        "gn": jnp.zeros((nh, dh), dtype),                   # per-head norm
        "w_down": dense_init(ks[6], dm, (d,), dtype),
    }


def _mlstm_qkvg(p, x, cfg):
    from repro.models.rglru import _causal_conv
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    up = h @ p["w_up"]
    xm, z = jnp.split(up, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(xm, p["conv_w"], p["conv_b"]))
    q = jnp.einsum("bsd,dhk->bshk", xc, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", xc, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xm, p["wv"])
    gates = xc.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    log_i, f_raw = jnp.split(gates, 2, axis=-1)             # (B, S, nh)
    log_f = -jax.nn.softplus(-f_raw)                        # log sigmoid
    return q, k, v, z, log_i, log_f


def mlstm_chunkwise(q, k, v, log_i, log_f, state, *, chunk: int = 256):
    """q,k,v: (B,S,H,dh) f32; log_i/log_f: (B,S,H); state (C,n,m) or None.
    Returns (out (B,S,H,dh), new_state). Chunkwise-parallel stabilized."""
    b, s, nh, dh = q.shape
    scale = 1.0 / math.sqrt(dh)
    q = q.astype(jnp.float32) * scale
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    L = min(chunk, s)
    pad = -s % L
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // L

    def to_chunks(x):
        return x.reshape(b, nc, L, *x.shape[2:]).transpose(
            1, 0, *range(2, x.ndim + 1))

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    lic, lfc = to_chunks(log_i), to_chunks(log_f)

    if state is None:
        C0 = jnp.zeros((b, nh, dh, dh))
        n0 = jnp.zeros((b, nh, dh))
        m0 = jnp.full((b, nh), -1e30)
    else:
        C0, n0, m0 = state

    def chunk_step(carry, inp):
        C, n, m = carry
        qh, kh, vh, li, lf = inp                   # (B,L,H,dh), ..., (B,L,H)
        F = jnp.cumsum(lf, axis=1)                 # inclusive decay-to-i
        # per-position stabilizer within + across chunk
        intra_max = jnp.max(li - F, axis=1, keepdims=True)  # loose upper bnd
        m_pos = jnp.maximum(m[:, None] + F, F + intra_max)  # (B,L,H)
        # intra-chunk masked decay attention
        # D[i,j] = exp(li_j + F_i - F_j - m_i)  for j <= i
        dmat = (li[:, None, :, :] + F[:, :, None, :]
                - F[:, None, :, :] - m_pos[:, :, None, :])  # (B, i, j, H)
        mask = jnp.tril(jnp.ones((L, L), bool))
        dmat = jnp.where(mask[None, :, :, None], dmat, -1e30)
        w = jnp.exp(dmat)
        scores = jnp.einsum("bihd,bjhd->bijh", qh, kh) * w
        num = jnp.einsum("bijh,bjhd->bihd", scores, vh)
        den = scores.sum(axis=2)                              # (B,L,H)
        # inter-chunk: decayed previous state
        inter_w = jnp.exp(m[:, None] + F - m_pos)             # (B,L,H)
        num = num + jnp.einsum("bihd,bhde,bih->bihe", qh, C, inter_w)
        den = den + jnp.einsum("bihd,bhd,bih->bih", qh, n, inter_w)
        out = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_pos))[..., None]
        # state update to end of chunk
        Ftot = F[:, -1]                                       # (B,H)
        m_new = jnp.maximum(m + Ftot, jnp.max(li + Ftot[:, None] - F, axis=1))
        kw = jnp.exp(li + Ftot[:, None] - F - m_new[:, None])  # (B,L,H)
        decay = jnp.exp(m + Ftot - m_new)                      # (B,H)
        C_new = decay[..., None, None] * C + jnp.einsum(
            "bjhd,bjhe,bjh->bhde", kh, vh, kw)
        n_new = decay[..., None] * n + jnp.einsum("bjhd,bjh->bhd", kh, kw)
        return (C_new, n_new, m_new), out

    (C, n, m), outs = jax.lax.scan(chunk_step, (C0, n0, m0),
                                   (qc, kc, vc, lic, lfc))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, nc * L, nh, dh)[:, :s]
    return out, (C, n, m)


def mlstm_apply(p, x, cfg) -> jax.Array:
    q, k, v, z, log_i, log_f = _mlstm_qkvg(p, x, cfg)
    out, _ = mlstm_chunkwise(q, k, v, log_i, log_f, None,
                             chunk=min(cfg.attn_chunk, 256))
    out = rms_norm(out.astype(x.dtype), p["gn"], cfg.norm_eps)
    dm = out.shape[-2] * out.shape[-1]
    out = out.reshape(*x.shape[:2], dm) * jax.nn.silu(z)
    return x + out @ p["w_down"]


def mlstm_prefill_cache(p, x, cfg) -> Tuple[jax.Array, dict]:
    q, k, v, z, log_i, log_f = _mlstm_qkvg(p, x, cfg)
    out, (C, n, m) = mlstm_chunkwise(q, k, v, log_i, log_f, None,
                                     chunk=min(cfg.attn_chunk, 256))
    out = rms_norm(out.astype(x.dtype), p["gn"], cfg.norm_eps)
    dm = out.shape[-2] * out.shape[-1]
    out = out.reshape(*x.shape[:2], dm) * jax.nn.silu(z)
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    xm = (h @ p["w_up"])[..., :dm]
    cache = {"C": C, "n": n, "m": m, "conv": xm[:, -3:].astype(x.dtype)}
    return x + out @ p["w_down"], cache


def mlstm_decode(p, x, cfg, *, cache, cache_len=None) -> Tuple[jax.Array, dict]:
    """x: (B, 1, D); O(1) matrix-memory update."""
    from repro.models.rglru import _causal_conv
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    up = h @ p["w_up"]
    dm = up.shape[-1] // 2
    xm, z = jnp.split(up, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(xm, p["conv_w"], p["conv_b"],
                                  tail=cache["conv"]))
    nh = p["wq"].shape[1]
    dh = p["wq"].shape[2]
    q = jnp.einsum("bd,dhk->bhk", xc[:, 0], p["wq"]).astype(jnp.float32) \
        / math.sqrt(dh)
    k = jnp.einsum("bd,dhk->bhk", xc[:, 0], p["wk"]).astype(jnp.float32)
    v = jnp.einsum("bd,dhk->bhk", xm[:, 0], p["wv"]).astype(jnp.float32)
    gates = xc[:, 0].astype(jnp.float32) @ p["w_if"] + p["b_if"]
    log_i, f_raw = jnp.split(gates, 2, axis=-1)
    log_f = -jax.nn.softplus(-f_raw)
    C, n, m = cache["C"], cache["n"], cache["m"]
    m_new = jnp.maximum(log_f + m, log_i)
    decay = jnp.exp(log_f + m - m_new)
    inw = jnp.exp(log_i - m_new)
    C = decay[..., None, None] * C + inw[..., None, None] \
        * jnp.einsum("bhd,bhe->bhde", k, v)
    n = decay[..., None] * n + inw[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)),
                      jnp.exp(-m_new))
    out = (num / den[..., None]).astype(x.dtype)
    out = rms_norm(out, p["gn"], cfg.norm_eps).reshape(x.shape[0], 1, dm)
    out = out * jax.nn.silu(z)
    new_cache = {"C": C, "n": n, "m": m_new,
                 "conv": jnp.concatenate([cache["conv"], xm], 1)[:, 1:]}
    return x + out @ p["w_down"], new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg, dtype) -> dict:
    d = cfg.d_model
    nh = cfg.n_heads
    assert d % nh == 0
    dh = d // nh
    ds = int(cfg.slstm_proj_factor * d)
    ks = jax.random.split(key, 5)
    return {
        "ln": jnp.zeros((d,), dtype),
        "w": dense_init(ks[0], d, (4, nh, dh), dtype),       # z,i,f,o
        "r": (jax.random.normal(ks[1], (4, nh, dh, dh), jnp.float32)
              / math.sqrt(dh)).astype(dtype),
        "b": jnp.zeros((4, nh, dh), jnp.float32)
             .at[2].set(3.0),                                # forget bias
        "gn": jnp.zeros((nh, dh), dtype),
        "w_up": dense_init(ks[2], d, (2 * ds,), dtype),
        "w_down": dense_init(ks[3], ds, (d,), dtype),
    }


def _slstm_cell(p, wx_t, state):
    """One sLSTM step. wx_t: (B, 4, nh, dh) input contribution;
    state = (c, n, m, h) each (B, nh, dh)."""
    c, n, m, h = state
    rec = jnp.einsum("bhd,ghde->bghe", h.astype(jnp.float32),
                     p["r"].astype(jnp.float32))
    raw = wx_t.astype(jnp.float32) + rec + p["b"]
    z = jnp.tanh(raw[:, 0])
    log_i = raw[:, 1]
    log_f = -jax.nn.softplus(-raw[:, 2])
    o = jax.nn.sigmoid(raw[:, 3])
    m_new = jnp.maximum(log_f + m, log_i)
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c_new = f_s * c + i_s * z
    n_new = jnp.maximum(f_s * n + i_s, 1e-6)
    h_new = o * c_new / n_new
    return (c_new, n_new, m_new, h_new)


def slstm_scan(p, wx, state):
    """wx: (B, S, 4, nh, dh). Sequential lax.scan over time."""
    def step(st, wx_t):
        st = _slstm_cell(p, wx_t, st)
        return st, st[3]
    state, hs = jax.lax.scan(step, state, wx.transpose(1, 0, 2, 3, 4))
    return hs.transpose(1, 0, 2, 3), state  # (B,S,nh,dh)


def _slstm_zero_state(b, nh, dh):
    z = jnp.zeros((b, nh, dh))
    return (z, jnp.ones((b, nh, dh)), jnp.full((b, nh, dh), -1e30), z)


def _slstm_out(p, hs, x, cfg):
    hs = rms_norm(hs.astype(x.dtype), p["gn"], cfg.norm_eps)
    flat = hs.reshape(*hs.shape[:-2], -1)
    up = flat @ p["w_up"]
    a, g = jnp.split(up, 2, axis=-1)
    return x + (jax.nn.gelu(a) * g) @ p["w_down"]


def slstm_apply(p, x, cfg) -> jax.Array:
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    wx = jnp.einsum("bsd,dghe->bsghe", h, p["w"])
    nh, dh = p["gn"].shape
    hs, _ = slstm_scan(p, wx, _slstm_zero_state(x.shape[0], nh, dh))
    return _slstm_out(p, hs, x, cfg)


def slstm_prefill_cache(p, x, cfg) -> Tuple[jax.Array, dict]:
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    wx = jnp.einsum("bsd,dghe->bsghe", h, p["w"])
    nh, dh = p["gn"].shape
    hs, st = slstm_scan(p, wx, _slstm_zero_state(x.shape[0], nh, dh))
    cache = {"c": st[0], "n": st[1], "m": st[2], "h": st[3]}
    return _slstm_out(p, hs, x, cfg), cache


def slstm_decode(p, x, cfg, *, cache, cache_len=None) -> Tuple[jax.Array, dict]:
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    wx = jnp.einsum("bsd,dghe->bsghe", h, p["w"])[:, 0]
    st = (cache["c"], cache["n"], cache["m"], cache["h"])
    c, n, m, hn = _slstm_cell(p, wx, st)
    out = _slstm_out(p, hn[:, None], x, cfg)
    return out, {"c": c, "n": n, "m": m, "h": hn}
