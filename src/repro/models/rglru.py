"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block structure (the paper's "recurrent block"):

    x -> RMSNorm -> { gate branch: W_gate -> GeLU            } -> * -> W_out -> +x
                    { rec branch:  W_rec -> causal conv(4)
                                   -> RG-LRU                 }

RG-LRU recurrence (real-gated linear recurrent unit), per channel::

    r_t = sigmoid(W_a h_t + b_a)          input-dependent recurrence gate
    i_t = sigmoid(W_x h_t + b_x)          input gate
    a_t = exp(-c * softplus(Lambda) * r_t)        with c = 8
    y_t = a_t * y_{t-1} + sqrt(1 - a_t^2) * (i_t * h_t)

Train/prefill lowers the recurrence with ``jax.lax.associative_scan``
(log-depth, parallelizable across the sequence — the TPU-native analogue
of the paper's GPU linear-scan kernel); the Pallas kernel
(``kernels.rglru_scan``) is the blocked TPU version. Decode is the O(1)
state update. The temporal conv keeps a (B, width-1, D) tail state.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm

_C = 8.0


def rglru_init(key, cfg, dtype) -> dict:
    d = cfg.d_model
    w = cfg.rglru_conv_width
    ks = jax.random.split(key, 6)
    # Lambda init so that a = exp(-c*softplus(L)*r) starts near 0.9..0.999
    lam = jax.random.uniform(ks[4], (d,), jnp.float32, 0.001, 0.1)
    lam = jnp.log(jnp.exp(-jnp.log(lam) / _C) - 1.0)  # inverse softplus
    return {
        "ln": jnp.zeros((d,), dtype),
        "w_in": dense_init(ks[0], d, (2 * d,), dtype),      # [gate | rec]
        "conv_w": dense_init(ks[1], w, (d,), dtype),        # depthwise
        "conv_b": jnp.zeros((d,), dtype),
        "w_gates": dense_init(ks[2], d, (2 * d,), dtype),   # [r | i]
        "b_gates": jnp.zeros((2 * d,), dtype),
        "lam": lam,
        "w_out": dense_init(ks[3], d, (d,), dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 tail: jax.Array = None) -> jax.Array:
    """Depthwise causal conv. x: (B, S, D); w: (W, D). ``tail``: (B, W-1, D)
    previous inputs for streaming decode (zeros for prefill)."""
    width = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(width))
    return out + b


def _gates(p, xc):
    gates = xc @ p["w_gates"] + p["b_gates"]
    r, i = jnp.split(jax.nn.sigmoid(gates.astype(jnp.float32)), 2, axis=-1)
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * (i * xc.astype(jnp.float32))


def rglru_scan_ref(a: jax.Array, b: jax.Array, h0: jax.Array) -> jax.Array:
    """y_t = a_t y_{t-1} + b_t via associative scan. a,b: (B, S, D)."""
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, b1 * a2 + b2
    a0 = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
    b0 = jnp.concatenate([h0[:, None], b], axis=1)
    _, y = jax.lax.associative_scan(combine, (a0, b0), axis=1)
    return y[:, 1:]


def rglru_apply(p, x, cfg) -> jax.Array:
    """Full-sequence recurrent block with residual. x: (B, S, D)."""
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    gate, rec = jnp.split(h @ p["w_in"], 2, axis=-1)
    gate = jax.nn.gelu(gate)
    xc = _causal_conv(rec, p["conv_w"], p["conv_b"])
    a, b = _gates(p, xc)
    if cfg.attn_impl == "pallas":
        from repro.kernels.rglru_scan import ops as scan_ops
        y = scan_ops.rglru_scan(a, b, jnp.zeros_like(a[:, 0]))
    else:
        y = rglru_scan_ref(a, b, jnp.zeros_like(a[:, 0]))
    out = (y.astype(x.dtype) * gate) @ p["w_out"]
    return x + out


def rglru_prefill_cache(p, x, cfg, *, positions=None) -> Tuple[jax.Array, dict]:
    """Prefill returning the decode state: recurrent h plus conv tail."""
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    gate, rec = jnp.split(h @ p["w_in"], 2, axis=-1)
    gate = jax.nn.gelu(gate)
    xc = _causal_conv(rec, p["conv_w"], p["conv_b"])
    a, b = _gates(p, xc)
    y = rglru_scan_ref(a, b, jnp.zeros_like(a[:, 0]))
    out = (y.astype(x.dtype) * gate) @ p["w_out"]
    w = cfg.rglru_conv_width
    cache = {"h": y[:, -1], "conv": rec[:, -(w - 1):].astype(x.dtype)}
    return x + out, cache


def rglru_decode(p, x, cfg, *, cache, cache_len=None) -> Tuple[jax.Array, dict]:
    """One-token decode: O(1) state update. x: (B, 1, D)."""
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    gate, rec = jnp.split(h @ p["w_in"], 2, axis=-1)
    gate = jax.nn.gelu(gate)
    xc = _causal_conv(rec, p["conv_w"], p["conv_b"], tail=cache["conv"])
    a, b = _gates(p, xc)
    y = a[:, 0] * cache["h"] + b[:, 0]
    out = (y[:, None].astype(x.dtype) * gate) @ p["w_out"]
    new_cache = {"h": y,
                 "conv": jnp.concatenate([cache["conv"], rec], axis=1)[:, 1:]}
    return x + out, new_cache
