"""Shared transformer building blocks: norms, RoPE, GQA attention, MLPs.

Attention comes in three interchangeable implementations:

* ``ref`` — naive full-matrix softmax attention (``kernels.flash_attention.ref``),
  the oracle for tests; O(S^2) memory, never used in the compiled path.
* ``xla`` — double-blocked online-softmax attention built from
  ``jax.lax.scan`` (this module): O(S * chunk) live memory, the production
  path on CPU and the dry-run path (XLA fuses the scan body). Supports
  causal and sliding-window masking, GQA, and per-call positions.
* ``pallas`` — the TPU kernel (``kernels.flash_attention``), same tiling
  expressed with explicit BlockSpecs; validated against ``ref`` in
  interpret mode.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with fp32 accumulation but ELEMENTWISE math in x.dtype.

    The variance reduction upcasts per-element inside the fused reduction
    only; the (B, S, D) tensor itself never exists in fp32. This matters
    under SPMD: with the residual stream sharded on the feature dim, a
    leading x.astype(f32) makes the partitioner place the model-axis
    all-gather on the fp32 tensor — 2x the bytes of the bf16 gather, and
    the single largest remaining collective in the MoE train_4k baseline
    (9.7 GB x 9 per layer; EXPERIMENTS.md §Perf iteration 2)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * (1.0 + scale).astype(x.dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": partial(jax.nn.gelu, approximate=True),
            "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, rot_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for the first ``rot_dim`` dims of a head.
    ``rot_dim < head_dim`` implements partial rotary (ChatGLM applies RoPE
    to half of each head — its '2d' position encoding keeps the other half
    position-free)."""
    return 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               rope_fraction: float = 1.0) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32. Rotates the first
    ``rope_fraction * D`` dims pairwise (non-interleaved / NeoX style)."""
    b, s, h, d = x.shape
    rot = int(d * rope_fraction)
    rot -= rot % 2
    inv = rope_freqs(d, rot, theta)                       # (rot/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (B, S, rot/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


# ---------------------------------------------------------------------------
# blocked online-softmax attention (the "xla" implementation)
# ---------------------------------------------------------------------------

def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _fa_mask(qp, kp, kv_len, causal, window, sk_valid=None):
    """Attention mask for one (q-block, k-block) tile.

    Static path (kv_len is None, qp is (bq,)): returns (bq, bk) — batch-
    independent, so XLA hoists a few-MB predicate instead of materializing
    a (B, bq, bk) tensor per tile (which shows up as multi-GB pred buffers
    in the train dry-run). ``sk_valid`` (static int) masks the zero-padded
    kv tail — without it, non-causal (cross-)attention attends to padding.
    Ragged path (kv_len (B,), qp (B, bq)): (B, bq, bk).
    """
    if kv_len is None:
        mask = jnp.ones((qp.shape[-1], kp.shape[0]), bool)
        if sk_valid is not None:
            mask &= kp[None, :] < sk_valid
        if causal:
            mask &= qp[:, None] >= kp[None, :]
        if window > 0:
            mask &= qp[:, None] - kp[None, :] < window
        return mask                                    # (bq, bk)
    mask = kp[None, None, :] < kv_len[:, None, None]
    if causal:
        mask &= qp[:, :, None] >= kp[None, None, :]
    if window > 0:
        mask &= qp[:, :, None] - kp[None, None, :] < window
    return mask                                        # (B, bq, bk)


def _apply_mask(s, mask):
    """s: (B,Hk,g,bq,bk); mask: (bq,bk) or (B,bq,bk)."""
    if mask.ndim == 2:
        return jnp.where(mask, s, -1e30)
    return jnp.where(mask[:, None, None], s, -1e30)


def _fa_forward(q, k, v, q_offset, kv_len, causal, window, chunk, scale):
    """Returns (out (B,Sq,H,D), lse (B,Hk,g,Sq)) — blocked online softmax."""
    b, sq, h, d = q.shape
    _, sk, hk, _ = k.shape
    g = h // hk
    bq = min(chunk, _ceil_to(sq, 8))
    bk = min(chunk, _ceil_to(sk, 8))
    nq, nk = -(-sq // bq), -(-sk // bk)
    pq, pk = nq * bq - sq, nk * bk - sk
    qf = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kf = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vf = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v
    qf = qf.reshape(b, nq, bq, hk, g, d)
    kf = kf.reshape(b, nk, bk, hk, d)
    vf = vf.reshape(b, nk, bk, hk, d)
    if q_offset is None:
        q_pos = jnp.arange(nq * bq).reshape(nq, bq)              # (nq, bq)
    else:
        q_pos = (q_offset[:, None]
                 + jnp.arange(nq * bq)[None]).reshape(b, nq, bq) \
            .transpose(1, 0, 2)                                  # (nq, B, bq)
    k_pos = jnp.arange(nk * bk).reshape(nk, bk)

    def q_block(args):
        qb, qp = args                                # (B,bq,Hk,g,D), (B,bq)

        def kv_step(carry, kv):
            m, l, acc = carry
            kb, vb, kp = kv                          # (B,bk,Hk,D), ..., (bk,)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb.astype(jnp.float32),
                           kb.astype(jnp.float32)) * scale
            mask = _fa_mask(qp, kp, kv_len, causal, window, sk_valid=sk)
            s = _apply_mask(s, mask)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32))
            return (m_new, l, acc), None

        m0 = jnp.full((b, hk, g, bq), -1e30)
        l0 = jnp.zeros((b, hk, g, bq))
        a0 = jnp.zeros((b, hk, g, bq, d))
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kf.transpose(1, 0, 2, 3, 4), vf.transpose(1, 0, 2, 3, 4), k_pos))
        out = acc / jnp.maximum(l[..., None], 1e-30)  # (B,Hk,g,bq,D)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))      # (B,Hk,g,bq)
        return out.transpose(0, 3, 1, 2, 4), lse

    outs, lses = jax.lax.map(q_block, (qf.transpose(1, 0, 2, 3, 4, 5),
                                       q_pos))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * bq, h, d)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(b, hk, g, nq * bq)
    return out[:, :sq].astype(q.dtype), lse[..., :sq]


@partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _fa(q, k, v, q_offset, kv_len, causal, window, chunk, scale):
    out, _ = _fa_forward(q, k, v, q_offset, kv_len, causal, window, chunk,
                         scale)
    return out


def _fa_fwd(q, k, v, q_offset, kv_len, causal, window, chunk, scale):
    out, lse = _fa_forward(q, k, v, q_offset, kv_len, causal, window, chunk,
                           scale)
    return out, (q, k, v, q_offset, kv_len, out, lse)


def _fa_bwd(causal, window, chunk, scale, res, dout):
    """True flash-attention backward: recompute P blockwise from (q,k,lse);
    O(bq*bk) live memory, no stacked residuals — this is what keeps the
    train_4k dry-run's temp footprint bounded."""
    q, k, v, q_offset, kv_len, out, lse = res
    b, sq, h, d = q.shape
    _, sk, hk, _ = k.shape
    g = h // hk
    bq = min(chunk, _ceil_to(sq, 8))
    bk = min(chunk, _ceil_to(sk, 8))
    nq, nk = -(-sq // bq), -(-sk // bk)
    pq, pk = nq * bq - sq, nk * bk - sk

    def padq(x):
        return jnp.pad(x, ((0, 0), (0, pq)) + ((0, 0),) * (x.ndim - 2)) \
            if pq else x

    def padk(x):
        return jnp.pad(x, ((0, 0), (0, pk)) + ((0, 0),) * (x.ndim - 2)) \
            if pk else x

    qf = padq(q).reshape(b, nq, bq, hk, g, d)
    dof = padq(dout.astype(jnp.float32)).reshape(b, nq, bq, hk, g, d)
    # delta = rowsum(dout * out)  (B,Hk,g,Sq)
    delta = jnp.einsum("bshd,bshd->bhs", dout.astype(jnp.float32),
                       out.astype(jnp.float32))
    delta = padq(delta.transpose(0, 2, 1)).transpose(0, 2, 1) \
        .reshape(b, hk, g, nq, bq)
    lsef = jnp.pad(lse, ((0, 0), (0, 0), (0, 0), (0, pq))) if pq else lse
    lsef = lsef.reshape(b, hk, g, nq, bq)
    kf = padk(k).reshape(b, nk, bk, hk, d)
    vf = padk(v).reshape(b, nk, bk, hk, d)
    if q_offset is None:
        q_pos = jnp.arange(nq * bq).reshape(nq, bq)
    else:
        q_pos = (q_offset[:, None]
                 + jnp.arange(nq * bq)[None]).reshape(b, nq, bq) \
            .transpose(1, 0, 2)
    k_pos = jnp.arange(nk * bk).reshape(nk, bk)

    def q_block(carry, xs):
        dk, dv = carry                       # (B,nk*bk,Hk,D) f32 accumulators
        qb, do, dl, ls, qp = xs

        def kv_step(dq, kv):
            kb, vb, kp, ki = kv
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb.astype(jnp.float32),
                           kb.astype(jnp.float32)) * scale
            mask = _fa_mask(qp, kp, kv_len, causal, window, sk_valid=sk)
            s = _apply_mask(s, mask)
            p = jnp.exp(s - ls[..., None])                  # (B,Hk,g,bq,bk)
            dv_blk = jnp.einsum("bhgqk,bqhgd->bkhd", p, do)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", do, vb.astype(jnp.float32))
            ds = p * (dp - dl[..., None]) * scale
            dq = dq + jnp.einsum("bhgqk,bkhd->bqhgd", ds,
                                 kb.astype(jnp.float32))
            dk_blk = jnp.einsum("bhgqk,bqhgd->bkhd", ds,
                                qb.astype(jnp.float32))
            return dq, (dk_blk, dv_blk, ki)

        dq0 = jnp.zeros((b, bq, hk, g, d))
        dq, (dk_blks, dv_blks, _) = jax.lax.scan(
            kv_step, dq0,
            (kf.transpose(1, 0, 2, 3, 4), vf.transpose(1, 0, 2, 3, 4),
             k_pos, jnp.arange(nk)))
        dk = dk + dk_blks.transpose(1, 0, 2, 3, 4).reshape(b, nk * bk, hk, d)
        dv = dv + dv_blks.transpose(1, 0, 2, 3, 4).reshape(b, nk * bk, hk, d)
        return (dk, dv), dq

    dk0 = jnp.zeros((b, nk * bk, hk, d))
    dv0 = jnp.zeros((b, nk * bk, hk, d))
    (dk, dv), dqs = jax.lax.scan(
        q_block, (dk0, dv0),
        (qf.transpose(1, 0, 2, 3, 4, 5), dof.transpose(1, 0, 2, 3, 4, 5),
         delta.transpose(3, 0, 1, 2, 4), lsef.transpose(3, 0, 1, 2, 4),
         q_pos))
    dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * bq, h, d)
    return (dq[:, :sq].astype(q.dtype), dk[:, :sk].astype(k.dtype),
            dv[:, :sk].astype(v.dtype), None, None)


_fa.defvjp(_fa_fwd, _fa_bwd)


def flash_attention_xla(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        q_offset: Optional[jax.Array] = None,
                        kv_len: Optional[jax.Array] = None,
                        chunk: int = 1024, scale: Optional[float] = None
                        ) -> jax.Array:
    """Blocked online-softmax attention with a flash backward (custom VJP):
    O(bq*bk) live score memory in BOTH passes. GQA: q has H heads, k/v have
    Hk | H heads.

    q: (B, Sq, H, D); k, v: (B, Sk, Hk, D).
    ``q_offset``: (B,) absolute position of q[0] within the kv sequence
    (prefill: 0; decode: cache length). ``kv_len``: (B,) valid kv prefix
    length (entries beyond it are masked; enables ragged batches).
    ``window > 0``: sliding-window mask (position distance < window).
    """
    b, sq, h, d = q.shape
    _, sk, hk, _ = k.shape
    assert h % hk == 0, (h, hk)
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    # q_offset / kv_len stay None on the static (train/prefill) path so
    # masks are batch-free (see _fa_mask); they are (B,) arrays only for
    # ragged/offset batches.
    return _fa(q, k, v, q_offset, kv_len, causal, window, chunk, scale)


def decode_attention_xla(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         cache_len: jax.Array, *, window: int = 0,
                         scale: Optional[float] = None) -> jax.Array:
    """Single-token GQA attention against a KV cache.

    q: (B, H, D); caches: (B, S, Hk, D); cache_len: (B,) number of valid
    entries INCLUDING the current token (already written at cache_len-1).
    """
    b, h, d = q.shape
    _, s, hk, _ = k_cache.shape
    g = h // hk
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(b, hk, g, d).astype(jnp.float32)
    kc = k_cache.astype(jnp.float32)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg, kc) * scale
    pos = jnp.arange(s)[None]                       # (1, S)
    mask = pos < cache_len[:, None]
    if window > 0:
        mask &= pos >= cache_len[:, None] - window
    logits = jnp.where(mask[:, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# parameter init helpers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_shape: Tuple[int, ...], dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim,) + out_shape, jnp.float32)
            * scale).astype(dtype)


# ---------------------------------------------------------------------------
# attention block (init + apply)
# ---------------------------------------------------------------------------

def attn_init(key, cfg, dtype) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, hk = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 5)
    p = {
        "wq": dense_init(ks[0], d, (h, hd), dtype),
        "wk": dense_init(ks[1], d, (hk, hd), dtype),
        "wv": dense_init(ks[2], d, (hk, hd), dtype),
        "wo": dense_init(ks[3], h * hd, (d,), dtype).reshape(h, hd, d),
        "ln": jnp.zeros((d,), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((hk, hd), dtype)
        p["bv"] = jnp.zeros((hk, hd), dtype)
    return p


def _qkv(p, x, cfg):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


def _theta_for(cfg, kind: str) -> float:
    if kind == "attn" and cfg.rope_theta_global is not None:
        return cfg.rope_theta_global
    return cfg.rope_theta


def attn_apply(p, x, cfg, *, kind: str, positions, mask_len=None) -> jax.Array:
    """Full-sequence (train/prefill) attention sublayer with residual."""
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q, k, v = _qkv(p, h, cfg)
    theta = _theta_for(cfg, kind)
    q = apply_rope(q, positions, theta, cfg.rope_fraction)
    k = apply_rope(k, positions, theta, cfg.rope_fraction)
    window = cfg.window if kind == "attn_local" else 0
    if cfg.attn_impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        o = fa_ops.flash_attention(q, k, v, causal=True, window=window)
    else:
        o = flash_attention_xla(q, k, v, causal=True, window=window,
                                kv_len=mask_len, chunk=cfg.attn_chunk)
    return x + jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def attn_decode(p, x, cfg, *, kind: str, cache, cache_len) -> Tuple[jax.Array, dict]:
    """One-token decode. x: (B, 1, D). cache: {"k","v"}: (B, S_cache, Hk, hd).
    Ring-buffer semantics: the new KV is written at ``cache_len % S_cache``.
    Local-attention layers allocate ``S_cache == window`` so the buffer IS
    the sliding window (what bounds long_500k memory); global layers
    allocate the full context so the modulo is a no-op. RoPE is applied to
    keys before caching with absolute positions, which is sound because
    rotary encoding is relative."""
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    positions = cache_len[:, None]                        # (B, 1)
    q, k, v = _qkv(p, h, cfg)
    theta = _theta_for(cfg, kind)
    q = apply_rope(q, positions, theta, cfg.rope_fraction)
    k = apply_rope(k, positions, theta, cfg.rope_fraction)
    size = cache["k"].shape[1]
    idx = cache_len % size
    kc = jax.vmap(lambda c, kn, i: jax.lax.dynamic_update_slice_in_dim(
        c, kn, i, axis=0))(cache["k"], k, idx)
    vc = jax.vmap(lambda c, vn, i: jax.lax.dynamic_update_slice_in_dim(
        c, vn, i, axis=0))(cache["v"], v, idx)
    valid = jnp.minimum(cache_len + 1, size)
    if cfg.attn_impl == "pallas":
        from repro.kernels.decode_attention import ops as da_ops
        o = da_ops.decode_attention(q[:, 0], kc, vc, valid)
    else:
        o = decode_attention_xla(q[:, 0], kc, vc, valid)
    out = x + jnp.einsum("bhk,hkd->bd", o, p["wo"])[:, None]
    return out, {"k": kc, "v": vc}


def attn_extend(p, x, cfg, *, kind: str, cache, cache_len
                ) -> Tuple[jax.Array, dict]:
    """Chunked-prefill extension: a (B, C) token chunk attends over the
    existing cache plus itself (causal within the chunk), and its K/V rows
    are appended at absolute positions [cache_len, cache_len + C).

    Global-attention only: the cache is position-indexed (no ring
    wrapping), which ``supports_chunked_prefill`` guarantees. The caller
    may pad the chunk past the real prompt — padded q rows sit at later
    positions, so causal masking keeps every real row's attention (and
    hence the emitted first token) independent of the padding."""
    assert kind == "attn", "chunked prefill pages global attention only"
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    c = x.shape[1]
    positions = cache_len[:, None] + jnp.arange(c, dtype=jnp.int32)[None]
    q, k, v = _qkv(p, h, cfg)
    theta = _theta_for(cfg, kind)
    q = apply_rope(q, positions, theta, cfg.rope_fraction)
    k = apply_rope(k, positions, theta, cfg.rope_fraction)
    kc = jax.vmap(lambda cc, kn, i: jax.lax.dynamic_update_slice_in_dim(
        cc, kn, i, axis=0))(cache["k"], k, cache_len)
    vc = jax.vmap(lambda cc, vn, i: jax.lax.dynamic_update_slice_in_dim(
        cc, vn, i, axis=0))(cache["v"], v, cache_len)
    o = flash_attention_xla(q, kc, vc, causal=True, q_offset=cache_len,
                            kv_len=cache_len + c, chunk=cfg.attn_chunk)
    out = x + jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, {"k": kc, "v": vc}


def paged_gather(pool: jax.Array, tables: jax.Array) -> jax.Array:
    """Materialize per-sequence contiguous KV views from a block pool.
    pool: (N, bs, Hk, D); tables: (B, nb) physical block per logical page.
    Returns (B, nb * bs, Hk, D) — row i of the result is the row that a
    slotted cache would hold at position i, so downstream attention (and
    its masking) is unchanged."""
    b, nb = tables.shape
    _, bs, hk, d = pool.shape
    return pool[tables].reshape(b, nb * bs, hk, d)


def attn_decode_paged(p, x, cfg, *, k_pool, v_pool, tables, cache_len
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode against a paged KV pool (global attention).

    x: (B, 1, D); pools: (N, bs, Hk, hd); tables: (B, nb) with physical
    block 0 reserved as the null block — free slots and unallocated pages
    point there, so their (masked, never-read) writes collide harmlessly.
    The new K/V row is scattered into block ``tables[b, cache_len // bs]``
    at offset ``cache_len % bs`` (the scheduler allocates that block
    before the step), then the slot's pages are streamed back — via the
    block-table-aware Pallas kernel when ``attn_impl == "pallas"``, or an
    XLA gather otherwise. Returns (out, new_k_pool, new_v_pool)."""
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    positions = cache_len[:, None]
    q, k, v = _qkv(p, h, cfg)
    theta = _theta_for(cfg, "attn")
    q = apply_rope(q, positions, theta, cfg.rope_fraction)
    k = apply_rope(k, positions, theta, cfg.rope_fraction)
    bs = k_pool.shape[1]
    blk = jnp.take_along_axis(tables, (cache_len // bs)[:, None], axis=1)[:, 0]
    off = cache_len % bs
    kp = k_pool.at[blk, off].set(k[:, 0])
    vp = v_pool.at[blk, off].set(v[:, 0])
    valid = cache_len + 1
    if cfg.attn_impl == "pallas":
        from repro.kernels.decode_attention import ops as da_ops
        o = da_ops.paged_decode_attention(q[:, 0], kp, vp, tables, valid)
    else:
        o = decode_attention_xla(q[:, 0], paged_gather(kp, tables),
                                 paged_gather(vp, tables), valid)
    out = x + jnp.einsum("bhk,hkd->bd", o, p["wo"])[:, None]
    return out, kp, vp


def attn_prefill_cache(p, x, cfg, *, kind: str, positions, cache_size: int
                       ) -> Tuple[jax.Array, dict]:
    """Full-sequence prefill that also materializes the decode cache.
    Returns (residual output, cache dict). The cache keeps the LAST
    ``cache_size`` positions in ring order (slot = position % cache_size),
    matching ``attn_decode``'s write rule."""
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q, k, v = _qkv(p, h, cfg)
    theta = _theta_for(cfg, kind)
    q = apply_rope(q, positions, theta, cfg.rope_fraction)
    k = apply_rope(k, positions, theta, cfg.rope_fraction)
    window = cfg.window if kind == "attn_local" else 0
    o = flash_attention_xla(q, k, v, causal=True, window=window,
                            chunk=cfg.attn_chunk)
    out = x + jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    s = x.shape[1]
    if cache_size >= s:
        pad = cache_size - s
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:
        # last cache_size tokens, placed at their ring slots
        tail_k = k[:, s - cache_size:]
        tail_v = v[:, s - cache_size:]
        slots = (jnp.arange(s - cache_size, s) % cache_size)
        kc = jnp.zeros_like(tail_k).at[:, slots].set(tail_k)
        vc = jnp.zeros_like(tail_v).at[:, slots].set(tail_v)
    return out, {"k": kc, "v": vc}


def attn_encoder_apply(p, x, cfg, *, positions) -> jax.Array:
    """Bidirectional (encoder) self-attention sublayer."""
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q, k, v = _qkv(p, h, cfg)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    o = flash_attention_xla(q, k, v, causal=False, chunk=cfg.attn_chunk)
    return x + jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def cross_attn_init(key, cfg, dtype) -> dict:
    return attn_init(key, cfg, dtype)


def cross_attn_apply(p, x, enc_out, cfg) -> jax.Array:
    """Decoder cross-attention: queries from x, K/V from encoder output."""
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    o = flash_attention_xla(q, k, v, causal=False, chunk=cfg.attn_chunk)
    return x + jnp.einsum("bshk,hkd->bsd", o, p["wo"])


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], d_model, (d_ff,), dtype),
        "w_up": dense_init(ks[1], d_model, (d_ff,), dtype),
        "w_down": dense_init(ks[2], d_ff, (d_model,), dtype),
        "ln": jnp.zeros((d_model,), dtype),
    }


def mlp_apply(p, x, cfg) -> jax.Array:
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    g = act_fn(cfg.act)(h @ p["w_gate"]) * (h @ p["w_up"])
    return x + g @ p["w_down"]
