"""Quickstart: the Edge-PRUNE workflow end-to-end in ~80 lines.

1. Express an application (the paper's vehicle-classification CNN) as a
   VR-PRUNE dataflow graph.
2. Check it against the design rules (Analyzer).
3. Explore every endpoint/server partition point (Explorer) on the
   paper's calibrated N2-i7 platform.
4. Synthesize the best privacy-preserving mapping into a staged program —
   TX/RX channels auto-inserted — and run real inference through it.
5. Serve an LLM workload through the stable ``repro.serving`` surface —
   submit, stream tokens, get a ``Completion``.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import (Explorer, Mapping, analyze, paper_platform,
                        synthesize)
from repro.models import transformer as T
from repro.models.cnn import vehicle_graph
from repro.models.config import ModelConfig
from repro.serving import Engine, EngineConfig, Request

# 1. the application graph (actors = layer groups, edges = token FIFOs)
g = vehicle_graph()
print(f"graph: {g}")
for f in g.fifos.values():
    print(f"  edge {f.name}: token {f.token_bytes} B")

# 2. consistency: deadlock/buffer-overflow freedom per VR-PRUNE rules
report = analyze(g)
print(f"analyzer: ok={report.ok} repetitions={report.repetition_vector}")

# 3. partition-point exploration on the calibrated paper platform
explorer = Explorer(g, paper_platform("N2", "ethernet"))
result = explorer.evaluate_modeled()
for rec in result.records:
    print(f"  pp{rec.pp}: endpoint {rec.endpoint_time_s*1e3:6.2f} ms, "
          f"boundary {rec.boundary_bytes} B")
best = result.best(privacy=True)
print(f"best privacy-preserving partition: pp{best.pp} "
      f"({best.endpoint_time_s*1e3:.1f} ms — paper: pp3, 14.9 ms)")

# 4. synthesize + execute the chosen mapping
mapping = Mapping.partition_point(g, best.pp)
prog = synthesize(g, mapping)
print(f"stages: {[s.unit for s in prog.stages]}, "
      f"channels: {[c.name for c in prog.channels]}")
img = np.random.RandomState(0).rand(96, 96, 3).astype(np.float32)
out = prog.run_local({"Input": img})
print(f"class probabilities: {np.asarray(out['L4-L5'][0]).round(3)}")

# 5. LLM serving through the stable repro.serving surface: one Engine,
# policy-configured (here the continuous scheduler, defaults); submit
# returns a handle you can stream token by token
cfg = ModelConfig(
    name="quickstart-tiny", arch_type="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256, dtype="float32",
    param_dtype="float32", attn_chunk=16, remat=False)
eng = Engine(cfg, T.init_params(cfg, jax.random.PRNGKey(0)),
             EngineConfig(max_len=48, max_slots=2))
prompt = np.random.RandomState(1).randint(0, 256, 16).astype(np.int32)
handle = eng.submit(Request(id=0, prompt=prompt, max_new_tokens=8))
streamed = list(handle.stream())        # pulls the engine step by step
print(f"served {len(streamed)} tokens ({handle.finish_reason}): "
      f"{streamed}")
