"""Quickstart: the Edge-PRUNE workflow end-to-end in ~60 lines.

1. Express an application (the paper's vehicle-classification CNN) as a
   VR-PRUNE dataflow graph.
2. Check it against the design rules (Analyzer).
3. Explore every endpoint/server partition point (Explorer) on the
   paper's calibrated N2-i7 platform.
4. Synthesize the best privacy-preserving mapping into a staged program —
   TX/RX channels auto-inserted — and run real inference through it.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (Explorer, Mapping, analyze, paper_platform,
                        synthesize)
from repro.models.cnn import vehicle_graph

# 1. the application graph (actors = layer groups, edges = token FIFOs)
g = vehicle_graph()
print(f"graph: {g}")
for f in g.fifos.values():
    print(f"  edge {f.name}: token {f.token_bytes} B")

# 2. consistency: deadlock/buffer-overflow freedom per VR-PRUNE rules
report = analyze(g)
print(f"analyzer: ok={report.ok} repetitions={report.repetition_vector}")

# 3. partition-point exploration on the calibrated paper platform
explorer = Explorer(g, paper_platform("N2", "ethernet"))
result = explorer.evaluate_modeled()
for rec in result.records:
    print(f"  pp{rec.pp}: endpoint {rec.endpoint_time_s*1e3:6.2f} ms, "
          f"boundary {rec.boundary_bytes} B")
best = result.best(privacy=True)
print(f"best privacy-preserving partition: pp{best.pp} "
      f"({best.endpoint_time_s*1e3:.1f} ms — paper: pp3, 14.9 ms)")

# 4. synthesize + execute the chosen mapping
mapping = Mapping.partition_point(g, best.pp)
prog = synthesize(g, mapping)
print(f"stages: {[s.unit for s in prog.stages]}, "
      f"channels: {[c.name for c in prog.channels]}")
img = np.random.RandomState(0).rand(96, 96, 3).astype(np.float32)
out = prog.run_local({"Input": img})
print(f"class probabilities: {np.asarray(out['L4-L5'][0]).round(3)}")
