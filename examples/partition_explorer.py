"""The Edge-PRUNE Explorer applied to a modern LLM: export a transformer
as a VR-PRUNE actor graph, generate the paper's artifact set (per-
partition-point mapping-file pairs + profiling script), and sweep the
pod-boundary partition points on the TPU platform model.

This is Sec III.C's methodology with a decoder LM instead of a CNN: the
partition point is where the activation token crosses from pod0 ("the
endpoint") to pod1 ("the server") over DCN.

Run: PYTHONPATH=src python examples/partition_explorer.py [--arch gemma3_1b]
"""
import argparse
import os
import tempfile

from repro.configs import get_config
from repro.core import Explorer, analyze, tpu_pod_platform
from repro.models import transformer as T


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3_1b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=2048)
    ap.add_argument("--group-size", type=int, default=2,
                    help="transformer layers per dataflow actor")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    g = T.to_actor_graph(cfg, None, batch=args.batch, seq=args.seq,
                         group_size=args.group_size)
    print(f"{cfg.name} as dataflow graph: {g}")
    print(f"analyzer: ok={analyze(g).ok}")

    platform = tpu_pod_platform(2)   # pod0 = 'endpoint', pod1 = 'server'
    explorer = Explorer(g, platform)
    outdir = os.path.join(tempfile.gettempdir(), f"edgeprune_{cfg.name}")
    artifacts = explorer.generate_artifacts(outdir)
    print(f"wrote {len(artifacts)} mapping files + profiling script "
          f"to {outdir}")

    res = explorer.evaluate_modeled()
    print(f"{'pp':>4} {'pod0 time':>12} {'boundary':>12}")
    for rec in res.records:
        print(f"{rec.pp:>4} {rec.endpoint_time_s*1e6:>10.1f}us "
              f"{rec.boundary_bytes:>10d}B  "
              f"{'<- best' if rec.pp == res.best(privacy=True).pp else ''}")
    print(f"\nEvery interior cut ships the same (B, S, d_model) activation "
          f"token, so on homogeneous pods the Explorer's optimum is set by "
          f"the compute split — unlike the paper's CNNs whose token sizes "
          f"shrink with depth. See EXPERIMENTS.md §Pod-boundary.")


if __name__ == "__main__":
    main()
