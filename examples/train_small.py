"""End-to-end training driver: train a small decoder LM for a few hundred
steps on the synthetic bigram LM stream and show the loss dropping toward
the process entropy; finish with a checkpoint + restore + greedy sample.

Run: PYTHONPATH=src python examples/train_small.py [--steps 300]
(Use --d-model 768 --layers 12 for a ~100M-param run on real hardware.)
"""
import argparse
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime import checkpoint, data, optim
from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.serving import Request
from repro.runtime.trainstep import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = ModelConfig(
        name="train-small", arch_type="dense", n_layers=args.layers,
        d_model=args.d_model, n_heads=max(args.d_model // 32, 2),
        n_kv_heads=max(args.d_model // 64, 1), d_ff=args.d_model * 4,
        vocab_size=512, dtype="float32", param_dtype="float32",
        attn_chunk=32, remat=False)
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps of {args.batch}x{args.seq} tokens")

    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = optim.init(params)
    oc = optim.AdamWConfig(lr=3e-3, warmup_steps=30, total_steps=args.steps,
                           weight_decay=0.01)
    step = jax.jit(make_train_step(cfg, oc), donate_argnums=(0, 1))
    gen = data.lm_batches(args.batch, args.seq, cfg.vocab_size, seed=0)
    t0 = time.time()
    first = last = None
    for i, batch in zip(range(args.steps), gen):
        params, opt, m = step(params, opt,
                              {k: jnp.asarray(v) for k, v in batch.items()})
        loss = float(m["loss"])
        first = first if first is not None else loss
        last = loss
        if i % 25 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {loss:.4f} "
                  f"({(time.time()-t0)/(i+1)*1e3:.0f} ms/step)")
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'LEARNED' if last < first * 0.8 else 'no progress?'})")

    path = os.path.join(tempfile.gettempdir(), "train_small.npz")
    checkpoint.save(path, params, meta={"steps": args.steps})
    restored = checkpoint.restore(path, jax.eval_shape(lambda: params))
    print(f"checkpoint round-trip ok -> {path}")

    eng = Engine(cfg, restored, EngineConfig(max_len=args.seq + 16,
                                             admission="batch"))
    prompt = next(data.lm_batches(1, 16, cfg.vocab_size, seed=9))["tokens"][0]
    out = eng.generate([Request(0, prompt, max_new_tokens=12)])[0]
    print(f"sampled continuation of trained model: {out.tokens}")


if __name__ == "__main__":
    main()
