"""Variable-rate conditional offload: VR-PRUNE's dynamic machinery
(Sec III.A — CA/DA/DPA, token rates, DPGs) used for confidence-gated
collaborative inference.

Scenario: the endpoint runs Input..L2 plus a cheap shallow head (the CA).
Only frames the shallow head is UNSURE about are offloaded to the server
for the deep L3..L5 path — everything else exits early on-device. The
dynamic subgraph (entry DA -> deep DPA -> exit DA) has token rate 0 or 1
per frame, set by the CA at run time; the analyzer proves the graph
deadlock/overflow-free at design time, and the symmetric-token-rate rule
guarantees the entry/exit rates always agree.

This is the paper's privacy argument made quantitative: the fraction of
frames whose intermediate features ever leave the device becomes a
RUN-TIME quantity (here 67%), and boundary traffic shrinks by the same
factor vs. always-offload.

Run: PYTHONPATH=src python examples/early_exit_offload.py
"""
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Mapping, Simulator, analyze
from repro.core.graph import Actor, ActorType, Dpg, Graph, Port, PortDir
from repro.models.cnn import conv2d, dense, maxpool2

rng = np.random.RandomState(0)
HW, NCLS = 32, 4


def pw(*shape):
    s = 1.0 / math.sqrt(np.prod(shape[:-1]))
    return jnp.asarray(rng.uniform(-s, s, shape), jnp.float32)


w1, w2 = pw(5, 5, 3, 16), pw(5, 5, 16, 16)
feat = (HW // 4) ** 2 * 16
w_sh, b_sh = pw(feat, NCLS), jnp.zeros((NCLS,))          # shallow head
w3, b3 = pw(feat, 64), jnp.zeros((64,))
w45, b45 = pw(64, NCLS), jnp.zeros((NCLS,))              # deep path

g = Graph("early_exit_offload")
state = {"decisions": [], "confidences": []}

inp = g.add_actor(Actor(
    "Input", ActorType.SPA, [],
    [Port("out", PortDir.OUT, token_shape=(HW, HW, 3))],
    fire_fn=lambda i, st, r: (
        {"out": [jnp.asarray(rng.rand(HW, HW, 3), jnp.float32)]}, st)))

backbone = g.add_actor(Actor(
    "L1L2", ActorType.SPA,
    [Port("in", PortDir.IN, token_shape=(HW, HW, 3))],
    [Port("out", PortDir.OUT, token_shape=(HW // 4, HW // 4, 16))],
    fire_fn=lambda i, st, r: ({"out": [maxpool2(jax.nn.relu(conv2d(
        maxpool2(jax.nn.relu(conv2d(i["in"][0], w1))), w2)))]}, st)))


def gate_fire(inputs, st, rates):
    """The CA: shallow classification + the offload decision."""
    (x,) = inputs["in"]
    logits = dense(x, w_sh, b_sh)
    probs = jax.nn.softmax(logits)
    conf = float(probs.max())
    state["confidences"].append(conf)
    state["decisions"].append(1 if conf < 0.263 else 0)  # unsure -> offload
    return {"feat": [x], "shallow": [probs]}, st


gate = g.add_actor(Actor(
    "Gate", ActorType.CA,
    [Port("in", PortDir.IN, token_shape=(HW // 4, HW // 4, 16))],
    [Port("feat", PortDir.OUT, token_shape=(HW // 4, HW // 4, 16)),
     Port("shallow", PortDir.OUT, token_shape=(NCLS,))],
    fire_fn=gate_fire))

entry = g.add_actor(Actor(
    "EntryDA", ActorType.DA,
    [Port("in", PortDir.IN, token_shape=(HW // 4, HW // 4, 16))],
    [Port("out", PortDir.OUT, lrl=0, url=1,
          token_shape=(HW // 4, HW // 4, 16))],
    fire_fn=lambda i, st, r: (
        {"out": list(i["in"])[:r["out"]]}, st)))

deep = g.add_actor(Actor(
    "DeepL3L5", ActorType.DPA,
    [Port("in", PortDir.IN, lrl=0, url=1,
          token_shape=(HW // 4, HW // 4, 16))],
    [Port("out", PortDir.OUT, lrl=0, url=1, token_shape=(NCLS,))],
    fire_fn=lambda i, st, r: (
        {"out": [jax.nn.softmax(dense(jax.nn.relu(
            dense(x, w3, b3)).reshape(8, 8), w45, b45))
            for x in i.get("in", [])]}, st)))

exit_da = g.add_actor(Actor(
    "ExitDA", ActorType.DA,
    [Port("deep", PortDir.IN, lrl=0, url=1, token_shape=(NCLS,)),
     Port("shallow", PortDir.IN, token_shape=(NCLS,))],
    [Port("result", PortDir.OUT, token_shape=(NCLS,))],
    fire_fn=lambda i, st, r: (
        {"result": [i["deep"][0] if i.get("deep") else i["shallow"][0]]},
        st)))

sink = g.add_actor(Actor(
    "Sink", ActorType.SPA,
    [Port("in", PortDir.IN, token_shape=(NCLS,))], [],
    fire_fn=lambda i, st, r: ({"result": i["in"]}, st)))

g.connect(inp.port("out"), backbone.port("in"))
g.connect(backbone.port("out"), gate.port("in"))
g.connect(gate.port("feat"), entry.port("in"))
g.connect(gate.port("shallow"), exit_da.port("shallow"), capacity=4)
g.connect(entry.port("out"), deep.port("in"))
g.connect(deep.port("out"), exit_da.port("deep"))
g.connect(exit_da.port("result"), sink.port("in"))
g.add_dpg(Dpg("offload", ca="Gate", entry_da="EntryDA", exit_da="ExitDA",
              members=["Gate", "EntryDA", "DeepL3L5", "ExitDA"]))

report = analyze(g)
print(f"analyzer: ok={report.ok} errors={report.errors}")


def atr_fn(actor, k):
    """The CA's run-time rate assignment: symmetric on every DPG edge."""
    d = state["decisions"][k] if k < len(state["decisions"]) else 1
    if actor.name == "EntryDA":
        return {"out": d}
    if actor.name == "DeepL3L5":
        return {"in": d, "out": d}
    if actor.name == "ExitDA":
        return {"deep": d}
    return {}


FRAMES = 30
mapping = Mapping("offload", {a: ("server" if a == "DeepL3L5" else "endpoint")
                              for a in g.actors})
sim = Simulator(g, atr_fn=atr_fn)
res = sim.run(FRAMES)
results = res.outputs["Sink"]
offloaded = sum(state["decisions"][:FRAMES])
print(f"confidence range: {min(state['confidences']):.3f}.."
      f"{max(state['confidences']):.3f}")
tok_bytes = g.fifos["Gate.feat->EntryDA.in"].token_bytes
print(f"frames: {FRAMES}, offloaded (conf<0.263): {offloaded} "
      f"({100*offloaded/FRAMES:.0f}%)")
print(f"boundary traffic: {offloaded * tok_bytes} B vs always-offload "
      f"{FRAMES * tok_bytes} B -> {100*(1-offloaded/FRAMES):.0f}% saved; "
      f"{FRAMES - offloaded} frames never leave the device")
assert len(results) == FRAMES
for p in results:
    np.testing.assert_allclose(float(jnp.sum(p)), 1.0, rtol=1e-5)
print("every frame produced a normalized classification — the variable-"
      "rate DPG is consistent (no deadlock, rates symmetric).")
