"""End-to-end driver: serve a small LLM with batched requests — static
buckets, the continuous-batching scheduler, and an Edge-PRUNE partitioned
actor graph streamed through a pipelined 2-unit schedule.

The partitioned path is the paper's collaborative-inference scenario:
the model's early layer-group actors run on the "endpoint" unit, the
rest on the "server"; the synthesis step auto-inserts the TX/RX channel
at the boundary and the prefill executes stage-by-stage. We verify both
paths produce identical logits, report the boundary traffic per request
— the quantity the paper's Figs 4-6 trade against compute — and show the
modeled pipelining win of overlapping stage k of frame i with stage k-1
of frame i+1 (Sec III.B).

Run: PYTHONPATH=src python examples/distributed_serving.py
"""
import jax
import numpy as np

from repro.core import Mapping, PlatformModel, paper_platform
from repro.models import transformer as T
from repro.models.config import ModelConfig
# repro.serving is the stable serving surface (Engine + lifecycle types);
# the partitioned actor-graph engine stays a runtime.serving export
from repro.runtime.serving import PartitionedServeEngine
from repro.serving import Engine, EngineConfig, Request

cfg = ModelConfig(
    name="serve-demo-60m", arch_type="dense", n_layers=6, d_model=256,
    n_heads=8, n_kv_heads=4, d_ff=1024, vocab_size=4096,
    dtype="float32", param_dtype="float32", attn_chunk=64, remat=False)

params = T.init_params(cfg, jax.random.PRNGKey(0))
print(f"model: {cfg.name}, ~{cfg.param_count()/1e6:.1f}M params")

# --- batched monolithic serving: static buckets vs continuous --------------
# Both execution modes are one policy-configured Engine: admission=
# "batch" is the seed static-bucket executor, the default fifo streams
# through the continuous scheduler. (The legacy ServeEngine kwarg shim
# still works with a DeprecationWarning — tests/test_serving_shim.py
# covers it — but new code uses repro.serving.)
rng = np.random.RandomState(0)
reqs = [Request(i, rng.randint(0, cfg.vocab_size,
                               (32, 48)[i % 2]).astype(np.int32),
                max_new_tokens=24) for i in range(8)]
eng = Engine(cfg, params, EngineConfig(max_len=96, admission="batch"))
outs = eng.generate(reqs)
tput = sum(len(o.tokens) for o in outs) / sum(o.decode_s for o in outs)
print(f"static-bucket: served {len(outs)} requests, ~{tput:.1f} tok/s")
print(f"req 0 continuation: {outs[0].tokens} ({outs[0].finish_reason})")

cont = Engine(cfg, params, EngineConfig(max_len=96, max_slots=4))
arrivals = list(np.cumsum(np.full(len(reqs), 0.01)))   # 100 req/s stream
couts = cont.generate(reqs, arrivals=arrivals)
assert [c.tokens for c in couts] == [o.tokens for o in outs], \
    "continuous scheduler must emit the same greedy tokens"
print(f"continuous:    same tokens over 4 slots; mean ttft "
      f"{np.mean([c.ttft_s for c in couts])*1e3:.1f} ms, "
      f"{len(cont.scheduler.events)} admission-queue events")

# request lifecycle: priority admission, per-token streaming, cancel
life = Engine(cfg, params, EngineConfig(max_len=96, max_slots=1,
                                        admission="priority"))
bg = life.submit(Request(100, reqs[0].prompt, max_new_tokens=24))
hi = life.submit(Request(101, reqs[1].prompt, max_new_tokens=24,
                         priority=5))
first_hi = next(hi.stream())           # pull-based: drives the engine
bg.cancel()                            # background work no longer needed
life.run()
assert hi.tokens[:1] == [first_hi] and hi.finish_reason == "length"
assert bg.finish_reason == "cancelled"
admit_order = [e.request_id for e in life.scheduler.events
               if e.kind == "admit"]
print(f"lifecycle:     priority admit order {admit_order}, streamed "
      f"first token {first_hi}, cancelled req 100 after "
      f"{len(bg.tokens)} tokens")

# wall-clock serving surface: a background drain thread pumps the
# scheduler, callers just submit and wait; with enforce_deadlines an
# expired request is shed as finish_reason="timeout" instead of served
# late (runtime.server builds the HTTP front end on exactly this mode)
wall = Engine(cfg, params, EngineConfig(max_len=96, max_slots=2,
                                        admission="edf",
                                        enforce_deadlines=True))
with wall.start():
    served = wall.submit(Request(200, reqs[0].prompt, max_new_tokens=12))
    doomed = wall.submit(Request(201, reqs[1].prompt, max_new_tokens=12,
                                 deadline_s=0.0))     # already expired
    ok, shed = served.result(timeout=120), doomed.result(timeout=120)
assert ok.finish_reason == "length" and shed.finish_reason == "timeout"
print(f"background:    drain thread served req 200 ({len(ok.tokens)} "
      f"tokens) and shed req 201 as '{shed.finish_reason}' "
      f"({len(shed.tokens)} tokens emitted)")

# --- Edge-PRUNE partitioned inference --------------------------------------
g = T.to_actor_graph(cfg, params, batch=1, seq=48, group_size=2)
names = list(g.actors)
print(f"\nactor graph: {names}")
for pp in (2, 3, 4):
    mapping = Mapping(f"pp{pp}", {n: ("endpoint" if i < pp else "server")
                                  for i, n in enumerate(names)})
    pse = PartitionedServeEngine(cfg, params, mapping, batch=1, seq=48,
                                 group_size=2)
    logits = pse.infer(reqs[1].prompt[None])
    mono, _ = T.forward(params, cfg,
                        {"tokens": jax.numpy.asarray(reqs[1].prompt[None])},
                        train=False)
    ok = np.allclose(np.asarray(logits), np.asarray(mono), rtol=2e-4,
                     atol=2e-4)
    print(f"pp={pp}: boundary {pse.comm_bytes():6d} B/frame, "
          f"logits match monolithic: {ok}")
    assert ok
print("\npartitioned inference is bit-compatible with monolithic — the "
      "mapping is a pure deployment decision (Edge-PRUNE Sec III.B).")

# --- pipelined multi-frame streaming over the partition --------------------
mapping = Mapping("pp3", {n: ("endpoint" if i < 3 else "server")
                          for i, n in enumerate(names)})
pse = PartitionedServeEngine(cfg, params, mapping, batch=1, seq=48,
                             group_size=2)
pm = PlatformModel(paper_platform("N2", "wifi"))
frames = [rng.randint(0, cfg.vocab_size, (1, 48)).astype(np.int32)
          for _ in range(8)]
piped, sched = pse.infer_pipelined(frames, platform=pm)
local = pse.infer(frames[0])
assert np.array_equal(np.asarray(piped[0]), np.asarray(local))
print(f"\npipelined stream of {len(frames)} frames on N2/i7 over WiFi: "
      f"modeled makespan {sched.makespan_s*1e3:.1f} ms vs sequential "
      f"{sched.sequential_s*1e3:.1f} ms — {sched.speedup:.2f}x from "
      f"client/server overlap (the Fig 6 effect).")
