"""End-to-end driver: serve a small LLM with batched requests, both
monolithic and through an Edge-PRUNE partitioned actor graph.

The partitioned path is the paper's collaborative-inference scenario:
the model's early layer-group actors run on the "endpoint" unit, the
rest on the "server"; the synthesis step auto-inserts the TX/RX channel
at the boundary and the prefill executes stage-by-stage. We verify both
paths produce identical logits and report the boundary traffic per
request — the quantity the paper's Figs 4-6 trade against compute.

Run: PYTHONPATH=src python examples/distributed_serving.py
"""
import time

import jax
import numpy as np

from repro.core import Mapping
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.serving import (PartitionedServeEngine, Request,
                                   ServeEngine)

cfg = ModelConfig(
    name="serve-demo-60m", arch_type="dense", n_layers=6, d_model=256,
    n_heads=8, n_kv_heads=4, d_ff=1024, vocab_size=4096,
    dtype="float32", param_dtype="float32", attn_chunk=64, remat=False)

params = T.init_params(cfg, jax.random.PRNGKey(0))
print(f"model: {cfg.name}, ~{cfg.param_count()/1e6:.1f}M params")

# --- batched monolithic serving -------------------------------------------
rng = np.random.RandomState(0)
reqs = [Request(i, rng.randint(0, cfg.vocab_size, 48).astype(np.int32),
                max_new_tokens=24) for i in range(8)]
eng = ServeEngine(cfg, params, max_len=96)
outs = eng.generate(reqs)
tput = sum(len(o.tokens) for o in outs) / sum(o.decode_s for o in outs)
print(f"served {len(outs)} requests, decode throughput {tput:.1f} tok/s")
print(f"req 0 continuation: {outs[0].tokens}")

# --- Edge-PRUNE partitioned inference --------------------------------------
g = T.to_actor_graph(cfg, params, batch=1, seq=48, group_size=2)
names = list(g.actors)
print(f"\nactor graph: {names}")
for pp in (2, 3, 4):
    mapping = Mapping(f"pp{pp}", {n: ("endpoint" if i < pp else "server")
                                  for i, n in enumerate(names)})
    pse = PartitionedServeEngine(cfg, params, mapping, batch=1, seq=48,
                                 group_size=2)
    logits = pse.infer(reqs[0].prompt[None])
    mono, _ = T.forward(params, cfg,
                        {"tokens": jax.numpy.asarray(reqs[0].prompt[None])},
                        train=False)
    ok = np.allclose(np.asarray(logits), np.asarray(mono), rtol=2e-4,
                     atol=2e-4)
    print(f"pp={pp}: boundary {pse.comm_bytes():6d} B/frame, "
          f"logits match monolithic: {ok}")
    assert ok
print("\npartitioned inference is bit-compatible with monolithic — the "
      "mapping is a pure deployment decision (Edge-PRUNE Sec III.B).")
