"""Property-based tests (hypothesis) on the sharding rule system and the
MoC invariants the framework relies on:

* every resolved PartitionSpec uses each mesh axis at most once and only
  on dims it divides (the _fits contract), for arbitrary shapes/paths;
* batch shardings always shard dim 0 or replicate;
* the explorer's partition-point mappings cover the actor set exactly and
  monotonically (pp actors on the endpoint);
* token-rate invariants: lrl <= atr <= url and the symmetric-rate rule
  are enforced by construction.
"""
from __future__ import annotations

import os

import jax
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (see "
    "requirements-dev.txt); the fast lane skips them")
from hypothesis import given, settings, strategies as st
from jax.sharding import Mesh

from repro.sharding.rules import (_fits, batch_axes, batch_shardings,
                                  cache_shardings, spec_for)

# small real meshes over 1 CPU device won't validate axis sizes; build
# abstract meshes with fake devices via mesh of size 1x1 but we need the
# SHAPE. Use jax.sharding.AbstractMesh for pure spec logic.
from jax.sharding import AbstractMesh


def make_mesh(pod=None, data=4, model=4):
    if pod:
        return AbstractMesh((pod, data, model), ("pod", "data", "model"))
    return AbstractMesh((data, model), ("data", "model"))


PATHS = ["scan/0/block/wq", "scan/1/moe/w_gate", "rem/0/mlp/w_down",
         "embed", "lm_head", "scan/0/block/w_in", "encoder/0/block/wk",
         "scan/0/moe/router", "scan/0/block/conv_w", "frontend_proj/w1",
         "scan/0/block/w_up", "rem/1/block/wo", "opaque/leaf"]


@settings(max_examples=200, deadline=None)
@given(
    path=st.sampled_from(PATHS),
    rank=st.integers(1, 4),
    dims=st.lists(st.sampled_from([1, 2, 3, 4, 5, 8, 12, 16, 60, 128, 1152]),
                  min_size=4, max_size=4),
    pod=st.sampled_from([None, 2]),
    data=st.sampled_from([2, 4, 16]),
    model=st.sampled_from([2, 4, 16]),
)
def test_spec_for_is_always_valid(path, rank, dims, pod, data, model):
    mesh = make_mesh(pod, data, model)
    shape = tuple(dims[:rank])
    stacked = path.startswith("scan")
    spec = spec_for(path, (7,) + shape if stacked else shape, mesh,
                    stacked=stacked)
    full_shape = (7,) + shape if stacked else shape
    # pad spec to rank
    tup = tuple(spec) + (None,) * (len(full_shape) - len(tuple(spec)))
    assert _fits(tup, full_shape, mesh), (path, full_shape, spec)
    if stacked:
        assert tup[0] is None   # never shard the scan-period dim


@settings(max_examples=100, deadline=None)
@given(
    b=st.sampled_from([1, 2, 8, 32, 128, 256]),
    extra=st.lists(st.integers(1, 64), min_size=0, max_size=2),
    pod=st.sampled_from([None, 2]),
)
def test_batch_shardings_shard_dim0_or_replicate(b, extra, pod):
    mesh = make_mesh(pod, 4, 4)
    tree = {"x": jax.ShapeDtypeStruct((b,) + tuple(extra), np.float32)}
    sh = batch_shardings(tree, mesh)
    spec = tuple(sh["x"].spec)
    if spec:
        assert spec[0] in (batch_axes(mesh), batch_axes(mesh)[-1], None)
        got = spec[0]
        if got is not None:
            size = np.prod([mesh.shape[a] for a in
                            (got if isinstance(got, tuple) else (got,))])
            assert b % size == 0


@settings(max_examples=60, deadline=None)
@given(
    b=st.sampled_from([1, 4, 32, 128]),
    s=st.sampled_from([512, 2048, 32768, 524288]),
    hk=st.sampled_from([1, 2, 4, 8]),
    hd=st.sampled_from([64, 128, 256]),
)
def test_kv_cache_sharding_batch_then_sequence(b, s, hk, hd):
    """KV caches shard batch x heads when divisible, else fall back to
    sequence sharding; never violate divisibility."""
    mesh = make_mesh(None, 4, 4)
    tree = {"scan": [{"k": jax.ShapeDtypeStruct((3, b, s, hk, hd),
                                                np.float32)}]}
    sh = cache_shardings(tree, mesh)
    spec = tuple(sh["scan"][0]["k"].spec)
    full = (3, b, s, hk, hd)
    tup = spec + (None,) * (5 - len(spec))
    assert _fits(tup, full, mesh)
    assert tup[0] is None
    if b % 4 == 0:
        assert tup[1] is not None      # batch sharded when possible
    elif b == 1:
        assert tup[2] is not None      # sequence-sharded fallback


# ---------------------------------------------------------------------------
# MoC invariants
# ---------------------------------------------------------------------------

from repro.core.graph import Actor, ActorType, Graph, Port, PortDir
from repro.core.mapping import Mapping


def _chain(n):
    g = Graph(f"chain{n}")
    prev = None
    for i in range(n):
        inp = [Port("in", PortDir.IN, token_shape=(4,))] if i else []
        outp = [Port("out", PortDir.OUT, token_shape=(4,))] \
            if i < n - 1 else []
        a = g.add_actor(Actor(f"a{i}", ActorType.SPA, inp, outp))
        if prev is not None:
            g.connect(prev.port("out"), a.port("in"))
        prev = a
    return g


@settings(max_examples=50, deadline=None)
@given(n=st.integers(2, 20), pp=st.integers(1, 20))
def test_partition_point_mapping_is_monotone_cover(n, pp):
    pp = min(pp, n)
    g = _chain(n)
    m = Mapping.partition_point(g, pp)
    units = [m.unit_of(f"a{i}") for i in range(n)]
    assert units == ["endpoint"] * pp + ["server"] * (n - pp)
    # boundary edges = 1 iff 0 < pp < n
    assert len(m.boundary_edges(g)) == (1 if 0 < pp < n else 0)


@settings(max_examples=50, deadline=None)
@given(lrl=st.integers(0, 5), url=st.integers(0, 5))
def test_port_rate_limits_enforced(lrl, url):
    if lrl <= url:
        p = Port("p", PortDir.IN, lrl=lrl, url=url)
        assert p.is_static_rate == (lrl == url)
    else:
        with pytest.raises(ValueError):
            Port("p", PortDir.IN, lrl=lrl, url=url)
