"""Unified-Engine API + request-lifecycle tests.

* policy objects in isolation — admission orders (FIFO / priority /
  EDF), preemption victim selection, static bucketing — exercised with
  plain records, no JAX;
* the ``Engine`` facade — policy order is observable in the admission
  event trace (token identity across the full admission/layout/
  preemption matrix lives in tests/test_conformance_matrix.py);
* the request lifecycle — ``RequestHandle.cancel()`` (queued, active,
  from inside a token callback: never a token after cancel() returns),
  per-token streaming (callback and pull iterator), ``finish_reason``
  on every path (eos / length / cancelled / failed), restart accounting
  under ``SlotFailure``;
* the paged admission watermark — damps growth preemptions without
  changing tokens;
* (the legacy ``ServeEngine`` shim has a dedicated regression suite in
  tests/test_serving_shim.py);
* a hypothesis property: ANY interleaving of submit / cancel / priority
  / deadline / failure events — with wall-clock deadline enforcement on
  or off — leaks no slots or blocks, a cancelled request never emits a
  token after ``cancel()`` returns, and a shed request finishes
  ``"timeout"`` with its stream frozen;
* (deadline-shed unit coverage — expired before prefill, mid-decode,
  at submit — lives in tests/test_deadline_shedding.py; the threaded /
  asyncio surface in tests/test_async_engine.py; the HTTP layer in
  tests/test_server.py).
"""
from __future__ import annotations

from types import SimpleNamespace

import jax
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.policies import (BatchAdmission, DeadlineAdmission,
                                    EvictLatest, FifoAdmission,
                                    LowestPriority, PriorityAdmission,
                                    make_admission, make_preemption)
from repro.runtime.scheduler import Request, SlotFailure

KEY = jax.random.PRNGKey(0)


def _tiny_cfg() -> ModelConfig:
    return ModelConfig(
        name="tiny", arch_type="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32",
        param_dtype="float32", attn_chunk=16, remat=False)


@pytest.fixture(scope="module")
def setup():
    cfg = _tiny_cfg()
    return cfg, T.init_params(cfg, KEY)


def _mixed_requests(cfg, specs, seed=0, **req_kw):
    rng = np.random.RandomState(seed)
    return [Request(i, rng.randint(0, cfg.vocab_size, plen).astype(np.int32),
                    max_new_tokens=mnew, **req_kw)
            for i, (plen, mnew) in enumerate(specs)]


MIXED_SPECS = [(8, 6), (12, 4), (8, 9), (5, 1), (12, 7), (16, 5)]


# ---------------------------------------------------------------------------
# policies in isolation (no JAX, no model)
# ---------------------------------------------------------------------------

def _ticket(seq, arrival=0.0, priority=0, deadline=None, admit=-1):
    return SimpleNamespace(
        req=SimpleNamespace(priority=priority, deadline_s=deadline),
        arrival_s=arrival, submit_seq=seq, admit_seq=admit)


def test_admission_policy_orders():
    ts = [_ticket(0, arrival=0.2), _ticket(1, arrival=0.1),
          _ticket(2, arrival=0.1, priority=3),
          _ticket(3, arrival=0.3, priority=9, deadline=0.05),
          _ticket(4, arrival=0.0, deadline=0.2)]

    def order(policy):
        return [t.submit_seq for t in sorted(ts, key=policy.key)]

    # FIFO: arrival, then submission order
    assert order(FifoAdmission()) == [4, 1, 2, 0, 3]
    # priority: 9 > 3 > 0s (FIFO within level)
    assert order(PriorityAdmission()) == [3, 2, 4, 1, 0]
    # EDF: absolute due = arrival + deadline; no deadline sorts last
    assert order(DeadlineAdmission()) == [4, 3, 1, 2, 0]


def test_preemption_policy_picks():
    cands = [_ticket(0, priority=2, admit=0), _ticket(1, priority=0, admit=1),
             _ticket(2, priority=0, admit=2), _ticket(3, priority=5, admit=3)]
    assert EvictLatest().pick(cands).submit_seq == 3
    # lowest priority; latest-admitted among equals
    assert LowestPriority().pick(cands).submit_seq == 2


def test_batch_admission_buckets():
    reqs = [SimpleNamespace(prompt=np.zeros(n)) for n in (8, 4, 8, 2)]
    got = BatchAdmission().buckets(reqs)
    assert [(plen, [len(r.prompt) for r in rs]) for plen, rs in got] == \
        [(2, [2]), (4, [4]), (8, [8, 8])]


def test_policy_factories():
    assert isinstance(make_admission("edf"), DeadlineAdmission)
    assert isinstance(make_admission("static-bucket"), BatchAdmission)
    assert isinstance(make_preemption("lowest-priority"), LowestPriority)
    fifo = FifoAdmission()
    assert make_admission(fifo) is fifo          # instance passthrough
    with pytest.raises(ValueError, match="admission policy"):
        make_admission("lifo")
    with pytest.raises(ValueError, match="preemption policy"):
        make_preemption("oldest")


# ---------------------------------------------------------------------------
# Engine facade: policy order is observable (token identity across the
# whole layout/policy matrix lives in tests/test_conformance_matrix.py)
# ---------------------------------------------------------------------------

def test_priority_admission_order_is_observable(setup):
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(max_len=64, max_slots=1,
                                           admission="priority"))
    reqs = _mixed_requests(cfg, [(8, 3)] * 4)
    reqs[2].priority = 5
    reqs[3].priority = 1
    eng.generate(reqs)
    admits = [e.request_id for e in eng.scheduler.events if e.kind == "admit"]
    assert admits == [2, 3, 0, 1]


def test_edf_admission_order_is_observable(setup):
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(max_len=64, max_slots=1,
                                           admission="edf"))
    reqs = _mixed_requests(cfg, [(8, 3)] * 3)
    reqs[0].deadline_s = None                   # background: last
    reqs[1].deadline_s = 0.2
    reqs[2].deadline_s = 0.1
    eng.generate(reqs)
    admits = [e.request_id for e in eng.scheduler.events if e.kind == "admit"]
    assert admits == [2, 1, 0]


def test_engine_config_rejected_combinations(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="batch admission"):
        Engine(cfg, params, EngineConfig(admission="batch",
                                         kv_layout="paged"))
    with pytest.raises(ValueError, match="kv_layout"):
        Engine(cfg, params, EngineConfig(kv_layout="blocked"))
    with pytest.raises(ValueError, match="arrivals"):
        Engine(cfg, params, EngineConfig(max_len=64, admission="batch")) \
            .generate(_mixed_requests(cfg, [(8, 2)]), arrivals=[0.0])


# ---------------------------------------------------------------------------
# request lifecycle: cancellation, streaming, finish reasons
# ---------------------------------------------------------------------------

def test_cancel_queued_request_never_runs(setup):
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(max_len=64, max_slots=1))
    reqs = _mixed_requests(cfg, [(8, 4), (8, 4)])
    eng.submit(reqs[0])
    h = eng.submit(reqs[1])
    h.cancel()
    outs = eng.run()
    assert h.finish_reason == "cancelled" and h.tokens == []
    byid = {c.id: c for c in outs}
    assert byid[1].finish_reason == "cancelled" and byid[1].tokens == []
    assert byid[0].finish_reason == "length" and len(byid[0].tokens) == 4
    # the cancelled request never occupied a slot
    assert 1 not in [e.request_id for e in eng.scheduler.events
                     if e.kind == "admit"]


def test_cancel_unarrived_request_skips_idle_wait(setup):
    """Cancelling a far-future arrival must retire it from the backlog —
    the drain returns immediately instead of sleeping to its arrival."""
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(max_len=64, max_slots=1))
    h = eng.submit(_mixed_requests(cfg, [(8, 4)])[0], arrival_s=9999.0)
    h.cancel()
    outs = eng.run()
    assert [c.finish_reason for c in outs] == ["cancelled"]


def test_cancel_from_token_callback_stops_stream(setup):
    """The contract: once cancel() returns, not one more token. Cancel is
    issued from inside the request's own on_token callback mid-decode."""
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(max_len=64, max_slots=2))
    reqs = _mixed_requests(cfg, [(8, 12), (8, 12)])
    h0, h1 = eng.submit(reqs[0]), eng.submit(reqs[1])
    at_cancel = []

    @h0.on_token
    def _(tok):
        if len(h0.tokens) == 3:
            h0.cancel()
            at_cancel.append(list(h0.tokens))
    outs = eng.run()
    assert h0.finish_reason == "cancelled"
    assert h0.tokens == at_cancel[0] == h0.completion.tokens
    assert len(h0.tokens) == 3
    # the co-batched stream is unaffected
    assert h1.finish_reason == "length" and len(h1.tokens) == 12
    kinds = {e.request_id: [x.kind for x in eng.scheduler.events
                            if x.request_id == e.request_id]
             for e in eng.scheduler.events}
    assert kinds[0] == ["admit", "cancel"]


def test_cancel_from_other_streams_callback_blocks_admission(setup):
    """A cancel issued mid-admission-pass — from an earlier admission's
    first-token callback — must keep the victim from ever being
    prefilled: the no-token-after-cancel contract covers token zero."""
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(max_len=64, max_slots=2))
    reqs = _mixed_requests(cfg, [(8, 4), (8, 4)])
    h0 = eng.submit(reqs[0])
    h1 = eng.submit(reqs[1])
    h0.on_token(lambda tok: h1.cancel())
    outs = eng.run()
    byid = {c.id: c for c in outs}
    assert byid[1].finish_reason == "cancelled" and h1.tokens == []
    assert 1 not in [e.request_id for e in eng.scheduler.events
                     if e.kind == "admit"]
    assert byid[0].finish_reason == "length" and len(h0.tokens) == 4


def test_step_driven_drain_after_idle_gap_rebases_epoch(setup):
    """A fresh submission after a completed drain starts a fresh arrival
    epoch on the step-driven path too: the idle wall-clock gap must not
    leak into the new request's TTFT/latency."""
    import time as _time
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(max_len=64, max_slots=1))
    eng.submit(_mixed_requests(cfg, [(8, 2)])[0])
    eng.run()
    _time.sleep(1.0)                        # idle gap between drains
    h = eng.submit(_mixed_requests(cfg, [(8, 2)])[0])
    c = h.result()                          # step-driven, no run() call
    assert 0.0 <= c.ttft_s < 0.5 and c.latency_s < 0.5


def test_cancel_is_idempotent_and_noop_after_completion(setup):
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(max_len=64, max_slots=1))
    h = eng.submit(_mixed_requests(cfg, [(8, 3)])[0])
    outs = eng.run()
    assert h.finish_reason == "length"
    h.cancel()                              # completed: must be a no-op
    h.cancel()
    assert h.finish_reason == "length" and len(h.tokens) == 3
    assert outs[0].tokens == h.tokens


def test_stream_iterator_and_result(setup):
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(max_len=64, max_slots=2))
    reqs = _mixed_requests(cfg, [(8, 5), (12, 7)])
    h0, h1 = eng.submit(reqs[0]), eng.submit(reqs[1])
    streamed = list(h0.stream())            # pull-driven: advances engine
    assert streamed == h0.completion.tokens and len(streamed) == 5
    c1 = h1.result()                        # drives the rest of the drain
    assert c1.finish_reason == "length" and len(c1.tokens) == 7
    assert eng.scheduler.done


def test_stream_then_run_keeps_timeline_coherent(setup):
    """Mixing the step-driven API with a closing run() must not rebase
    the engine clock: in-flight timestamps stay on one epoch, so no
    completion reports a negative decode span or finish < first-token."""
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(max_len=64, max_slots=2))
    h = eng.submit(_mixed_requests(cfg, [(8, 6)])[0])
    next(h.stream())                        # step-driven first token
    outs = eng.run()
    assert outs[0].decode_s >= 0.0
    assert outs[0].finish_s >= outs[0].first_token_s >= 0.0


def test_batch_double_submit_same_request_object(setup):
    """Submitting the same Request object twice through batch admission
    must complete both handles (no identity-keyed dedup)."""
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(max_len=64, admission="batch"))
    (req,) = _mixed_requests(cfg, [(8, 4)])
    h1, h2 = eng.submit(req), eng.submit(req)
    outs = eng.run()
    assert len(outs) == 2 and h1.done and h2.done
    assert h1.tokens == h2.tokens and len(h1.tokens) == 4


def test_finish_reason_eos_vs_length_continuous_and_static(setup):
    """The satellite backfill: eos-stop and length-stop are no longer
    conflated, and both executors agree on every request."""
    cfg, params = setup
    specs = [(8, 12), (10, 12), (6, 12)]
    probe = Engine(cfg, params, EngineConfig(max_len=64, admission="batch")) \
        .generate(_mixed_requests(cfg, specs))
    eos = probe[0].tokens[3]                # occurs mid-stream for req 0
    reqs = _mixed_requests(cfg, specs, eos=eos)
    static = Engine(cfg, params, EngineConfig(max_len=64, admission="batch")) \
        .generate(reqs)
    cont = Engine(cfg, params, EngineConfig(max_len=64, max_slots=2)) \
        .generate(_mixed_requests(cfg, specs, eos=eos))
    assert [c.tokens for c in cont] == [c.tokens for c in static]
    assert [c.finish_reason for c in cont] == \
        [c.finish_reason for c in static]
    assert static[0].finish_reason == "eos" and len(static[0].tokens) < 12
    assert "length" in {c.finish_reason for c in static}


def test_slot_failure_restart_accounting_and_failed_reason(setup):
    """SlotFailure-requeued requests surface how they ended: restart
    count on success, finish_reason='failed' (tokens truncated at the
    failure point) once max_restarts is exhausted."""
    cfg, params = setup
    spec = [(8, 8)]
    ref = Engine(cfg, params, EngineConfig(max_len=64, admission="batch")) \
        .generate(_mixed_requests(cfg, spec))
    retried = Engine(cfg, params, EngineConfig(max_len=64, max_slots=1),
                     failures=[SlotFailure(step=2, slots=(0,))]) \
        .generate(_mixed_requests(cfg, spec))
    assert retried[0].tokens == ref[0].tokens
    assert retried[0].finish_reason == "length"
    assert retried[0].restarts == 1
    failed = Engine(cfg, params, EngineConfig(max_len=64, max_slots=1),
                    failures=[SlotFailure(step=2, slots=(0,))]) \
        .generate(_mixed_requests(cfg, spec, max_restarts=0))
    assert failed[0].finish_reason == "failed"
    assert failed[0].restarts == 0
    # the tokens streamed before the failure are reported, nothing more
    assert failed[0].tokens == ref[0].tokens[:len(failed[0].tokens)]
    assert len(failed[0].tokens) < len(ref[0].tokens)


def test_failed_after_multiple_restarts_reports_streamed_history(setup):
    """A terminal failure after earlier restarts must report the longest
    streamed history, not the final attempt's shorter replay — the
    completion and the handle's stream must agree."""
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(max_len=64, max_slots=1),
                 failures=[SlotFailure(step=2, slots=(0,)),
                           SlotFailure(step=3, slots=(0,))])
    h = eng.submit(_mixed_requests(cfg, [(8, 8)], max_restarts=1)[0])
    (out,) = eng.run()
    assert out.finish_reason == "failed" and out.restarts == 1
    assert out.tokens == h.tokens
    assert 1 <= len(out.tokens) < 8


def test_static_cancel_before_and_during_bucket(setup):
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(max_len=64, admission="batch"))
    reqs = _mixed_requests(cfg, [(8, 8), (8, 8), (8, 8)])
    h0, h1, h2 = (eng.submit(r) for r in reqs)
    h0.cancel()                             # before the bucket runs

    @h1.on_token
    def _(tok):
        if len(h1.tokens) == 2:
            h1.cancel()
    outs = eng.run()
    byid = {c.id: c for c in outs}
    assert byid[0].finish_reason == "cancelled" and byid[0].tokens == []
    assert byid[1].finish_reason == "cancelled" and len(byid[1].tokens) == 2
    assert byid[2].finish_reason == "length" and len(byid[2].tokens) == 8


# ---------------------------------------------------------------------------
# paged admission watermark
# ---------------------------------------------------------------------------

def test_watermark_damps_growth_preemption(setup):
    """Holding back free blocks at admission leaves growth headroom for
    the running requests: strictly fewer (here: zero) growth preemptions
    on an oversubscribed pool, with tokens unchanged."""
    cfg, params = setup
    preempts = {}
    outs = {}
    for wm in (0, 3):
        eng = Engine(cfg, params, EngineConfig(
            max_len=64, max_slots=4, kv_layout="paged", block_size=4,
            num_blocks=16, watermark=wm, debug=True))
        outs[wm] = eng.generate(_mixed_requests(cfg, MIXED_SPECS))
        preempts[wm] = eng.stats()["preemptions"]
        assert eng.scheduler.alloc.in_use == 0
    assert preempts[0] > 0, "workload must thrash without a watermark"
    assert preempts[3] < preempts[0]
    assert [c.tokens for c in outs[0]] == [c.tokens for c in outs[3]]


def test_watermark_never_blocks_a_servable_request(setup):
    cfg, params = setup
    # capacity 7, watermark 5 leaves 2 admissible blocks. A 2-block
    # prompt that grows to 3 blocks IS servable: admission needs
    # prompt + watermark free, growth bypasses the watermark.
    eng = Engine(cfg, params, EngineConfig(
        max_len=32, max_slots=2, kv_layout="paged", block_size=4,
        num_blocks=8, watermark=5, debug=True))
    rng = np.random.RandomState(0)
    (out,) = eng.generate([Request(0, rng.randint(0, cfg.vocab_size, 8)
                                   .astype(np.int32), max_new_tokens=4)])
    assert len(out.tokens) == 4 and eng.scheduler.alloc.in_use == 0
    # a 3-block prompt can never clear admission with 5 held back
    with pytest.raises(ValueError, match="watermark"):
        eng.submit(Request(1, np.zeros(12, np.int32), max_new_tokens=2))
    # and a worst case beyond the whole pool is rejected regardless
    with pytest.raises(ValueError, match="worst-case"):
        eng.submit(Request(2, np.zeros(8, np.int32), max_new_tokens=24))
    with pytest.raises(ValueError, match="watermark"):
        Engine(cfg, params, EngineConfig(
            kv_layout="paged", block_size=4, num_blocks=8, watermark=7))


# (the legacy ServeEngine shim has its own regression suite in
# tests/test_serving_shim.py)


# ---------------------------------------------------------------------------
# property: arbitrary lifecycle interleavings leak nothing
# ---------------------------------------------------------------------------

CFG = _tiny_cfg()
PARAMS = T.init_params(CFG, KEY)
PROMPT_LENS = (4, 6, 8)


def test_property_lifecycle_interleavings():
    hypothesis = pytest.importorskip(
        "hypothesis", reason="property tests need hypothesis (see "
        "requirements-dev.txt); the fast lane skips them")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=8, deadline=None)
    @given(data=st.data())
    def inner(data):
        """Random workloads mixing priorities, deadlines, cancellations
        (immediate and at a drawn token index, issued from inside the
        token callback) and SlotFailure injections, over a drawn
        layout/admission combination: every request gets exactly one
        completion with a legal finish_reason, a cancelled request's
        token stream is frozen the moment cancel() returns, and no slot
        or block outlives the drain."""
        rng = np.random.RandomState(data.draw(st.integers(0, 2 ** 16),
                                              label="seed"))
        n_req = data.draw(st.integers(2, 6), label="n_req")
        max_slots = data.draw(st.integers(1, 3), label="max_slots")
        paged = data.draw(st.booleans(), label="paged")
        admission = data.draw(st.sampled_from(["fifo", "priority", "edf"]),
                              label="admission")
        kw = {}
        if paged:
            # the workload's worst case is 4 blocks (8 + 6 - 1 rows);
            # the watermark shrinks admissible capacity, so size the
            # pool to keep every drawn request servable
            wm = data.draw(st.integers(0, 1), label="watermark")
            kw = dict(kv_layout="paged", block_size=4,
                      num_blocks=data.draw(st.integers(5 + wm, 13),
                                           label="num_blocks"),
                      watermark=wm,
                      preemption=data.draw(st.sampled_from(
                          ["evict-latest", "lowest-priority"]),
                          label="preemption"),
                      prefill_chunk=data.draw(st.sampled_from([0, 4]),
                                              label="chunk"))
        enforce = data.draw(st.booleans(), label="enforce_deadlines")
        n_fail = data.draw(st.integers(0, 2), label="n_fail")
        failures = [SlotFailure(step=data.draw(st.integers(0, 20),
                                               label=f"fail_step{i}"),
                                slots=data.draw(st.sampled_from(
                                    [None, (0,), (0, 1)]),
                                    label=f"fail_slots{i}"))
                    for i in range(n_fail)]
        eng = Engine(CFG, PARAMS, EngineConfig(
            max_len=16, max_slots=max_slots, admission=admission,
            enforce_deadlines=enforce, debug=True, **kw),
            failures=failures)
        handles = []
        frozen = {}                      # id -> tokens at cancel() return
        for i in range(n_req):
            req = Request(
                i, rng.randint(0, CFG.vocab_size,
                               PROMPT_LENS[i % len(PROMPT_LENS)]
                               ).astype(np.int32),
                max_new_tokens=int(rng.randint(1, 7)),
                priority=int(rng.randint(0, 3)),
                deadline_s=None if rng.rand() < 0.5
                else float(rng.rand() * 0.2),
                max_restarts=data.draw(st.sampled_from([None, 0, 2]),
                                       label=f"max_restarts{i}"))
            h = eng.submit(req)
            cancel_at = data.draw(
                st.sampled_from([None, 0, 1, 3]), label=f"cancel_at{i}")
            if cancel_at == 0:
                h.cancel()
                frozen[i] = list(h.tokens)
            elif cancel_at is not None:
                def make_cb(h=h, at=cancel_at, i=i):
                    def cb(tok):
                        if len(h.tokens) >= at and i not in frozen:
                            h.cancel()
                            frozen[i] = list(h.tokens)
                    return cb
                h.on_token(make_cb())
            handles.append(h)
        outs = eng.run()
        assert sorted(c.id for c in outs) == list(range(n_req)), \
            "request lost or duplicated"
        for h, c in zip(handles, sorted(outs, key=lambda c: c.id)):
            assert c.finish_reason in ("eos", "length", "cancelled",
                                       "failed", "timeout")
            assert h.completion is c
            if c.finish_reason == "cancelled":
                assert h.tokens == frozen[c.id], \
                    "token emitted after cancel() returned"
            elif c.finish_reason == "length":
                assert len(c.tokens) == h.request.max_new_tokens
            elif c.finish_reason == "failed":
                assert h.request.max_restarts is not None
                assert c.restarts <= h.request.max_restarts
            elif c.finish_reason == "timeout":
                # shedding only ever fires on a deadline-carrying
                # request under enforcement, and freezes the stream
                assert enforce and h.request.deadline_s is not None
                assert h.tokens == c.tokens, \
                    "token emitted after the shed"
        sched = eng.scheduler
        assert sched.done
        assert sorted(sched.free) == list(range(max_slots)), "slot leak"
        assert not sched.cache_len.any() and not sched.tokens.any()
        if paged:
            assert sched.alloc.in_use == 0, "leaked blocks"
            assert sched.alloc.available == sched.alloc.capacity
            assert not sched.block_tables.any()

    inner()
