"""Property-based prefix-cache-service tests.

One core routine drives an engine through an arbitrary interleaving of
admissions (mixed tenants, overlapping prefixes), completions, slot
failures, growth preemptions (tight pool), and optional mid-trace
checkpoint/restart — then asserts the service's invariants:

* the allocator books balance at every drain: ``in_use`` equals the
  victim-pool population exactly (no leak, no double-free — a block is
  either free, live, or parked, never two at once);
* no block is simultaneously referenced by a live slot and resident in
  the victim pool (``layout.check`` pins this per call);
* tenant isolation: with identical prompts submitted under different
  tenants, the block sets backing each tenant's parked chains are
  disjoint, and a foreign tenant's ``match_prefix`` finds nothing;
* a save/restore cycle midway through the trace preserves all of the
  above and changes no tokens.

The hypothesis wrappers explore the space (nightly lane installs
hypothesis; locally they skip); the fixed-seed smoke tests below pin a
handful of known-interesting traces so the fast lane still exercises
the core routine without the dependency.
"""
from __future__ import annotations

import os
import tempfile

import jax
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.scheduler import Request, SlotFailure

CFG = ModelConfig(
    name="tiny-pc-props", arch_type="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32",
    param_dtype="float32", attn_chunk=16, remat=False)
PARAMS = T.init_params(CFG, jax.random.PRNGKey(0))

_PREFIX_RNG = np.random.RandomState(7)
PREFIXES = [_PREFIX_RNG.randint(0, CFG.vocab_size, 8).astype(np.int32)
            for _ in range(3)]
TENANTS = ("", "acme", "globex")


def _trace_engine(num_blocks, max_slots, failures, quotas):
    return Engine(CFG, PARAMS, EngineConfig(
        max_len=24, max_slots=max_slots, kv_layout="paged", block_size=4,
        num_blocks=num_blocks, prefix_cache=True, victim_cache=True,
        prefix_cache_tenants=quotas, greedy=True, seed=0, debug=True),
        failures=failures)


def _requests(rng, n_req, start_id=0):
    reqs = []
    for i in range(n_req):
        head = PREFIXES[rng.randint(len(PREFIXES))]
        tail = rng.randint(0, CFG.vocab_size, rng.randint(0, 5)).astype(
            np.int32)
        reqs.append(Request(
            start_id + i, np.concatenate([head, tail]) if len(tail) else
            head.copy(), max_new_tokens=int(rng.randint(1, 6)),
            tenant=TENANTS[rng.randint(len(TENANTS))]))
    return reqs


def _assert_service_invariants(eng, max_slots):
    """Drain-time books: free + parked == capacity, parked set == index
    cover, per-step check() rules (no live/parked overlap, tenant tags
    consistent) hold."""
    sched = eng.scheduler
    lay = sched.layout
    assert sched.alloc.in_use == len(lay.victim), \
        "blocks neither live nor parked at drain (leak)"
    assert sched.alloc.available == sched.alloc.capacity - len(lay.victim)
    assert set(lay._block_keys) == set(lay.victim.blocks)
    lay.check(set(), max_slots)
    sched.alloc.check()


def _tenant_block_sets(lay):
    per = {}
    for b in lay.victim.blocks:
        per.setdefault(lay._block_tenant.get(b, ""), set()).add(b)
    return per


def run_trace(seed, n_req=6, num_blocks=12, max_slots=2, n_waves=2,
              with_failures=True, with_restart=False, quotas=None):
    """The core property routine; every wrapper below funnels into it.
    Returns the {request id: tokens} map for oracle comparisons."""
    rng = np.random.RandomState(seed)
    failures = [SlotFailure(step=int(rng.randint(0, 15)),
                            slots=(0,) if rng.rand() < 0.5 else None)
                ] if with_failures and rng.rand() < 0.6 else []
    eng = _trace_engine(num_blocks, max_slots, failures, quotas)
    toks = {}
    ckpt = None
    for wave in range(n_waves):
        reqs = _requests(rng, n_req, start_id=wave * 100)
        outs = eng.generate(reqs)
        assert sorted(c.id for c in outs) == sorted(r.id for r in reqs)
        for c in outs:
            if c.finish_reason == "length":
                assert len(c.tokens) == next(
                    r for r in reqs if r.id == c.id).max_new_tokens
            toks[c.id] = list(c.tokens)
        _assert_service_invariants(eng, max_slots)
        per = _tenant_block_sets(eng.scheduler.layout)
        tenants = list(per)
        for i, a in enumerate(tenants):     # pairwise disjointness
            for b in tenants[i + 1:]:
                assert not (per[a] & per[b]), \
                    f"tenants {a!r}/{b!r} share parked blocks"
        # a hash hit may never map another tenant's K/V: at drain every
        # block is parked, so any match must resolve inside the
        # requesting tenant's own parked set
        lay = eng.scheduler.layout
        for head in PREFIXES:
            for t in TENANTS:
                blks, _ = lay.match_prefix(head, tenant=t)
                assert set(blks) <= per.get(t, set()), \
                    "match resolved blocks outside the tenant's namespace"
        if with_restart and wave == 0:
            fd, path = tempfile.mkstemp(suffix=".npz")
            os.close(fd)
            try:
                eng.save_prefix_cache(path)
                eng = _trace_engine(num_blocks, max_slots, [], quotas)
                eng.restore_prefix_cache(path)
                _assert_service_invariants(eng, max_slots)
            finally:
                for p in (path, path + ".meta.json"):
                    if os.path.exists(p):
                        os.remove(p)
    return toks


# -- fixed-seed smoke (fast lane, no hypothesis needed) ---------------------

@pytest.mark.parametrize("seed", [0, 3, 11])
def test_trace_smoke(seed):
    run_trace(seed)


def test_trace_smoke_with_restart():
    run_trace(5, with_restart=True, with_failures=False)


def test_trace_smoke_with_quotas():
    bb = 4 * T.kv_row_bytes(CFG)
    toks = run_trace(9, quotas={"acme": 2 * bb, "globex": 4 * bb})
    assert toks


def test_trace_tokens_match_victimless_oracle():
    """The cache is a pure work-saver: the same trace with the victim
    cache off (the prefix index dies at each drain, so no cross-wave
    reuse at all) yields identical token streams."""
    seed = 4
    cached = run_trace(seed, with_failures=False)
    # with_failures=False consumes no rng draws before the waves, so the
    # mirrored trace below sees the exact same request stream
    rng = np.random.RandomState(seed)
    plain = {}
    eng = Engine(CFG, PARAMS, EngineConfig(
        max_len=24, max_slots=2, kv_layout="paged", block_size=4,
        num_blocks=12, prefix_cache=True, victim_cache=False,
        greedy=True, seed=0, debug=True))
    for wave in range(2):
        for c in eng.generate(_requests(rng, 6, start_id=wave * 100)):
            plain[c.id] = list(c.tokens)
    assert cached == plain, "victim cache changed the sampled tokens"


# -- hypothesis exploration (nightly lane) ----------------------------------
# Guarded with a plain try/import (NOT module-level importorskip, which
# would skip the fixed-seed smoke tests above too): the fast lane runs
# the smokes without hypothesis installed, the nightly lane explores.

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    st = None

if st is not None:
    @settings(max_examples=15, deadline=None)
    @given(data=st.data())
    def test_property_cache_service_interleavings(data):
        """Arbitrary seeds x pool sizes x slot widths x failure toggles:
        every drain balances the books, live/parked sets never overlap,
        and tenants stay disjoint."""
        run_trace(seed=data.draw(st.integers(0, 2 ** 16), label="seed"),
                  n_req=data.draw(st.integers(2, 7), label="n_req"),
                  num_blocks=data.draw(st.integers(9, 16),
                                       label="num_blocks"),
                  max_slots=data.draw(st.integers(1, 3), label="max_slots"),
                  with_failures=data.draw(st.booleans(), label="failures"))

    @settings(max_examples=8, deadline=None)
    @given(data=st.data())
    def test_property_restart_preserves_invariants(data):
        """A checkpoint/restore inserted mid-trace preserves the
        invariants and never manufactures or loses blocks."""
        run_trace(seed=data.draw(st.integers(0, 2 ** 16), label="seed"),
                  num_blocks=data.draw(st.integers(10, 16),
                                       label="num_blocks"),
                  with_failures=False, with_restart=True)

    @settings(max_examples=8, deadline=None)
    @given(data=st.data())
    def test_property_quotas_hold_under_any_trace(data):
        """Per-tenant budgets are never exceeded at any drain point."""
        bb = 4 * T.kv_row_bytes(CFG)
        quotas = {"acme": data.draw(st.integers(1, 4), label="qa") * bb,
                  "globex": data.draw(st.integers(1, 4), label="qg") * bb}
        seed = data.draw(st.integers(0, 2 ** 16), label="seed")
        rng = np.random.RandomState(seed)
        eng = _trace_engine(12, 2, [], quotas)
        for wave in range(2):
            eng.generate(_requests(rng, 5, start_id=wave * 100))
            per = eng.scheduler.layout.victim.per_tenant_bytes()
            for t, cap in quotas.items():
                assert per.get(t, 0) <= cap, (t, per, quotas)
            _assert_service_invariants(eng, 2)
